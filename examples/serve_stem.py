"""Serving example: a mixed-length, staggered-arrival request trace through
the continuous-batching engine with the paged Stem KV cache — the paper's
deployment scenario, multi-tenant.  All arms share the engine and are
declared via the policy registry (``--policy``); the dense arm runs the
same paged decode at ``budget_frac=1.0`` (the dense-equivalent oracle), so
the comparisons isolate each policy's selection rule.

Prefill is **chunked** by default: long prompts advance ``--chunk-size``
tokens per engine step inside the single unified trace instead of stalling
co-tenants behind a monolithic pass (``--monolithic`` shows the legacy
behaviour and its per-length retraces).

  PYTHONPATH=src python examples/serve_stem.py
"""
from repro.launch import serve as serve_mod

COMMON = [
    "--arch", "qwen3-0.6b", "--reduced",
    "--requests", "6", "--min-prompt", "64", "--max-prompt", "320",
    "--decode-tokens", "16", "--max-slots", "3", "--arrival-every", "2",
    "--block-size", "32", "--chunk-size", "128",
]


def main():
    print("== dense-equivalent decode (budget_frac=1.0, chunked prefill) ==")
    dense = serve_mod.main(COMMON)
    print("\n== Stem-sparse decode (--policy stem, budget_frac=0.5) ==")
    stem = serve_mod.main(COMMON + ["--policy", "stem", "--budget-frac", "0.5"])
    print("\n== StreamingLLM decode (--policy streaming: sink+local pages) ==")
    streaming = serve_mod.main(COMMON + ["--policy", "streaming"])
    print("\n== monolithic-prefill baseline (per-length traces, HOL stalls) ==")
    mono = serve_mod.main(COMMON + ["--policy", "stem", "--monolithic"])
    print(f"\nthroughput dense {dense['throughput_tok_s']:.1f} tok/s vs stem "
          f"{stem['throughput_tok_s']:.1f} tok/s vs streaming "
          f"{streaming['throughput_tok_s']:.1f} tok/s; inter-token p50 "
          f"{dense['p50_ms']:.2f} -> {stem['p50_ms']:.2f} -> "
          f"{streaming['p50_ms']:.2f} ms; chunked vs monolithic p95 "
          f"{stem['p95_ms']:.2f} vs {mono['p95_ms']:.2f} ms, traces "
          f"{stem['engine_stats']['traces']} vs "
          f"{mono['engine_stats']['traces']}"
          f"+{mono['engine_stats']['prefill_traces']} "
          f"(CPU proxy; roofline analysis covers the TPU story)")


if __name__ == "__main__":
    main()
