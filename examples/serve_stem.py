"""Serving example: a mixed-length, staggered-arrival request trace through
the continuous-batching engine with the paged Stem KV cache — the paper's
deployment scenario, multi-tenant.  All arms share the engine and are
declared via the policy registry (``--policy``); the dense arm runs the
same paged decode at ``budget_frac=1.0`` (the dense-equivalent oracle), so
the comparisons isolate each policy's selection rule.

  PYTHONPATH=src python examples/serve_stem.py
"""
from repro.launch import serve as serve_mod

COMMON = [
    "--arch", "qwen3-0.6b", "--reduced",
    "--requests", "6", "--min-prompt", "64", "--max-prompt", "320",
    "--decode-tokens", "16", "--max-slots", "3", "--arrival-every", "2",
    "--block-size", "32",
]


def main():
    print("== dense-equivalent decode (budget_frac=1.0) ==")
    dense = serve_mod.main(COMMON)
    print("\n== Stem-sparse decode (--policy stem, budget_frac=0.5) ==")
    stem = serve_mod.main(COMMON + ["--policy", "stem", "--budget-frac", "0.5"])
    print("\n== StreamingLLM decode (--policy streaming: sink+local pages) ==")
    streaming = serve_mod.main(COMMON + ["--policy", "streaming"])
    print(f"\nthroughput dense {dense['throughput_tok_s']:.1f} tok/s vs stem "
          f"{stem['throughput_tok_s']:.1f} tok/s vs streaming "
          f"{streaming['throughput_tok_s']:.1f} tok/s; per-token p50 "
          f"{dense['p50_ms']:.2f} -> {stem['p50_ms']:.2f} -> "
          f"{streaming['p50_ms']:.2f} ms "
          f"(CPU proxy; roofline analysis covers the TPU story)")


if __name__ == "__main__":
    main()
