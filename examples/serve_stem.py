"""Serving example: batched requests through Stem-accelerated prefill then
greedy decode — the paper's deployment scenario (TTFT is what Stem cuts).

  PYTHONPATH=src python examples/serve_stem.py
"""
from repro.launch import serve as serve_mod


def main():
    print("== dense prefill ==")
    dense = serve_mod.main([
        "--arch", "qwen3-0.6b", "--reduced", "--batch", "4",
        "--prompt-len", "512", "--decode-tokens", "16",
    ])
    print("\n== Stem prefill ==")
    stem = serve_mod.main([
        "--arch", "qwen3-0.6b", "--reduced", "--batch", "4",
        "--prompt-len", "512", "--decode-tokens", "16", "--stem",
    ])
    print(f"\nTTFT dense {dense['ttft_s']*1e3:.1f} ms vs stem "
          f"{stem['ttft_s']*1e3:.1f} ms "
          f"(CPU proxy; roofline analysis covers the TPU story)")


if __name__ == "__main__":
    main()
