"""Quickstart: Stem sparse attention as a drop-in module.

Runs the coarse-to-fine pipeline (Algorithm 1) on random Q/K/V, compares
against dense attention, and prints the realized budget — the 60-second
tour of the paper's contribution.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import StemConfig, dense_attention, stem_attention
from repro.core.schedule import schedule_for


def main():
    batch, q_heads, kv_heads, seq, head_dim = 2, 8, 4, 4096, 64
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (batch, q_heads, seq, head_dim), jnp.float32)
    k = jax.random.normal(keys[1], (batch, kv_heads, seq, head_dim), jnp.float32)
    v = jax.random.normal(keys[2], (batch, kv_heads, seq, head_dim), jnp.float32)

    # Paper defaults: B=128, mu=0.7, beta=0.2, 4 sink + 4 local blocks.
    cfg = StemConfig(block_size=128, k_start_frac=0.25, mu=0.7, beta=0.2,
                     sink_blocks=2, local_blocks=2, min_budget_blocks=4)

    out, stats = stem_attention(q, k, v, cfg, return_stats=True)
    ref = dense_attention(q, k, v)

    budgets = schedule_for(cfg, seq)
    print(f"sequence        : {seq} tokens = {seq // cfg.block_size} blocks of {cfg.block_size}")
    print(f"TPD budgets     : first rows {budgets[:4].tolist()} ... last rows {budgets[-4:].tolist()}")
    print(f"realized density: {float(stats.density):.1%} of the causal block triangle")
    print(f"max error vs dense: {float(jnp.abs(out - ref).max()):.4f}")
    print(f"mean error vs dense: {float(jnp.abs(out - ref).mean()):.5f}")
    print("(random QKV is the worst case for sparse attention; see "
          "benchmarks/oam_vs_sam.py for trained-model reconstruction errors)")


if __name__ == "__main__":
    main()
