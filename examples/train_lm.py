"""End-to-end driver: train a ~100M-parameter qwen3-family LM for a few
hundred steps on the synthetic pipeline, with checkpointing + straggler
monitoring — the full production path at CPU scale.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: qwen3-family geometry scaled to CPU wall-clock budget.
    from repro import configs
    from repro.configs.base import ArchConfig

    cfg = ArchConfig(
        name="qwen3-100m", family="dense", num_layers=6, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=1536,
        vocab_size=50304, qk_norm=True, dtype="float32",
    )
    configs.ALL[cfg.name] = cfg

    out = train_mod.main([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--batch", "8", "--seq", "512", "--lr", "3e-4",
        "--checkpoint-dir", args.checkpoint_dir,
        "--checkpoint-every", "50", "--log-every", "10",
    ])
    losses = out["losses"]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    if losses[-1] >= losses[0]:
        print("WARNING: loss did not decrease", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
