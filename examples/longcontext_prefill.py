"""Long-context prefill: Stem's budget scaling on a 16k-token prompt.

Shows the TPD schedule, the realized density at the paper's length rule
(k_start = 0.2 N_blk at 16k), and per-position reconstruction error —
early rows (recursive anchors) get large budgets, late rows are pruned hard.

  PYTHONPATH=src python examples/longcontext_prefill.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import StemConfig, dense_attention, stem_attention
from repro.core.schedule import average_budget, schedule_for


def main():
    seq = 16384
    cfg = StemConfig()   # paper defaults incl. the length-dependent k_start
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, seq, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, seq, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, seq, 64), jnp.float32)
    # a couple of heavy-hitter keys that Stem must keep
    v = v.at[:, :, 100:110].multiply(10.0)

    budgets = schedule_for(cfg, seq)
    nb = seq // cfg.block_size
    print(f"{seq} tokens -> {nb} blocks; k_start = {cfg.k_start_blocks(seq)} blocks"
          f" ({cfg.k_start_fraction(seq):.0%} rule), floor {cfg.min_budget_blocks}")
    print(f"budget row 16: {budgets[16]}  row {nb//2}: {budgets[nb//2]}  "
          f"row {nb-1}: {budgets[nb-1]}  (avg {average_budget(budgets):.1f})")

    out, stats = stem_attention(q, k, v, cfg, return_stats=True)
    ref = dense_attention(q, k, v)
    err = np.asarray(jnp.abs(out - ref).mean(axis=(0, 1, 3)))
    qtr = seq // 4
    print(f"realized density: {float(stats.density):.1%}")
    for i in range(4):
        print(f"mean |err| rows [{i*qtr:6d},{(i+1)*qtr:6d}): "
              f"{err[i*qtr:(i+1)*qtr].mean():.5f}")


if __name__ == "__main__":
    main()
