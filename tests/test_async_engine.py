"""Async engine loop tests (runtime/engine.py async pipeline +
runtime/sampling.py + the pallas-fallback observability counter).

The load-bearing property is **bit-identity**: the async pipeline
(on-device sampling, device-resident fed-back-token buffer, one-step
lookahead dispatch) must emit exactly the streams of the synchronous
oracle loop — across policies, under chaos, through preempt/restore
cycles, and with EOS termination (where the one speculative lookahead
step is discarded for free).  On top of that, the perf contract: the
async loop's blocking host syncs are O(finished requests), not O(steps),
and it still compiles exactly two traces.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import policy as policy_lib
from repro.core.config import StemConfig
from repro.kernels import paged_attn
from repro.models import registry
from repro.runtime import sampling as sampling_lib
from repro.runtime.chaos import ChaosConfig, ChaosInjector
from repro.runtime.engine import EngineConfig, Request, StemEngine

TINY = ArchConfig(
    name="async-tiny", family="dense", num_layers=2, d_model=32,
    num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64,
    qk_norm=True, dtype="float32",
)
STEM = StemConfig(block_size=8, sink_blocks=1, local_blocks=1,
                  min_budget_blocks=2, stride=4)

TRACE = [  # (prompt_len, max_new_tokens, arrival_step)
    (5, 4, 0),
    (13, 6, 0),
    (8, 3, 1),
    (20, 5, 3),
    (9, 4, 5),
]


@pytest.fixture(scope="module")
def built():
    bundle = registry.build(TINY)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return bundle, params


def _requests():
    rng = np.random.RandomState(7)
    return [Request(uid=uid,
                    prompt=rng.randint(0, TINY.vocab_size,
                                       size=(plen,)).astype(np.int32),
                    max_new_tokens=mnt, arrival_step=arr)
            for uid, (plen, mnt, arr) in enumerate(TRACE)]


def _ecfg(max_slots, **kw):
    per_slot = -(-max(p + n for p, n, _ in TRACE) // STEM.block_size)
    return EngineConfig(max_slots=max_slots,
                        num_pages=1 + max_slots * per_slot,
                        max_pages_per_slot=per_slot, **kw)


# ---------------------------------------------------------------------------
# Bit-identity differentials: async == sync oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy_name", ["stem", "streaming"])
def test_async_matches_sync_bit_identical(built, policy_name):
    """The full staggered/recycling trace through both loops, per policy:
    identical greedy streams, every page returned, in-flight queue empty,
    and — with no EOS configured — zero lookahead discards (max-token
    finishes are deterministic at grant time and never speculate)."""
    bundle, params = built
    pol = policy_lib.get_policy(policy_name).with_updates(
        block_size=8, stride=4, sink_blocks=1, local_blocks=1,
        min_budget_blocks=2, ignore_missing=True)

    sync = StemEngine(bundle, params, pol, _ecfg(2))
    want = {f.uid: f.tokens for f in sync.run(_requests())}

    eng = StemEngine(bundle, params, pol, _ecfg(2, async_depth=1))
    fin = eng.run(_requests())

    assert {f.uid: f.tokens for f in fin} == want, (
        f"policy {policy_name}: async stream diverged from sync oracle")
    for f, (_, mnt, _) in zip(fin, TRACE):
        assert len(f.tokens) == mnt, "speculative token leaked into stream"
    assert not eng._inflight
    assert eng.stats["lookahead_discards"] == 0
    assert eng.allocator.available == eng.ecfg.num_pages - 1
    eng.allocator.check_conservation([])


def test_async_two_traces_and_o1_host_syncs(built):
    """The perf contract: the async sampled step still compiles exactly
    two traces (mixed + decode-only), the per-step transfers are tiny id
    fetches, and *blocking* host syncs collapse from O(decode steps) to
    O(finished requests) — the only non-overlapped reconciles are
    end-of-request drains."""
    bundle, params = built
    sync = StemEngine(bundle, params, STEM, _ecfg(2))
    sync.run(_requests())

    eng = StemEngine(bundle, params, STEM, _ecfg(2, async_depth=1))
    fin = eng.run(_requests())

    assert eng.stats["traces"] == 2
    assert eng.stats["host_syncs"] < sync.stats["host_syncs"]
    assert eng.stats["host_syncs"] <= 2 * len(fin), (
        "async host syncs must be O(finished requests), got "
        f"{eng.stats['host_syncs']} for {len(fin)} requests")
    # every reconcile fetched ids; most overlapped with the next dispatch
    assert eng.stats["id_fetches"] >= eng.stats["host_syncs"]
    # one tiny fetch per lane (decode / chunk) per dispatched step
    assert (eng.stats["step_calls"] <= eng.stats["id_fetches"]
            <= 2 * eng.stats["step_calls"])
    assert eng.metrics["inflight_steps"] == 0


def test_eos_lookahead_discard_free(built):
    """EOS reconciles one step late under async: pick a mid-stream token
    from the sync run as eos_id, rerun both loops — streams stay
    bit-identical (the speculative step past EOS wrote only into the
    request's own reserved pages) and the discard is visible in stats."""
    bundle, params = built
    probe = StemEngine(bundle, params, STEM, _ecfg(2))
    ref = probe.run(_requests())
    # a token strictly before the stream tail => EOS fires mid-decode,
    # while the lookahead step for that slot is already in flight
    eos = ref[1].tokens[2]

    sync = StemEngine(bundle, params, STEM, _ecfg(2, eos_id=eos))
    want = sync.run(_requests())

    eng = StemEngine(bundle, params, STEM, _ecfg(2, async_depth=1,
                                                 eos_id=eos))
    fin = eng.run(_requests())

    assert {f.uid: f.tokens for f in fin} == {f.uid: f.tokens for f in want}
    assert any(f.tokens and f.tokens[-1] == eos
               and len(f.tokens) < mnt
               for f, (_, mnt, _) in zip(fin, TRACE)), (
        "scenario no longer exercises early EOS termination")
    assert eng.stats["lookahead_discards"] >= 1
    # at most one speculative step per early-EOS finish (a slot may also
    # reconcile EOS with nothing in flight — drain steps, grant races)
    assert eng.stats["lookahead_discards"] <= sum(
        1 for f, (_, mnt, _) in zip(fin, TRACE)
        if f.tokens[-1] == eos and len(f.tokens) < mnt)
    eng.allocator.check_conservation([])


def test_async_under_chaos_bit_identical(built):
    """Transient faults (alloc denial + one step failure, both within the
    retry bounds) with the lookahead pipeline live: outputs must match the
    chaos-free sync run — the drain-before-mutate rule keeps in-flight
    speculative work consistent through recovery paths."""
    bundle, params = built
    rng = np.random.RandomState(5)
    reqs = [Request(uid=i,
                    prompt=rng.randint(0, TINY.vocab_size,
                                       size=(10 + 3 * i,)).astype(np.int32),
                    max_new_tokens=5)
            for i in range(4)]
    reqs.append(Request(uid=9,
                        prompt=rng.randint(0, TINY.vocab_size,
                                           size=(9,)).astype(np.int32),
                        max_new_tokens=3, priority=2, arrival_step=5))
    per_slot = -(-(20 + 8) // STEM.block_size)
    ecfg = EngineConfig(max_slots=2, num_pages=1 + 2 * per_slot,
                        max_pages_per_slot=per_slot)

    clean = StemEngine(bundle, params, STEM, ecfg)
    want = {f.uid: f.tokens for f in
            clean.run([dataclasses.replace(r) for r in reqs])}

    chaos = ChaosInjector(ChaosConfig(deny_alloc_steps=(0,), fail_steps=(3,)))
    eng = StemEngine(bundle, params, STEM,
                     dataclasses.replace(ecfg, async_depth=1), chaos=chaos)
    fin = eng.run(reqs)

    assert chaos.counts["alloc_denied"] == 1
    assert chaos.counts["step_failed"] == 1
    assert eng.stats["aborts"] == 0
    assert len(fin) == len(reqs) and all(f.error is None for f in fin)
    assert {f.uid: f.tokens for f in fin} == want, "chaos changed outputs"
    eng.allocator.check_conservation([])


def test_async_preempt_restore_cycle_bit_identical(built):
    """Priority preemption mid-pipeline: the in-flight step drains before
    the victim's pages move, the HP request jumps the queue, and both
    streams match the sync run of the same scenario bit-for-bit."""
    bundle, params = built
    rng = np.random.RandomState(23)
    mk = lambda uid, plen, mnt, **kw: Request(
        uid=uid,
        prompt=rng.randint(0, TINY.vocab_size, size=(plen,)).astype(np.int32),
        max_new_tokens=mnt, **kw)
    lp = mk(0, 20, 8, priority=0)
    hp = mk(1, 13, 4, priority=1, arrival_step=4)
    per_slot = -(-(20 + 8) // STEM.block_size)
    ecfg = EngineConfig(max_slots=1, num_pages=1 + per_slot,
                        max_pages_per_slot=per_slot)

    sync = StemEngine(bundle, params, STEM, ecfg)
    want = sync.run([dataclasses.replace(lp), dataclasses.replace(hp)])
    assert sync.stats["preemptions"] == 1

    eng = StemEngine(bundle, params, STEM,
                     dataclasses.replace(ecfg, async_depth=1))
    fin = eng.run([lp, hp])
    assert eng.stats["preemptions"] == 1 and eng.stats["restores"] == 1
    assert fin[0].tokens == want[0].tokens
    assert fin[1].tokens == want[1].tokens
    assert fin[1].finished_step < fin[0].finished_step
    assert eng.stats["restore_bytes"] > 0
    eng.allocator.check_conservation([])


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="async_depth"):
        EngineConfig(async_depth=2)
    with pytest.raises(ValueError, match="monolithic"):
        EngineConfig(async_depth=1, monolithic_prefill=True)
    with pytest.raises(ValueError, match="unknown sampler"):
        EngineConfig(sampler="metropolis")


# ---------------------------------------------------------------------------
# Sampler ops + registry (runtime/sampling.py)
# ---------------------------------------------------------------------------

def test_greedy_sampler_matches_host_argmax_with_ties():
    """On-device greedy must reproduce ``np.argmax`` exactly — including
    first-maximal-index tie-breaking, the case that would silently break
    the async==sync differential."""
    s = sampling_lib.get_sampler("greedy")
    assert s.deterministic
    rng = np.random.RandomState(0)
    logits = rng.randn(4, 16).astype(np.float32)
    logits[1, 3] = logits[1, 11] = logits[1].max() + 1.0   # exact tie
    logits[2, :] = 0.0                                     # all-way tie
    got = np.asarray(s(jnp.asarray(logits)))
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, np.argmax(logits, axis=-1))


def test_sampler_registry():
    with pytest.raises(ValueError, match="unknown sampler"):
        sampling_lib.get_sampler("nope")
    with pytest.raises(ValueError, match="already registered"):
        sampling_lib.register_sampler("greedy", sampling_lib.GreedySampler)
    sampling_lib.register_sampler("test-custom", sampling_lib.GreedySampler)
    try:
        assert isinstance(sampling_lib.get_sampler("test-custom"),
                          sampling_lib.GreedySampler)
    finally:
        del sampling_lib._SAMPLERS["test-custom"]


def test_temperature_sampler_op_level():
    with pytest.raises(ValueError, match="temperature"):
        sampling_lib.TemperatureSampler(temperature=0.0)
    s = sampling_lib.TemperatureSampler(temperature=0.7)
    assert not s.deterministic
    logits = jnp.asarray(np.random.RandomState(1).randn(3, 8), jnp.float32)
    with pytest.raises(ValueError, match="PRNG key"):
        s(logits)
    ids = np.asarray(s(logits, key=jax.random.PRNGKey(0)))
    assert ids.shape == (3,) and ids.dtype == np.int32
    assert ((ids >= 0) & (ids < 8)).all()
    # temperature -> 0 limit concentrates on the argmax
    cold = sampling_lib.TemperatureSampler(temperature=1e-6)
    np.testing.assert_array_equal(
        np.asarray(cold(logits, key=jax.random.PRNGKey(0))),
        np.argmax(np.asarray(logits), axis=-1))


# ---------------------------------------------------------------------------
# Pallas fallback observability (kernels/paged_attn.py + engine.stats)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _OpaqueZeroMetric:
    """StreamingMetric's math under a class the fused kernels do not
    classify — forces the silent XLA-oracle fallback at both call sites."""

    def prefill_scores(self, q, k, v, *, block_size):
        return policy_lib.StreamingMetric().prefill_scores(
            q, k, v, block_size=block_size)

    def decode_scores(self, q, k_groups, v_mag):
        return policy_lib.StreamingMetric().decode_scores(q, k_groups, v_mag)

    def chunk_scores(self, q, k_groups, v_mag, *, block_size):
        return policy_lib.StreamingMetric().chunk_scores(
            q, k_groups, v_mag, block_size=block_size)


def test_pallas_fallback_counted_and_warned_once(built):
    """A pallas-executor engine whose metric the fused kernels cannot
    serve: the fallback is no longer silent — it warns once per site,
    counts per trace in ``FALLBACKS``, and surfaces in
    ``engine.stats['pallas_fallbacks']`` (surviving reset_metrics)."""
    bundle, params = built
    pol = policy_lib.get_policy("streaming").with_updates(
        block_size=8, stride=4, sink_blocks=1, local_blocks=1,
        min_budget_blocks=2, ignore_missing=True)
    pol = dataclasses.replace(pol, metric=_OpaqueZeroMetric(),
                              name="opaque-zero")
    assert paged_attn._metric_kind(pol.metric) is None

    saved_warned = set(paged_attn._WARNED)
    paged_attn._WARNED.clear()
    base = dict(paged_attn.FALLBACKS)
    try:
        eng = StemEngine(bundle, params, pol,
                         _ecfg(2, executor="pallas", async_depth=1))
        with pytest.warns(RuntimeWarning, match="falling back"):
            fin = eng.run(_requests()[:2])
        assert len(fin) == 2 and all(f.error is None for f in fin)

        delta = {k: paged_attn.FALLBACKS.get(k, 0) - base.get(k, 0)
                 for k in paged_attn.FALLBACKS}
        assert delta.get("decode", 0) >= 1
        assert delta.get("chunk", 0) >= 1
        total = sum(v for v in delta.values() if v > 0)
        assert eng.stats["pallas_fallbacks"] == total

        # warn-once: a second run through the SAME sites stays quiet
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            eng2 = StemEngine(bundle, params, pol,
                              _ecfg(2, executor="pallas"))
            eng2.run(_requests()[:1])
        # per-engine baseline: eng2 counts only its own traces
        assert 0 < eng2.stats["pallas_fallbacks"] <= total

        eng.reset_metrics()
        assert eng.stats["pallas_fallbacks"] == total, (
            "fallback count must survive reset_metrics (it is a property "
            "of the compiled traces, like stats['traces'])")
    finally:
        paged_attn._WARNED.clear()
        paged_attn._WARNED.update(saved_warned)


def test_xla_engine_reports_no_fallbacks(built):
    """The default XLA executor takes no pallas path at all — the counter
    must stay 0 even if other tests bumped the module-level dict."""
    bundle, params = built
    eng = StemEngine(bundle, params, STEM, _ecfg(2, async_depth=1))
    eng.run(_requests()[:2])
    assert eng.stats["pallas_fallbacks"] == 0
