"""Prefix caching with copy-on-write pages (runtime/paged.py + engine.py).

The load-bearing property is the **differential oracle**: prefix caching is
a pure memory optimisation, so every request's greedy token stream must be
bitwise identical with the feature on and off — across multi-tenant
sharing, copy-on-write of a fully-matched page, mid-decode preemption of a
slot that holds shared (pinned) pages, and slot recycling into the ref-0
cached set.  Plus allocator-level invariants: the free / cached / allocated
partition conserves pages under any interleaving of alloc, share, register,
CoW, evict/restore, and free (property test), the LRU cached set is
reclaimed oldest-first and its index entries invalidated, and the
suffix-prefill entry (``prefill_kv_pages_suffix``) reproduces one-shot
prefill through shared read-only prefix pages.
"""
import dataclasses
import random

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to fixed-seed parametrized sampling
    from _hypothesis_compat import given, settings, st

from repro.configs.base import ArchConfig
from repro.core.config import StemConfig
from repro.models import registry, transformer
from repro.runtime import paged as paged_lib
from repro.runtime.engine import EngineConfig, Request, StemEngine
from repro.runtime.paged import PageAllocator, prefix_page_keys

BS = 8

TINY = ArchConfig(
    name="prefix-tiny", family="dense", num_layers=2, d_model=32,
    num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64,
    qk_norm=True, dtype="float32",
)
STEM = StemConfig(block_size=BS, sink_blocks=1, local_blocks=1,
                  min_budget_blocks=2, stride=4)


@pytest.fixture(scope="module")
def built():
    bundle = registry.build(TINY)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return bundle, params


def _ecfg(max_slots, per_slot, num_pages=None, **kw):
    return EngineConfig(max_slots=max_slots,
                        num_pages=num_pages or 1 + max_slots * per_slot,
                        max_pages_per_slot=per_slot, budget_frac=1.0, **kw)


def _run(bundle, params, ecfg, reqs, prefix_cache):
    engine = StemEngine(bundle, params, STEM,
                        dataclasses.replace(ecfg, prefix_cache=prefix_cache))
    finished = engine.run([dataclasses.replace(r) for r in reqs])
    return engine, {f.uid: f.tokens for f in finished}


# ---------------------------------------------------------------------------
# Differential oracle: on == off, bit for bit
# ---------------------------------------------------------------------------

def test_shared_system_prompt_differential(built):
    """Four tenants share one 2-page system prompt with distinct suffixes,
    staggered so later tenants arrive after the first prefill registered its
    pages.  Token streams must be bitwise identical to the prefix-cache-off
    run, sharing must actually have happened, and every page must come home
    at drain (shared refs decremented, not double-freed)."""
    bundle, params = built
    rng = np.random.RandomState(42)
    system = rng.randint(0, TINY.vocab_size, size=(2 * BS,)).astype(np.int32)
    reqs = []
    for uid, (suf, mnt, arr) in enumerate([(5, 4, 0), (7, 5, 0),
                                           (3, 4, 6), (9, 3, 8)]):
        suffix = rng.randint(0, TINY.vocab_size, size=(suf,)).astype(np.int32)
        reqs.append(Request(uid=uid, prompt=np.concatenate([system, suffix]),
                            max_new_tokens=mnt, arrival_step=arr))
    per_slot = -(-max(len(r.prompt) + r.max_new_tokens for r in reqs) // BS)
    ecfg = _ecfg(2, per_slot)

    e_off, t_off = _run(bundle, params, ecfg, reqs, False)
    e_on, t_on = _run(bundle, params, ecfg, reqs, True)

    assert t_on == t_off, "prefix caching changed a token stream"
    # 4 tenants / 2 slots with staggered arrivals: at least the two late
    # arrivals (and the recycled-slot tenants) hit the 2-page prefix.
    assert e_on.stats["prefix_hits"] >= 2
    assert e_on.stats["prefix_pages_shared"] >= 4
    assert e_on.allocator.shares >= e_on.stats["prefix_pages_shared"]
    # sharing is a real allocation saving
    assert e_on.allocator.total_alloced < e_off.allocator.total_alloced
    # the off arm never touches the index
    assert e_off.stats["prefix_hits"] == 0 and e_off.allocator.shares == 0
    # drain: no slot held, no page orphaned; registered pages may park in
    # the ref-0 cached set but stay accounted for.
    for e in (e_on, e_off):
        assert all(s is None for s in e.slots)
        e.allocator.check_conservation([])
        assert (e.allocator.available == e.ecfg.num_pages - 1)


def test_cow_on_fully_matched_prompt(built):
    """An exact-page-multiple prompt that fully matches the index still
    replays its final page (the engine needs its last-token logits), so
    admission maps that page copy-on-write: fresh page, contents copied,
    shared ref dropped.  Tokens must match the off arm bitwise."""
    bundle, params = built
    rng = np.random.RandomState(5)
    prompt = rng.randint(0, TINY.vocab_size, size=(2 * BS,)).astype(np.int32)
    reqs = [Request(uid=0, prompt=prompt, max_new_tokens=4),
            Request(uid=1, prompt=prompt, max_new_tokens=4)]
    per_slot = -(-(len(prompt) + 4) // BS)
    ecfg = _ecfg(1, per_slot, num_pages=1 + 2 * per_slot)

    e_off, t_off = _run(bundle, params, ecfg, reqs, False)
    e_on, t_on = _run(bundle, params, ecfg, reqs, True)

    assert t_on == t_off
    assert t_on[0] == t_on[1], "identical prompts, identical greedy streams"
    assert e_on.stats["prefix_cows"] == 1
    assert e_on.stats["prefix_hits"] == 1
    assert e_on.stats["prefix_pages_shared"] == 1   # page 0 shared, page 1 CoW
    assert e_on.allocator.cows == 1
    e_on.allocator.check_conservation([])


def test_preempt_slot_with_shared_pages(built):
    """Mid-decode preemption of a slot whose leading pages are SHARED: only
    the private pages may be offloaded/evicted; the shared pages stay
    pinned on device and are re-attached at restore.  The stream must stay
    bitwise identical to (a) the off arm under the same preemption and
    (b) an unpreempted run."""
    bundle, params = built
    rng = np.random.RandomState(5)
    system = rng.randint(0, TINY.vocab_size, size=(2 * BS,)).astype(np.int32)
    mk = lambda uid, suf, mnt, arr: Request(
        uid=uid,
        prompt=np.concatenate(
            [system, rng.randint(0, TINY.vocab_size, size=(suf,)).astype(np.int32)]),
        max_new_tokens=mnt, arrival_step=arr)
    reqs = [mk(0, 5, 10, 0), mk(1, 7, 10, 4)]
    per_slot = -(-max(len(r.prompt) + r.max_new_tokens for r in reqs) // BS)
    ecfg = _ecfg(2, per_slot)

    def run(prefix_cache, do_preempt):
        e = StemEngine(bundle, params, STEM,
                       dataclasses.replace(ecfg, prefix_cache=prefix_cache))
        for r in reqs:
            e.submit(dataclasses.replace(r))
        steps = preempted = 0
        while e.pending:
            e.step()
            steps += 1
            if do_preempt and not preempted and steps >= 8:
                for s, st_ in enumerate(e.slots):
                    if st_ is not None and st_.req.uid == 1 \
                            and st_.phase == "decode":
                        if prefix_cache:
                            assert e.slot_nshared[s] == 2, \
                                "uid 1 should be sharing the system pages"
                        e.preempt(s)
                        preempted = 1
                        break
            assert steps < 500, "engine failed to drain"
        if do_preempt:
            assert preempted, "never caught uid 1 mid-decode"
        return e, {f.uid: f.tokens for f in e.finished}

    e_on, t_on = run(True, True)
    e_off, t_off = run(False, True)
    _, t_ref = run(False, False)
    assert t_on == t_off == t_ref, \
        "preempting a sharing slot changed its token stream"
    assert e_on.stats["preemptions"] >= 1
    assert e_on.stats["prefix_hits"] == 1
    e_on.allocator.check_conservation([])
    assert len(e_on.host_store) == 0
    assert all(s is None for s in e_on.slots)


def test_recycled_registration_enables_sequential_sharing(built):
    """Sequential tenants through ONE slot: the first tenant's registered
    prompt pages park in the ref-0 cached set at recycle and are revived —
    not re-prefilled — by the second tenant.  Guards the cached-set
    half of the partition (a plain free would sever sharing across
    recycles)."""
    bundle, params = built
    rng = np.random.RandomState(11)
    system = rng.randint(0, TINY.vocab_size, size=(2 * BS,)).astype(np.int32)
    mk = lambda uid, suf: Request(
        uid=uid,
        prompt=np.concatenate(
            [system, rng.randint(0, TINY.vocab_size, size=(suf,)).astype(np.int32)]),
        max_new_tokens=3)
    reqs = [mk(0, 5), mk(1, 6), mk(2, 4)]
    per_slot = -(-max(len(r.prompt) + r.max_new_tokens for r in reqs) // BS)
    # ONE slot: tenants strictly sequential, sharing must survive recycling
    ecfg = _ecfg(1, per_slot, num_pages=1 + 2 * per_slot)

    e_off, t_off = _run(bundle, params, ecfg, reqs, False)
    e_on, t_on = _run(bundle, params, ecfg, reqs, True)
    assert t_on == t_off
    assert e_on.stats["prefix_hits"] == 2          # tenants 1 and 2
    assert e_on.allocator.cache_reclaims == 0      # pool big enough: revived,
    assert e_on.stats["prefix_pages_shared"] == 4  # never cannibalised
    e_on.allocator.check_conservation([])


# ---------------------------------------------------------------------------
# Suffix prefill parity: shared read-only prefix pages
# ---------------------------------------------------------------------------

def test_suffix_prefill_matches_full_prefill(built):
    """``prefill_kv_pages_suffix`` over already-written prefix pages must
    reproduce one-shot ``prefill_kv_pages``: same next-token logits, same
    page contents and summaries — and it must not write the prefix pages it
    reads through (they may be shared with other slots)."""
    bundle, params = built
    rng = np.random.RandomState(3)
    plen = 43                                     # partial final page
    prompt = rng.randint(0, TINY.vocab_size, size=(plen,)).astype(np.int32)
    npages_prompt = -(-plen // BS)
    n_reserved = npages_prompt + 1
    page_row = jnp.arange(1, n_reserved + 1, dtype=jnp.int32)
    toks = np.zeros((1, npages_prompt * BS), np.int32)
    toks[0, :plen] = prompt
    tl = jnp.asarray(plen, jnp.int32)

    pools = transformer.init_page_pools(TINY, 1 + n_reserved + 1, STEM)
    ref_logits, ref_pools = transformer.prefill_kv_pages(
        params, jnp.asarray(toks), tl, pools, page_row, TINY, STEM)

    start = 2 * BS                                # 2 matched prefix pages
    # Poison the private (suffix + spill) pages of the full-prefill result,
    # then reset them — exactly the engine's admission path, which must not
    # touch the shared prefix pages.
    private = page_row[start // BS:]
    poisoned = jax.tree.map(
        lambda p: paged_lib.PagePool(k=p.k + 7.0, v=p.v - 7.0,
                                     kg=p.kg + 7.0, vm=p.vm + 7.0)
        if isinstance(p, paged_lib.PagePool) else p,
        ref_pools, is_leaf=lambda x: isinstance(x, paged_lib.PagePool))
    # restore the shared prefix pages from the reference (they are mapped
    # read-only; the suffix pass may not rewrite them)
    shared = page_row[:start // BS]
    merged = jax.tree.map(
        lambda pz, rf: paged_lib.PagePool(
            k=pz.k.at[:, :, shared].set(rf.k[:, :, shared]),
            v=pz.v.at[:, :, shared].set(rf.v[:, :, shared]),
            kg=pz.kg.at[:, :, shared].set(rf.kg[:, :, shared]),
            vm=pz.vm.at[:, :, shared].set(rf.vm[:, :, shared])),
        poisoned, ref_pools,
        is_leaf=lambda x: isinstance(x, paged_lib.PagePool))
    merged = paged_lib.reset_pools_stacked(merged, private)

    got_logits, got_pools = transformer.prefill_kv_pages_suffix(
        params, jnp.asarray(toks), tl, start, merged, page_row, TINY, STEM)

    np.testing.assert_allclose(np.asarray(got_logits), np.asarray(ref_logits),
                               atol=1e-4, rtol=1e-4)
    for si in range(len(ref_pools)):
        for sub in ref_pools[si]:
            rp, gp = ref_pools[si][sub], got_pools[si][sub]
            for name in ("k", "v", "kg", "vm"):
                r = np.asarray(getattr(rp, name))[:, :, page_row]
                g = np.asarray(getattr(gp, name))[:, :, page_row]
                np.testing.assert_allclose(g, r, atol=1e-5, rtol=1e-5,
                                           err_msg=f"{sub}.{name}")


def test_suffix_prefill_rejects_misaligned_start(built):
    bundle, params = built
    pools = transformer.init_page_pools(TINY, 4, STEM)
    row = jnp.arange(1, 3, dtype=jnp.int32)
    toks = jnp.zeros((1, 2 * BS), jnp.int32)
    with pytest.raises(ValueError, match="block"):
        transformer.prefill_kv_pages_suffix(
            params, toks, jnp.asarray(9, jnp.int32), 3, pools, row, TINY, STEM)


# ---------------------------------------------------------------------------
# Allocator invariants
# ---------------------------------------------------------------------------

def test_prefix_page_keys_chain():
    """Chained hash: a page's key commits to the ENTIRE prefix (tokens and
    per-page budget rows), never to the page alone — layer-ℓ K/V depend on
    everything before them."""
    t = list(range(40))
    k1 = prefix_page_keys(t, [3, 3, 3, 3, 3], BS)
    assert len(k1) == 5                       # whole pages only
    assert prefix_page_keys(t[:39], [3] * 5, BS) == k1[:4]   # tail page unkeyed
    # same page content, different predecessor -> different key
    t2 = [99] + t[1:]
    k2 = prefix_page_keys(t2, [3, 3, 3, 3, 3], BS)
    assert k1[0] != k2[0] and k1[3] != k2[3]
    # same tokens, different budget row (padded-length dependence) -> differ
    k3 = prefix_page_keys(t, [3, 3, 3, 3, 4], BS)
    assert k3[:4] == k1[:4] and k3[4] != k1[4]


def test_cached_lru_reclaim_invalidates_index():
    """Filling the pool reclaims the ref-0 cached set oldest-first; a
    reclaimed page's index entry must vanish (probe misses, never a stale
    hit on a recycled page)."""
    a = PageAllocator(5)                           # pages 1..4
    pages = a.alloc(4)
    keys = prefix_page_keys(list(range(4 * BS)), [1, 1, 1, 1], BS)
    for p, k in zip(pages, keys):
        a.register(p, k)
    a.free(pages[:2])                              # cached, LRU order p0, p1
    a.free(pages[2:])                              # then p2, p3
    assert a.available == 4 and a.cached_pages == 4
    got = a.alloc(3)                               # reclaims 3 oldest
    assert sorted(got) == sorted(pages[:3])
    assert a.cache_reclaims == 3
    for k in keys[:3]:
        assert a.probe(k) is None, "stale index entry after reclaim"
    assert a.probe(keys[3]) == pages[3]
    a.check_conservation(got)
    # revive the survivor, confirm contents-address still routes to it
    p = a.share(a.probe(keys[3]))
    assert p == pages[3] and a.refcount(p) == 1
    a.check_conservation(got + [p])


def test_hit_rate_eviction_keeps_hot_pages():
    """evict_policy='hit-rate': reclaim cannibalizes the cached page with
    the fewest prefix hits since registration, LRU among ties — a hot
    system-prompt page survives pressure that LRU would evict it under."""
    a = PageAllocator(5, evict_policy="hit-rate")
    pages = a.alloc(4)
    keys = prefix_page_keys(list(range(4 * BS)), [1, 1, 1, 1], BS)
    for p, k in zip(pages, keys):
        a.register(p, k)
    a.free(pages)                   # all cached; LRU order p0, p1, p2, p3
    # Make p0 the HOTTEST page (2 hits vs 1 each) that is also the OLDEST
    # cached page (every later share/free re-parks the others after it) —
    # exactly the page LRU reclaims first and hit-rate must keep.
    a.free([a.share(pages[0])])
    a.free([a.share(pages[0])])
    for p in pages[1:]:
        a.free([a.share(p)])        # LRU order is now p0, p1, p2, p3 again
    got = a.alloc(3)                # reclaims the three 1-hit pages
    assert sorted(got) == sorted(pages[1:])
    assert a.probe(keys[0]) == pages[0], "hot page evicted under hit-rate"
    for k in keys[1:]:
        assert a.probe(k) is None
    a.check_conservation(got)
    # hit counts die with the registration: a reclaimed page re-registered
    # later starts cold.
    a.register(got[0], "fresh")
    a.free([got[0]])
    assert a._hits.get(got[0], 0) == 0


def test_eviction_policy_default_and_validation():
    """LRU stays the default (bit-for-bit the pre-flag behavior) and the
    config rejects unknown policies."""
    assert PageAllocator(4).evict_policy == "lru"
    with pytest.raises(ValueError):
        PageAllocator(4, evict_policy="belady")
    # Same pressure as the hit-rate test under the default: the hot-but-old
    # page is reclaimed first — the behavior the flag exists to change.
    a = PageAllocator(5)
    pages = a.alloc(4)
    keys = prefix_page_keys(list(range(4 * BS)), [1, 1, 1, 1], BS)
    for p, k in zip(pages, keys):
        a.register(p, k)
    a.free(pages)
    a.free([a.share(pages[0])])
    a.free([a.share(pages[0])])
    for p in pages[1:]:
        a.free([a.share(p)])        # p0 hottest AND oldest, as above
    assert pages[0] in a.alloc(1), "LRU default no longer oldest-first"


def test_register_idempotent_first_writer_wins():
    a = PageAllocator(4)
    p, q = a.alloc(2)
    a.register(p, "k1")
    a.register(p, "k1")                            # idempotent
    a.register(q, "k1")                            # second writer: no-op
    assert a.probe("k1") == p
    a.register(p, "k2")                            # re-key allowed
    assert a.probe("k1") is None and a.probe("k2") == p
    with pytest.raises(ValueError):
        a.register(99, "k3")
    a.check_conservation([p, q])


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), num_pages=st.integers(4, 12),
       n_ops=st.integers(10, 60))
def test_refcount_conservation_property(seed, num_pages, n_ops):
    """Random interleavings of alloc / register / share / CoW /
    evict+restore / free against a mirror of every outstanding reference:
    after every op the allocator's free + cached + allocated sets must
    partition the pool exactly, with refcounts equal to the mirror's
    multiset.  This is the invariant the engine's admission, preemption,
    and recycling paths all lean on."""
    rng = random.Random(seed)
    a = PageAllocator(num_pages)
    held = []            # one entry per outstanding reference (multiset)
    registered = []      # (page, key) we may probe/share
    evicted = []         # pinned refs surviving a simulated offload
    serial = 0
    for _ in range(n_ops):
        op = rng.choice(("alloc", "free", "register", "share", "cow",
                         "evict", "restore"))
        if op == "alloc":
            n = rng.randint(1, max(1, a.available))
            got = a.alloc(n)
            if got is not None:
                held.extend(got)
        elif op == "free" and held:
            p = rng.choice(held)
            a.free([p])
            held.remove(p)
        elif op == "register" and held:
            p = rng.choice(held)
            serial += 1
            key = f"key-{seed}-{serial}"
            a.register(p, key)
            registered[:] = [(q, k) for q, k in registered if q != p]
            registered.append((p, key))
        elif op == "share" and registered:
            p, key = rng.choice(registered)
            hit = a.probe(key)
            if hit is not None:
                assert hit == p
                a.share(hit)
                held.append(hit)
        elif op == "cow" and held:
            # all-or-nothing: on None the caller's reference is untouched
            p = rng.choice(held)
            fresh = a.cow(p)
            if fresh is not None:
                held.remove(p)
                held.append(fresh)
        elif op == "evict" and held:
            # simulate preemption: a private page is freed (its contents
            # live on in the host snapshot); restore re-allocates one
            p = rng.choice(held)
            a.evict([p])
            held.remove(p)
            evicted.append(None)
        elif op == "restore" and evicted:
            got = a.restore(1)
            evicted.pop()
            if got is not None:
                held.extend(got)
        # any alloc/cow/restore above may have reclaimed a cached page —
        # its index entry must be gone; drop stale mirror rows
        registered[:] = [(q, k) for q, k in registered if a.probe(k) == q]
        a.check_conservation(held)
    # drain everything and confirm the pool is whole again
    for p in list(held):
        a.free([p])
    a.check_conservation([])
    assert a.available == num_pages - 1
