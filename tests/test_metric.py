"""Tests for the Output-Aware Metric and anti-diagonal downsampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metric as metric_lib
from repro.core.config import StemConfig


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def test_antidiag_separability_exact():
    """Pooled routing == mean of the strided anti-diagonal logits.

    The separable group-mean formulation must equal the direct
    O(B^2) computation of mean_{(a+b) % s == 0} q_a . k_b / sqrt(d).
    """
    B, H, N, D, bs, s = 1, 2, 256, 32, 64, 8
    q = _rand(0, (B, H, N, D))
    k = _rand(1, (B, H, N, D))
    cfg = StemConfig(block_size=bs, stride=s)
    got = metric_lib.routing_scores(q, k, cfg)  # (B,H,nb,nb)

    nb = N // bs
    qb = np.asarray(q).reshape(B, H, nb, bs, D)
    kb = np.asarray(k).reshape(B, H, nb, bs, D)
    want = np.zeros((B, H, nb, nb))
    a = np.arange(bs)[:, None]
    b = np.arange(bs)[None, :]
    sel = ((a + b) % s) == 0
    for i in range(nb):
        for j in range(nb):
            scores = np.einsum("bhad,bhcd->bhac", qb[:, :, i], kb[:, :, j]) / np.sqrt(D)
            want[:, :, i, j] = scores[:, :, sel].mean(axis=-1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-5)


def test_mean_pooling_matches_explicit():
    B, H, N, D, bs = 2, 2, 128, 16, 32
    q = _rand(2, (B, H, N, D))
    k = _rand(3, (B, H, N, D))
    cfg = StemConfig(block_size=bs, stride=8, pooling="mean")
    got = metric_lib.routing_scores(q, k, cfg)
    qm = np.asarray(q).reshape(B, H, N // bs, bs, D).mean(axis=3)
    km = np.asarray(k).reshape(B, H, N // bs, bs, D).mean(axis=3)
    want = np.einsum("bhid,bhjd->bhij", qm, km) / np.sqrt(D)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


def test_value_magnitude_blockmax():
    B, H, N, D, bs = 1, 1, 64, 8, 16
    v = _rand(4, (B, H, N, D))
    got = metric_lib.value_block_magnitude(v, bs)
    norms = np.linalg.norm(np.asarray(v), axis=-1)
    want = np.log(norms).reshape(B, H, N // bs, bs).max(axis=-1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


def test_oam_prefers_high_magnitude_values():
    """Two key blocks with identical routing scores: the one holding a
    high-magnitude value must score strictly higher under OAM (Eq. 7), and
    identically under SAM."""
    B, H, N, D, bs = 1, 1, 128, 16, 32
    q = _rand(5, (B, H, N, D))
    k = jnp.tile(_rand(6, (B, H, bs, D)), (1, 1, N // bs, 1))  # identical K blocks
    v = jnp.ones((B, H, N, D)) * 0.01
    v = v.at[:, :, bs : 2 * bs].set(100.0)  # block 1 = high energy
    oam = metric_lib.oam_metric(q, k, v, StemConfig(block_size=bs, stride=8))
    sam = metric_lib.oam_metric(q, k, v, StemConfig(block_size=bs, stride=8, metric="sam"))
    # routing identical across key blocks:
    np.testing.assert_allclose(np.asarray(sam[..., 0]), np.asarray(sam[..., 1]), rtol=1e-5)
    assert (np.asarray(oam[..., 1]) > np.asarray(oam[..., 0])).all()


def test_oam_magnitude_clamped_at_zero():
    """max(0, log||V||): tiny-norm values must not be *penalized* below
    pure routing (the clamp in Eq. 7)."""
    B, H, N, D, bs = 1, 1, 64, 16, 32
    q, k = _rand(7, (B, H, N, D)), _rand(8, (B, H, N, D))
    v = jnp.full((B, H, N, D), 1e-8)
    cfg = StemConfig(block_size=bs, stride=8)
    oam = metric_lib.oam_metric(q, k, v, cfg)
    sam = metric_lib.oam_metric(q, k, v, StemConfig(block_size=bs, stride=8, metric="sam"))
    np.testing.assert_allclose(np.asarray(oam), np.asarray(sam), rtol=1e-5, atol=1e-6)


def test_gqa_broadcast_and_group_reduce():
    B, Hq, Hk, N, D, bs = 2, 8, 2, 128, 16, 32
    q = _rand(9, (B, Hq, N, D))
    k = _rand(10, (B, Hk, N, D))
    v = _rand(11, (B, Hk, N, D))
    cfg = StemConfig(block_size=bs, stride=8)
    m = metric_lib.oam_metric(q, k, v, cfg)
    assert m.shape == (B, Hq, N // bs, N // bs)
    red = metric_lib.group_reduce_metric(m, Hq // Hk, "mean")
    g = np.asarray(red).reshape(B, Hk, Hq // Hk, N // bs, N // bs)
    for gi in range(1, Hq // Hk):
        np.testing.assert_allclose(g[:, :, 0], g[:, :, gi])


def test_metric_matches_true_mean_logit_scale():
    """Pooled routing should approximate the mean block logit: unbiased for
    mean pooling, and close for antidiag (it samples 1/s of the pairs)."""
    B, H, N, D, bs = 1, 1, 256, 64, 64
    q, k = _rand(12, (B, H, N, D)), _rand(13, (B, H, N, D))
    true = np.einsum("bhqd,bhkd->bhqk", np.asarray(q), np.asarray(k)) / np.sqrt(D)
    nb = N // bs
    true_block = true.reshape(B, H, nb, bs, nb, bs).mean(axis=(3, 5))
    got = metric_lib.routing_scores(q, k, StemConfig(block_size=bs, stride=8, pooling="mean"))
    np.testing.assert_allclose(np.asarray(got), true_block, rtol=1e-4, atol=1e-5)
