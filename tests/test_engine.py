"""Continuous-batching engine integration tests (runtime/engine.py).

The load-bearing property is **batch-invariance**: a request's greedy token
stream must be bitwise independent of which slot it lands in, who its
co-tenants are, and when it arrives — the engine trace with mixed prompt
lengths and staggered arrivals must reproduce each request decoded alone in
a fresh single-slot engine.  Under the unified step this also covers
chunked prefill: chunk boundaries depend only on chunk_size, never on
co-tenants or the token budget's interleaving.  Plus lifecycle invariants:
staggered requests are never admitted early, freed slots are reused, every
page returns to the allocator at drain, and the unified step compiles a
fixed number of traces regardless of prompt lengths.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import policy as policy_lib
from repro.core.config import StemConfig
from repro.core.decode import summarize_cache
from repro.launch import steps as steps_lib
from repro.models import registry, transformer
from repro.runtime.engine import EngineConfig, Request, StemEngine
from repro.runtime.paged import (PageAllocator, append_token, init_pool,
                                 write_prefill_pages)

TINY = ArchConfig(
    name="engine-tiny", family="dense", num_layers=2, d_model=32,
    num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64,
    qk_norm=True, dtype="float32",
)
STEM = StemConfig(block_size=8, sink_blocks=1, local_blocks=1,
                  min_budget_blocks=2, stride=4)

# Mixed lengths (none a multiple of block_size=8 except 8 itself), mixed
# decode lengths, staggered arrivals — more requests than slots so the
# engine must recycle.
TRACE = [  # (prompt_len, max_new_tokens, arrival_step)
    (5, 4, 0),
    (13, 6, 0),
    (8, 3, 1),
    (20, 5, 3),
    (9, 4, 5),
]


@pytest.fixture(scope="module")
def built():
    bundle = registry.build(TINY)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return bundle, params


def _requests():
    rng = np.random.RandomState(7)
    reqs = []
    for uid, (plen, mnt, arr) in enumerate(TRACE):
        reqs.append(Request(
            uid=uid,
            prompt=rng.randint(0, TINY.vocab_size, size=(plen,)).astype(np.int32),
            max_new_tokens=mnt,
            arrival_step=arr,
        ))
    return reqs


def _ecfg(max_slots, budget_frac):
    # Enough pages for max_slots of the largest request, plus the trash page.
    per_slot = -(-max((p + n for p, n, _ in TRACE)) // STEM.block_size)
    return EngineConfig(max_slots=max_slots, num_pages=1 + max_slots * per_slot,
                        max_pages_per_slot=per_slot, budget_frac=budget_frac)


@pytest.mark.parametrize("budget_frac", [1.0, 0.5])
def test_batch_invariance_and_recycling(built, budget_frac):
    bundle, params = built
    engine = StemEngine(bundle, params, STEM, _ecfg(2, budget_frac))
    finished = engine.run(_requests())

    assert [f.uid for f in finished] == list(range(len(TRACE)))
    for f, (plen, mnt, arr) in zip(finished, TRACE):
        assert len(f.tokens) == mnt
        # staggered arrival respected: never admitted before arrival_step
        assert f.admitted_step >= arr

    # 5 requests through 2 slots: freed slots must be reused, and the run
    # must genuinely overlap requests (continuous batching, not serial).
    assert engine.stats["slots_reused"] >= 3
    assert engine.stats["max_concurrency"] == 2
    # drain: every page is back in the free list, none orphaned
    assert engine.allocator.available == engine.ecfg.num_pages - 1
    assert all(st is None for st in engine.slots)
    engine.allocator.check_conservation([])

    # Batch-invariance: each request decoded alone, in a fresh single-slot
    # engine (different slot shapes, different co-tenants, no staggering),
    # must emit the identical greedy stream.
    for req in _requests():
        solo = StemEngine(bundle, params, STEM, _ecfg(1, budget_frac))
        alone = solo.run([Request(uid=req.uid, prompt=req.prompt,
                                  max_new_tokens=req.max_new_tokens)])
        assert alone[0].tokens == finished[req.uid].tokens, (
            f"request {req.uid} tokens depend on its co-tenants "
            f"(budget_frac={budget_frac})")


def test_admission_blocks_on_memory(built):
    """Two requests that each need the entire page pool: slots are free but
    memory isn't, so decode is serialized — and both still complete
    (head-of-line waits, no deadlock)."""
    bundle, params = built
    rng = np.random.RandomState(11)
    reqs = [Request(uid=i,
                    prompt=rng.randint(0, TINY.vocab_size, size=(20,)).astype(np.int32),
                    max_new_tokens=5)
            for i in range(2)]
    per_slot = -(-(20 + 5) // STEM.block_size)
    ecfg = EngineConfig(max_slots=2, num_pages=1 + per_slot,
                        max_pages_per_slot=per_slot, budget_frac=1.0)
    engine = StemEngine(bundle, params, STEM, ecfg)
    finished = engine.run(reqs)
    assert len(finished) == 2
    assert engine.stats["max_concurrency"] == 1
    assert engine.allocator.available == ecfg.num_pages - 1
    engine.allocator.check_conservation([])


def test_oversized_request_rejected(built):
    bundle, params = built
    engine = StemEngine(bundle, params, STEM, _ecfg(1, 1.0))
    big = Request(uid=0, prompt=np.zeros((10_000,), np.int32), max_new_tokens=4)
    with pytest.raises(ValueError, match="max_pages_per_slot"):
        engine.submit(big)


def test_eos_stops_decode(built):
    """EOS recycling: pick the first greedy token stream's token as EOS and
    check the stream is truncated at it."""
    bundle, params = built
    req = _requests()[1]
    ref = StemEngine(bundle, params, STEM, _ecfg(1, 1.0)).run([req])[0]
    eos = ref.tokens[2]  # force a stop after the 3rd token
    ecfg = dataclasses.replace(_ecfg(1, 1.0), eos_id=eos)
    cut = StemEngine(bundle, params, STEM, ecfg).run([req])[0]
    stop = ref.tokens.index(eos) + 1
    assert cut.tokens == ref.tokens[:stop]


def test_page_recycling_isolation(built):
    """A recycled page must not leak the previous tenant's summaries: a
    request served after another finishes (reusing its pages) must emit the
    same tokens as the same request into a fresh engine — at a sparse
    budget, where OAM selection reads the per-page kg/vm summaries that a
    stale page would pollute."""
    bundle, params = built
    rng = np.random.RandomState(3)
    mk = lambda uid, plen, mnt: Request(
        uid=uid, prompt=rng.randint(0, TINY.vocab_size, size=(plen,)).astype(np.int32),
        max_new_tokens=mnt)
    # Decode long enough to cross into a SECOND spill page: the first spill
    # page then stops being the forced-local block and must compete on its
    # kg/vm metric — exactly where a stale page changes selection.  This
    # geometry diverges deterministically when reset_pages is skipped.
    first, second = mk(0, 53, 20), mk(1, 41, 20)

    per_slot = -(-(53 + 20 - 1) // STEM.block_size)
    ecfg = EngineConfig(max_slots=1, num_pages=1 + per_slot,
                        max_pages_per_slot=per_slot, budget_frac=0.5)
    shared = StemEngine(bundle, params, STEM, ecfg)
    shared.submit(first)
    shared.submit(second)
    reused = shared.run()
    assert shared.stats["slots_reused"] == 1
    shared.allocator.check_conservation([])

    fresh = StemEngine(bundle, params, STEM, ecfg)
    alone = fresh.run([Request(uid=1, prompt=second.prompt,
                               max_new_tokens=second.max_new_tokens)])
    assert reused[1].tokens == alone[0].tokens, (
        "second tenant's tokens depend on the recycled pages' history")


def test_unified_step_trace_counts(built):
    """The chunked engine's unified step compiles exactly once per lane
    signature (mixed and decode-only), independent of prompt lengths —
    heterogeneous and novel prompt lengths must add ZERO traces.  The
    monolithic baseline retraces per padded prompt-length bucket."""
    bundle, params = built
    engine = StemEngine(bundle, params, STEM, _ecfg(2, 1.0))
    engine.run(_requests())
    assert engine.stats["traces"] == 2, "one mixed + one decode-only trace"
    assert engine.stats["prefill_traces"] == 0

    rng = np.random.RandomState(23)
    novel = [Request(uid=100 + i,
                     prompt=rng.randint(0, TINY.vocab_size,
                                        size=(p,)).astype(np.int32),
                     max_new_tokens=2)
             for i, p in enumerate((7, 21, 30))]    # new padded buckets
    engine.run(novel)
    assert engine.stats["traces"] == 2, "novel prompt lengths retraced"

    mono = StemEngine(bundle, params, STEM,
                      dataclasses.replace(_ecfg(2, 1.0),
                                          monolithic_prefill=True))
    mono.run(_requests())
    # TRACE prompt lengths pad to buckets {8, 16, 24} -> 3 prefill traces.
    assert mono.stats["prefill_traces"] == 3


def test_admission_control_rejects_infeasible_ttft(built):
    """SLO-aware admission control (opt-in): with the measured step time
    making a request's TTFT SLO unreachable, the request is rejected up
    front with an explicit error — no pages allocated, no silent SLO miss.
    Off by default; requests without a TTFT SLO are never rejected."""
    bundle, params = built
    rng = np.random.RandomState(41)
    prompt = rng.randint(0, TINY.vocab_size, size=(13,)).astype(np.int32)
    mk = lambda **kw: Request(uid=0, prompt=prompt.copy(),
                              max_new_tokens=4, **kw)
    ecfg = dataclasses.replace(_ecfg(2, 1.0), admission_control=True)

    eng = StemEngine(bundle, params, STEM, ecfg)
    eng.monitor.ema = 10.0            # 10 s/step: any tight SLO is infeasible
    fin = eng.run([mk(ttft_slo_s=0.05)])
    assert fin[0].error is not None and fin[0].error.startswith("rejected")
    assert fin[0].tokens == []
    assert eng.stats["admission_rejects"] == 1
    assert eng.allocator.available == ecfg.num_pages - 1, \
        "rejected request left pages allocated"

    # Control: same request, same fake EMA, flag off -> runs to completion.
    off = StemEngine(bundle, params, STEM, _ecfg(2, 1.0))
    off.monitor.ema = 10.0
    fin_off = off.run([mk(ttft_slo_s=0.05)])
    assert fin_off[0].error is None and len(fin_off[0].tokens) == 4
    assert off.stats["admission_rejects"] == 0

    # No TTFT SLO -> admission control never rejects, however slow.
    eng2 = StemEngine(bundle, params, STEM, ecfg)
    eng2.monitor.ema = 10.0
    fin2 = eng2.run([mk()])
    assert fin2[0].error is None and len(fin2[0].tokens) == 4


def test_append_token_matches_prefill_pages():
    """Paged incremental summaries: growing a sequence token-by-token via
    ``append_token`` must reproduce ``write_prefill_pages`` of the full
    sequence — kg/vm increments are what OAM selection reads at decode."""
    hk, d = 2, 16
    n_pages, npages_req = 6, 4
    L = npages_req * STEM.block_size
    plen = 19                                       # partial second page
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    k = jax.random.normal(ks[0], (hk, L, d))
    v = jax.random.normal(ks[1], (hk, L, d))
    page_ids = jnp.asarray([2, 4, 1, 5])
    table = jnp.asarray([[2, 4, 1, 5]])
    grow = init_pool(n_pages, hk, STEM.block_size, d, STEM.stride)
    grow = write_prefill_pages(grow, page_ids, k, v, jnp.asarray(plen), STEM)
    for pos in range(plen, L):
        grow = append_token(grow, table, jnp.asarray([pos]),
                            k[None, :, pos:pos + 1], v[None, :, pos:pos + 1],
                            STEM)
    full = init_pool(n_pages, hk, STEM.block_size, d, STEM.stride)
    full = write_prefill_pages(full, page_ids, k, v, jnp.asarray(L), STEM)
    for got, want, name in zip(grow, full, ("k", "v", "kg", "vm")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5, err_msg=name)
    # and against the contiguous-layout batch summary
    ref = summarize_cache(k[None], v[None], STEM)
    np.testing.assert_allclose(
        np.asarray(grow.kg[:, page_ids]), np.asarray(ref.k_groups[0]),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(grow.vm[:, page_ids]), np.asarray(ref.v_mag[0]),
        rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Cross-policy serving differential: paged engine == fixed-batch decode
# ---------------------------------------------------------------------------

def _fixed_batch_tokens(bundle, params, pol, prompt, mnt,
                        return_caches=False):
    """Reference arm: monolithic contiguous-cache prefill + policy-sparse
    per-step decode (``apply_decode`` re-summarizing the whole cache), no
    paging, no chunking, no engine.  Greedy stream of ``mnt`` tokens."""
    plen = len(prompt)
    bs = pol.block_size
    max_len = -(-(plen + mnt) // bs) * bs         # sparse decode needs L % bs == 0
    # Pad the prompt to a page multiple, exactly like the engine: TPD
    # prefill budgets are evaluated at the PADDED length, so an unpadded
    # prefill would select different blocks and break bit-equality.
    lp = -(-plen // bs) * bs
    toks = np.zeros((1, lp), np.int32)
    toks[0, :plen] = prompt
    prefill = jax.jit(lambda p, b, last: bundle.prefill(
        p, b, max_len=max_len, stem_cfg=pol, last_pos=last))
    serve = jax.jit(steps_lib.make_serve_step(bundle, stem_cfg=pol,
                                              budget_frac=1.0))
    logits, caches = prefill(params, {"tokens": jnp.asarray(toks)},
                             jnp.asarray([plen - 1]))
    tok = jnp.argmax(logits, axis=-1)[:, None]
    out = [int(tok[0, 0])]
    cache_lens = jnp.asarray([plen])
    for i in range(mnt - 1):
        logits, caches = serve(params, tok, caches,
                               cache_lens if i == 0 else None)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        out.append(int(tok[0, 0]))
    if return_caches:
        return out, caches
    return out


CROSS_POLICIES = ["stem", "stem-sam", "uniform-sam", "streaming", "dense"]


@pytest.mark.parametrize("policy_name", CROSS_POLICIES)
def test_cross_policy_engine_matches_fixed_batch(built, policy_name):
    """The paged continuous-batching engine and the monolithic fixed-batch
    decode are two implementations of the same math for EVERY registered
    budget-driven policy family (OAM, SAM, uniform, streaming sink+local,
    dense): greedy streams must agree token-for-token.  Run at
    budget_frac=1.0, where each policy's selection is content-independent —
    the comparison then pins the attention/cache plumbing itself rather
    than near-tie selection behaviour."""
    bundle, params = built
    pol = policy_lib.get_policy(policy_name).with_updates(
        block_size=8, stride=4, sink_blocks=1, local_blocks=1,
        min_budget_blocks=2, ignore_missing=True)
    reqs = _requests()[:3]
    engine = StemEngine(bundle, params, pol, _ecfg(2, 1.0))
    finished = engine.run(reqs)
    assert [f.uid for f in finished] == [0, 1, 2]

    for req, fin in zip(_requests()[:3], finished):
        ref = _fixed_batch_tokens(bundle, params, pol, req.prompt,
                                  req.max_new_tokens)
        assert fin.tokens == ref, (
            f"policy {policy_name}: paged engine diverged from fixed-batch "
            f"decode for request {req.uid}")


def test_long_decode_matches_fixed_batch(built):
    """Long-decode regression: >=512 generated tokens through the paged
    engine (chunked prefill + per-token page appends across ~66 pages) must
    be bitwise the fixed-batch stream, and the pages' stored K/V and
    kg/vm summaries must still match a from-scratch ``summarize_cache`` of
    the reference cache — incremental summary updates may not drift over
    hundreds of appends."""
    bundle, params = built
    rng = np.random.RandomState(29)
    plen, mnt = 21, 512
    prompt = rng.randint(0, TINY.vocab_size, size=(plen,)).astype(np.int32)
    per_slot = -(-(plen + mnt) // STEM.block_size)
    ecfg = EngineConfig(max_slots=1, num_pages=1 + per_slot,
                        max_pages_per_slot=per_slot, budget_frac=1.0)
    engine = StemEngine(bundle, params, STEM, ecfg)
    engine.submit(Request(uid=0, prompt=prompt, max_new_tokens=mnt))
    page_row = None
    while engine.pending:
        engine.step()
        if engine.slots[0] is not None:
            page_row = list(engine.slot_pages[0])
    fin = engine.finished[0]
    pol = policy_lib.as_policy(STEM)
    ref, caches = _fixed_batch_tokens(bundle, params, pol, prompt, mnt,
                                      return_caches=True)
    assert fin.tokens == ref, "long decode drifted from fixed-batch"

    # The drained slot's pages still hold the request's K/V and summaries
    # (pages are only reset on reuse).  Compare every FULL page against the
    # reference cache and a batch re-summarization of it.
    bs = pol.block_size
    L = plen + mnt - 1                  # final token is never fed back
    nfull = L // bs
    pages = np.asarray(page_row[:nfull])
    for si, (n, kinds) in enumerate(transformer.layer_program(TINY)):
        for i, _ in enumerate(kinds):
            pool = engine.pools[si][f"sub{i}"]
            cache = caches[si][f"sub{i}"]
            ck = np.asarray(cache.k)[:, 0, :, :nfull * bs, :]
            cv = np.asarray(cache.v)[:, 0, :, :nfull * bs, :]
            got_k = np.asarray(pool.k)[:, :, pages].reshape(ck.shape)
            got_v = np.asarray(pool.v)[:, :, pages].reshape(cv.shape)
            np.testing.assert_allclose(got_k, ck, atol=1e-4, rtol=1e-4)
            np.testing.assert_allclose(got_v, cv, atol=1e-4, rtol=1e-4)
            for layer in range(ck.shape[0]):
                summ = summarize_cache(jnp.asarray(ck[layer])[None],
                                       jnp.asarray(cv[layer])[None], pol)
                np.testing.assert_allclose(
                    np.asarray(pool.kg)[layer][:, pages],
                    np.asarray(summ.k_groups[0]), atol=1e-4, rtol=1e-4,
                    err_msg=f"kg drift layer {layer} sub{i}")
                np.testing.assert_allclose(
                    np.asarray(pool.vm)[layer][:, pages],
                    np.asarray(summ.v_mag[0]), atol=1e-4, rtol=1e-4,
                    err_msg=f"vm drift layer {layer} sub{i}")


# ---------------------------------------------------------------------------
# PageAllocator unit invariants
# ---------------------------------------------------------------------------

def test_allocator_all_or_nothing():
    a = PageAllocator(5)            # pages 1..4 usable
    assert a.available == 4
    assert a.alloc(5) is None       # refuse, and consume nothing
    assert a.available == 4
    got = a.alloc(4)
    assert sorted(got) == [1, 2, 3, 4]
    assert 0 not in got             # trash page never handed out
    assert a.alloc(1) is None
    a.free(got)
    assert a.available == 4


def test_allocator_double_free_rejected():
    a = PageAllocator(4)
    pages = a.alloc(2)
    a.free(pages)
    with pytest.raises(ValueError, match="double free"):
        a.free([pages[0]])
    with pytest.raises(ValueError, match="bad page"):
        a.free([0])
