"""Baseline sparse-attention methods (the paper's comparison set)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StemConfig, dense_attention
from repro.core.baselines import (baseline_attention, streaming_selection,
                                  uniform_sam_selection, xattention_like_selection)
from repro.core.schedule import schedule_for


def _qkv(seed, b, hq, hk, n, d):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, hq, n, d)),
            jax.random.normal(ks[1], (b, hk, n, d)),
            jax.random.normal(ks[2], (b, hk, n, d)))


def test_streaming_density_analytic():
    """Sink + local window keeps exactly min(sink + local, i+1) blocks/row."""
    sel = streaming_selection(nq=16, nk=16, batch=1, heads=2,
                              sink_blocks=2, local_blocks=2)
    counts = np.asarray(sel.block_mask).sum(axis=-1)[0, 0]
    want = np.minimum(4, np.arange(1, 17))
    # sink and local overlap on the first rows
    assert (counts <= want).all() and counts[-1] == 4


def test_uniform_sam_budget_respected():
    q, k, v = _qkv(0, 1, 2, 2, 512, 32)
    cfg = StemConfig(block_size=64, sink_blocks=1, local_blocks=1,
                     min_budget_blocks=1, stride=8)
    sel = uniform_sam_selection(q, k, v, cfg, k_uni=3)
    counts = np.asarray(sel.block_mask).sum(axis=-1)
    admissible = np.minimum(3, np.arange(1, 9))
    assert (counts == admissible[None, None]).all()


def test_xattention_tau_monotone():
    """Higher cumulative-mass threshold keeps more blocks; tau->1 ~ dense."""
    q, k, v = _qkv(1, 1, 2, 2, 512, 32)
    cfg = StemConfig(block_size=64, sink_blocks=1, local_blocks=1, stride=8)
    kept = []
    for tau in (0.5, 0.9, 0.999999):
        sel = xattention_like_selection(q, k, v, cfg, tau=tau)
        kept.append(int(np.asarray(sel.block_mask).sum()))
    assert kept[0] <= kept[1] <= kept[2]
    full = np.tril(np.ones((8, 8))).sum() * 2  # heads
    assert kept[-1] == full


@pytest.mark.parametrize("method", ["uniform_sam", "streaming", "xattention"])
def test_baselines_run_and_bounded(method):
    q, k, v = _qkv(2, 2, 4, 2, 512, 32)
    cfg = StemConfig(block_size=64, k_start_frac=0.4, sink_blocks=1,
                     local_blocks=1, min_budget_blocks=1, stride=8)
    out, density = baseline_attention(q, k, v, cfg, method=method)
    assert out.shape == q.shape
    assert np.isfinite(np.asarray(out)).all()
    assert 0.0 < float(density) <= 1.0


def test_sparse_segment_schedule():
    """Fig. 3 analysis mode: rows outside the segment keep full budgets."""
    cfg = StemConfig(block_size=64, k_start_frac=0.25, min_budget_blocks=1,
                     sink_blocks=0, local_blocks=1, stride=8,
                     sparse_segment=(0.25, 0.5))
    b = schedule_for(cfg, 64 * 16)
    full = np.minimum(np.arange(1, 17), 16)
    lo, hi = 4, 8
    np.testing.assert_array_equal(b[:lo], full[:lo])
    np.testing.assert_array_equal(b[hi:], full[hi:])
    assert (b[lo:hi] <= full[lo:hi]).all()
    assert (b[lo:hi] < full[lo:hi]).any()
