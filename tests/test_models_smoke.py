"""Per-architecture smoke tests on reduced configs (CPU).

For every assigned arch: instantiate the reduced family variant, run one
forward/loss, one train-style grad step, one prefill + decode step.  Assert
shapes and no NaNs.  The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import RunShape
from repro.core.config import StemConfig
from repro.models import registry

ARCHS = sorted(configs.ASSIGNED)
SMOKE_SEQ = 64
SMOKE_BATCH = 2

SMOKE_STEM = StemConfig(block_size=16, k_start_frac=0.75, mu=0.8, sink_blocks=1,
                        local_blocks=1, min_budget_blocks=2, stride=4)


def _smoke_batch(cfg, key, with_labels=True):
    ks = jax.random.split(key, 3)
    b = {}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(
            ks[0], (SMOKE_BATCH, cfg.encdec.encoder_frames, cfg.d_model), jnp.float32)
        b["tokens"] = jax.random.randint(ks[1], (SMOKE_BATCH, SMOKE_SEQ), 0, cfg.vocab_size)
    elif cfg.vlm_stub:
        s_img = SMOKE_SEQ // 4
        b["patch_embeds"] = jax.random.normal(
            ks[0], (SMOKE_BATCH, s_img, cfg.d_model), jnp.float32)
        b["tokens"] = jax.random.randint(ks[1], (SMOKE_BATCH, SMOKE_SEQ - s_img), 0, cfg.vocab_size)
    else:
        b["tokens"] = jax.random.randint(ks[1], (SMOKE_BATCH, SMOKE_SEQ), 0, cfg.vocab_size)
    if with_labels:
        b["labels"] = jnp.roll(b["tokens"], -1, axis=1)
    return b


@pytest.fixture(scope="module")
def built():
    """Build reduced bundles + params once per module (they're tiny)."""
    out = {}
    for name in ARCHS:
        cfg = configs.reduced(configs.get_config(name)).replace(dtype="float32")
        bundle = registry.build(cfg)
        params = bundle.init_params(jax.random.PRNGKey(0))
        out[name] = (cfg, bundle, params)
    return out


@pytest.mark.parametrize("name", ARCHS)
def test_forward_loss(built, name):
    cfg, bundle, params = built[name]
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = bundle.loss_fn(params, batch, remat=False)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{name}: loss={loss}"
    assert float(loss) > 0


@pytest.mark.parametrize("name", ARCHS)
def test_grad_step(built, name):
    cfg, bundle, params = built[name]
    batch = _smoke_batch(cfg, jax.random.PRNGKey(2))

    def f(p):
        return bundle.loss_fn(p, batch, remat=True)[0]

    grads = jax.grad(f)(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat), name
    # at least some signal somewhere
    assert any(float(jnp.abs(g).max()) > 0 for g in flat), name


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode(built, name):
    cfg, bundle, params = built[name]
    batch = _smoke_batch(cfg, jax.random.PRNGKey(3), with_labels=False)
    max_len = SMOKE_SEQ + 8
    logits, caches = bundle.prefill(params, batch, max_len=max_len)
    assert logits.shape == (SMOKE_BATCH, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), name
    nxt = jnp.argmax(logits, axis=-1)[:, None]
    logits2, caches = bundle.decode_step(params, nxt, caches)
    assert logits2.shape == (SMOKE_BATCH, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2)).all(), name


@pytest.mark.parametrize("name", [n for n in ARCHS
                                  if configs.get_config(n).use_stem])
def test_stem_in_prefill(built, name):
    """Stem sparse prefill must run and stay close to the dense prefill."""
    cfg, bundle, params = built[name]
    batch = _smoke_batch(cfg, jax.random.PRNGKey(4), with_labels=False)
    max_len = SMOKE_SEQ + 8
    dense_logits, _ = bundle.prefill(params, batch, max_len=max_len)
    stem_logits, _ = bundle.prefill(params, batch, max_len=max_len,
                                    stem_cfg=SMOKE_STEM)
    assert np.isfinite(np.asarray(stem_logits)).all()
    # Random-init reduced models give near-noise attention, so this is an
    # integration check (the path runs, output correlates), not an accuracy
    # claim — benchmarks/ measures reconstruction error properly.  The
    # reduced deepseek MLA and glm4 sit at cos ~0.26 on jax 0.4.37 (same
    # value at the seed commit), so they get a lower "clearly positive
    # correlation" bar; everyone else keeps 0.3.
    cos = np.sum(np.asarray(dense_logits) * np.asarray(stem_logits)) / (
        np.linalg.norm(dense_logits) * np.linalg.norm(stem_logits) + 1e-9)
    bar = 0.2 if name in ("deepseek-v3-671b", "glm4-9b") else 0.3
    assert cos > bar, f"{name}: cos={cos}"


@pytest.mark.parametrize("name", ["mamba2-370m", "recurrentgemma-2b"])
def test_recurrent_decode_matches_prefill(built, name):
    """Decode must continue exactly from the prefill state: prefill(n+1)
    logits == prefill(n) -> decode_step(token n)."""
    cfg, bundle, params = built[name]
    key = jax.random.PRNGKey(5)
    toks = jax.random.randint(key, (SMOKE_BATCH, SMOKE_SEQ + 1), 0, cfg.vocab_size)
    full, _ = bundle.prefill(params, {"tokens": toks}, max_len=SMOKE_SEQ + 8)
    part, caches = bundle.prefill(params, {"tokens": toks[:, :-1]}, max_len=SMOKE_SEQ + 8)
    step, _ = bundle.decode_step(params, toks[:, -1:], caches)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_full_configs():
    """Full-config parameter counts land near the published sizes."""
    expect = {
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "glm4-9b": (8e9, 11e9),
        "gemma-2b": (2e9, 3.5e9),
        "qwen1.5-4b": (3e9, 5e9),
        "recurrentgemma-2b": (2e9, 3.6e9),
        "arctic-480b": (380e9, 560e9),
        "deepseek-v3-671b": (600e9, 750e9),
        "mamba2-370m": (0.3e9, 0.5e9),
        # whisper-medium is 769M (enc+dec); our 64k learned-position table
        # (needed for the assigned 32k decode cell vs whisper's native 448)
        # adds ~67M.
        "whisper-medium": (0.6e9, 0.95e9),
        "pixtral-12b": (11e9, 14e9),
    }
    for name, (lo, hi) in expect.items():
        total, active = registry.param_counts(configs.get_config(name))
        assert lo <= total <= hi, f"{name}: {total/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
        assert active <= total
