"""Engine-level fault injection + graceful degradation (runtime/chaos.py).

The contract under chaos: the engine NEVER crashes and NEVER wedges —
every submitted request terminates, either finished or as an explicitly
failed ``FinishedRequest`` (``.error`` set), and the page allocator's
free-list conservation holds at exit (no orphaned pages through any
recovery path).  Transient faults (within the retry bounds) must be fully
absorbed: same results, no aborts.
"""
import dataclasses

import numpy as np
import pytest

import jax

from repro.configs.base import ArchConfig
from repro.core.config import StemConfig
from repro.models import registry
from repro.runtime.chaos import ChaosConfig, ChaosInjector
from repro.runtime.engine import (EngineConfig, EngineStalledError, Request,
                                  StemEngine)
from repro.runtime.fault_tolerance import FailureInjector, InjectedFailure

TINY = ArchConfig(
    name="chaos-tiny", family="dense", num_layers=2, d_model=32,
    num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64,
    qk_norm=True, dtype="float32",
)
STEM = StemConfig(block_size=8, sink_blocks=1, local_blocks=1,
                  min_budget_blocks=2, stride=4)


@pytest.fixture(scope="module")
def built():
    bundle = registry.build(TINY)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return bundle, params


def _mk(rng, uid, plen, mnt, **kw):
    return Request(uid=uid,
                   prompt=rng.randint(0, TINY.vocab_size,
                                      size=(plen,)).astype(np.int32),
                   max_new_tokens=mnt, **kw)


def _ecfg(max_slots, per_slot, **kw):
    return EngineConfig(max_slots=max_slots,
                        num_pages=1 + max_slots * per_slot,
                        max_pages_per_slot=per_slot, **kw)


def test_transient_chaos_absorbed_bit_identical(built):
    """Alloc denial + one step failure + one restore failure, all within
    the retry bounds: every request finishes cleanly with the SAME tokens
    as the chaos-free run — transient faults are invisible in outputs."""
    bundle, params = built
    rng = np.random.RandomState(5)
    per_slot = -(-(20 + 8) // STEM.block_size)
    reqs = [_mk(rng, i, 10 + 3 * i, 5) for i in range(4)]
    reqs.append(_mk(rng, 9, 9, 3, priority=2, arrival_step=5))  # forces preempt
    ecfg = _ecfg(2, per_slot)

    clean = StemEngine(bundle, params, STEM, ecfg)
    want = {f.uid: f.tokens for f in
            clean.run([dataclasses.replace(r) for r in reqs])}

    # Restore lands at step 7: the cost-model victim is the CHEAPEST
    # lowest-priority slot (uid 0, 2 private pages), and its re-admission
    # waits for uid 1's slot to free after the HP request is served.
    chaos = ChaosInjector(ChaosConfig(deny_alloc_steps=(0,), fail_steps=(3,),
                                      fail_restore_steps=(7,)))
    eng = StemEngine(bundle, params, STEM, ecfg, chaos=chaos)
    fin = eng.run(reqs)

    assert chaos.counts == {"alloc_denied": 1, "step_failed": 1,
                            "restore_failed": 1}
    assert eng.stats["alloc_denials"] == 1
    assert eng.stats["step_failures"] == 1
    assert eng.stats["restore_failures"] == 1
    assert eng.stats["aborts"] == 0
    assert len(fin) == len(reqs) and all(f.error is None for f in fin)
    assert {f.uid: f.tokens for f in fin} == want, "chaos changed outputs"
    eng.allocator.check_conservation([])


def test_persistent_step_failure_degrades_not_crashes(built):
    """A step fault outlasting the retry bound: the engine aborts its
    lowest-priority active request (explicit error), retries with the
    smaller batch, and the higher-priority request still completes."""
    bundle, params = built
    rng = np.random.RandomState(7)
    per_slot = -(-(20 + 8) // STEM.block_size)
    # 4 consecutive failures at step 2 vs max_step_retries=2: three failures
    # force one abort, the fourth is absorbed by the post-abort retry.
    chaos = ChaosInjector(ChaosConfig(fail_steps=(2,), step_repeats=4))
    eng = StemEngine(bundle, params, STEM, _ecfg(2, per_slot), chaos=chaos)
    fin = eng.run([_mk(rng, 0, 10, 6, priority=0),
                   _mk(rng, 1, 11, 6, priority=1)])
    errs = {f.uid: f.error for f in fin}
    assert errs[0] is not None and "step failed" in errs[0]
    assert errs[1] is None and len(fin[1].tokens) == 6
    assert eng.stats["aborts"] == 1 and eng.stats["step_failures"] == 4
    eng.allocator.check_conservation([])


def test_total_step_failure_every_request_terminates(built):
    """Worst case — the step fails forever at one engine step: everything
    active is aborted with an error, nothing hangs, nothing leaks."""
    bundle, params = built
    rng = np.random.RandomState(9)
    per_slot = -(-(20 + 8) // STEM.block_size)
    chaos = ChaosInjector(ChaosConfig(fail_steps=(2,), step_repeats=10_000))
    eng = StemEngine(bundle, params, STEM, _ecfg(2, per_slot), chaos=chaos)
    fin = eng.run([_mk(rng, i, 10, 6) for i in range(2)])
    assert len(fin) == 2 and all(f.error is not None for f in fin)
    eng.allocator.check_conservation([])


def test_restore_failure_retries_then_aborts(built):
    """Persistent restore faults: the fresh pages are freed on every
    attempt (conservation), and the offloaded request is aborted with an
    explicit error after max_restore_retries — its snapshot is dropped."""
    bundle, params = built
    rng = np.random.RandomState(11)
    per_slot = -(-(20 + 8) // STEM.block_size)
    lp = _mk(rng, 0, 20, 8, priority=0)
    hp = _mk(rng, 1, 13, 4, priority=1, arrival_step=4)
    chaos = ChaosInjector(ChaosConfig(
        fail_restore_steps=tuple(range(40)), restore_repeats=1))
    eng = StemEngine(bundle, params, STEM,
                     _ecfg(1, per_slot, max_restore_retries=2), chaos=chaos)
    fin = eng.run([lp, hp])
    errs = {f.uid: f.error for f in fin}
    assert errs[1] is None, "HP request should finish normally"
    assert errs[0] is not None and "restore failed" in errs[0]
    assert eng.stats["restore_failures"] == 3     # 2 retries + final
    assert eng.stats["preemptions"] == 1 and eng.stats["restores"] == 0
    assert len(eng.host_store) == 0, "aborted snapshot not dropped"
    eng.allocator.check_conservation([])


def test_load_shedding_bounds_waiting_queue(built):
    """max_waiting: overflow sheds the lowest-priority pending request as a
    failed FinishedRequest; every submitted request still terminates."""
    bundle, params = built
    rng = np.random.RandomState(13)
    per_slot = -(-(8 + 3) // STEM.block_size)
    eng = StemEngine(bundle, params, STEM,
                     _ecfg(1, per_slot, max_waiting=1))
    reqs = [_mk(rng, i, 8, 3, priority=i % 2) for i in range(4)]
    fin = eng.run(reqs)
    assert len(fin) == 4, "a shed request vanished"
    shed = [f for f in fin if f.error and f.error.startswith("shed")]
    assert shed and eng.stats["shed"] == len(shed)
    assert all(f.priority == 0 for f in shed), "shed a high-priority request"
    assert all(f.slot == -1 and not f.tokens for f in shed)
    done = [f for f in fin if f.error is None]
    assert all(len(f.tokens) == 3 for f in done)
    eng.allocator.check_conservation([])


def test_alloc_denial_is_transient_not_preemption(built):
    """An injected allocator denial must behave like momentary exhaustion:
    admission waits a step — it must NOT preempt anyone or leak pages."""
    bundle, params = built
    rng = np.random.RandomState(17)
    per_slot = -(-(10 + 4) // STEM.block_size)
    chaos = ChaosInjector(ChaosConfig(deny_alloc_steps=(0, 1)))
    eng = StemEngine(bundle, params, STEM, _ecfg(2, per_slot), chaos=chaos)
    fin = eng.run([_mk(rng, 0, 10, 4, priority=0),
                   _mk(rng, 1, 10, 4, priority=5)])
    assert all(f.error is None for f in fin)
    assert eng.stats["preemptions"] == 0
    assert eng.stats["alloc_denials"] == 2
    assert min(f.admitted_step for f in fin) >= 2
    eng.allocator.check_conservation([])


def test_engine_stalled_error_names_requests(built):
    bundle, params = built
    rng = np.random.RandomState(19)
    per_slot = -(-(8 + 3) // STEM.block_size)
    eng = StemEngine(bundle, params, STEM, _ecfg(1, per_slot))
    eng.submit(_mk(rng, 42, 8, 3, arrival_step=10**9))
    with pytest.raises(EngineStalledError, match=r"waiting uids \[42\]"):
        eng.run(max_steps=5)
    # The cap is relative to each run: after the operator drops the stuck
    # request, the same engine keeps serving with a fresh step budget.
    eng.waiting.clear()
    fin = eng.run([_mk(rng, 43, 8, 3)], max_steps=50)
    assert [f.uid for f in fin if f.error is None and f.uid == 43]


def test_straggler_monitor_wired_into_step_loop(built):
    """The engine times every working step; with a hair-trigger threshold
    the monitor must flag steps into stats and engine.metrics."""
    bundle, params = built
    rng = np.random.RandomState(23)
    per_slot = -(-(13 + 6) // STEM.block_size)
    eng = StemEngine(bundle, params, STEM,
                     _ecfg(1, per_slot, straggler_threshold=1e-9))
    eng.run([_mk(rng, 0, 13, 6)])
    assert eng.monitor.ema is not None and eng.monitor.ema > 0
    assert eng.stats["straggler_steps"] > 0
    assert eng.metrics["straggler_steps"], "flags missing from metrics"
    assert eng.stats["straggler_steps"] == len(eng.monitor.flagged)


def test_failure_injector_repeats():
    inj = FailureInjector((3,), repeats=2)
    assert not inj.should_fail(2)
    assert inj.should_fail(3) and inj.should_fail(3)
    assert not inj.should_fail(3)
    assert inj.fired == 2
    with pytest.raises(InjectedFailure):
        FailureInjector((1,)).maybe_fail(1)


def test_chaos_injector_counts():
    chaos = ChaosInjector(ChaosConfig(deny_alloc_steps=(0,), fail_steps=(1,),
                                      fail_restore_steps=(2,)))
    assert chaos.deny_alloc(0) and not chaos.deny_alloc(0)
    with pytest.raises(InjectedFailure):
        chaos.maybe_fail_step(1)
    chaos.maybe_fail_step(5)            # non-configured step: no-op
    with pytest.raises(InjectedFailure):
        chaos.maybe_fail_restore(2)
    assert chaos.counts == {"alloc_denied": 1, "step_failed": 1,
                            "restore_failed": 1}
