"""Sharding rules: shape-aware resolution, ZeRO-1 upgrades, cache layouts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.models import registry
from repro.sharding import rules as rules_lib

# Capability gate: these tests build (2,4) and (2,2,2) meshes, so they need
# >= 8 devices.  On a plain CPU host run them with the forced host-device
# flag (CI does, in the "sharding / multi-device" step):
#   XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest tests/test_sharding.py
pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs >= 8 devices for the (2,4)/(2,2,2) meshes; on CPU set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh(multi=False):
    if multi:
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    return jax.make_mesh((2, 4), ("data", "model"))


def test_spec_shape_aware_fallback():
    mesh = _mesh()
    cfg = configs.get_config("qwen3-0.6b")
    rules = rules_lib.logical_rules(cfg, mesh)
    # divisible: heads 16 over model=4
    assert rules_lib.spec_for((1024, 16, 128), ("embed", "heads", "head_dim"),
                              rules, mesh) == P(None, "model")
    # non-divisible dim falls back to replication, no uneven padding
    assert rules_lib.spec_for((1024, 10, 128), ("embed", "heads", "head_dim"),
                              rules, mesh) == P()


def test_no_mesh_axis_used_twice():
    mesh = _mesh()
    cfg = configs.get_config("deepseek-v3-671b")
    rules = rules_lib.logical_rules(cfg, mesh)
    spec = rules_lib.spec_for((256, 7168, 2048), ("experts", "embed", "expert_mlp"),
                              rules, mesh)
    used = [n for e in spec if e for n in ((e,) if isinstance(e, str) else e)]
    assert len(used) == len(set(used))
    assert "model" in used and "data" in used   # EP + FSDP


def test_param_shardings_cover_all_archs():
    mesh = _mesh()
    for name in configs.ASSIGNED:
        cfg = configs.get_config(name)
        bundle = registry.build(cfg)
        values, axes = bundle.abstract_params()
        sh = rules_lib.param_shardings(cfg, mesh, values, axes)
        for v, s in zip(jax.tree.leaves(values), jax.tree.leaves(sh)):
            # every sharded dim must divide
            spec = list(s.spec) + [None] * (len(v.shape) - len(s.spec))
            for dim, entry in zip(v.shape, spec):
                if entry is None:
                    continue
                names = (entry,) if isinstance(entry, str) else entry
                total = int(np.prod([mesh.shape[n] for n in names]))
                assert dim % total == 0, (name, v.shape, s.spec)


def test_zero1_adds_data_axis():
    mesh = _mesh(multi=True)
    cfg = configs.get_config("qwen3-0.6b")
    bundle = registry.build(cfg)
    values, axes = bundle.abstract_params()
    base = rules_lib.param_shardings(cfg, mesh, values, axes)
    z1 = rules_lib.zero1_shardings(cfg, mesh, values, base)
    embed_base = jax.tree.leaves(base)[0].spec
    bigger = 0
    for v, b, z in zip(jax.tree.leaves(values), jax.tree.leaves(base),
                       jax.tree.leaves(z1)):
        nb = [n for e in b.spec if e for n in ((e,) if isinstance(e, str) else e)]
        nz = [n for e in z.spec if e for n in ((e,) if isinstance(e, str) else e)]
        assert set(nb) <= set(nz)
        if len(nz) > len(nb):
            bigger += 1
    assert bigger > 0, "ZeRO-1 sharded nothing extra"


def test_cache_layouts():
    mesh = _mesh()
    # GQA arch with divisible heads -> heads sharded; indivisible -> kv_seq
    cfg = configs.get_config("qwen3-0.6b")   # kv=8, model=4 -> divisible
    caches = registry.abstract_caches(cfg, configs.DECODE_32K)
    sh = rules_lib.cache_shardings(cfg, mesh, caches)
    kv_spec = jax.tree.leaves(sh)[0].spec
    flat = [n for e in kv_spec if e for n in ((e,) if isinstance(e, str) else e)]
    assert "model" in flat and "data" in flat


def test_batch_sharding_respects_divisibility():
    mesh = _mesh()
    cfg = configs.get_config("mamba2-370m")
    spec = {"tokens": jax.ShapeDtypeStruct((1, 128), jnp.int32)}   # batch 1
    sh = rules_lib.batch_sharding(cfg, mesh, spec)
    assert sh["tokens"].spec == P()   # batch=1 can't shard over data=2
