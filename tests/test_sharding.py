"""Sharding: rule resolution + the mesh-sharded serving engine.

Part 1 — sharding rules: shape-aware resolution, ZeRO-1 upgrades, cache
layouts (the training-side spec machinery).

Part 2 — mesh-sharded serving (``sharding/serving.py``): one engine over a
``(dp, tp)`` mesh — page pools tensor-parallel over the KV-head axis,
slot groups data-parallel — must be **bit-for-bit** the single-device
engine: identical greedy streams for every mesh shape, identical restored
page bytes through a tp>1 preempt/restore round-trip, and exactly the same
two compiled traces.  Per-KV-head page selection is what makes tp sharding
communication-free up to the attention-output all-gather; these tests are
the proof.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.base import ArchConfig
from repro.core import policy as policy_lib
from repro.core.config import StemConfig
from repro.models import registry
from repro.runtime import offload as offload_lib
from repro.runtime.engine import EngineConfig, Request, StemEngine
from repro.sharding import rules as rules_lib
from repro.sharding import serving as serving_lib

# Capability gate: these tests build (2,4) and (2,2,2) meshes, so they need
# >= 8 devices.  On a plain CPU host run them with the forced host-device
# flag (CI does, in the "sharding / multi-device" step):
#   XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest tests/test_sharding.py
pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs >= 8 devices for the (2,4)/(2,2,2) meshes; on CPU set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh(multi=False):
    if multi:
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    return jax.make_mesh((2, 4), ("data", "model"))


def test_spec_shape_aware_fallback():
    mesh = _mesh()
    cfg = configs.get_config("qwen3-0.6b")
    rules = rules_lib.logical_rules(cfg, mesh)
    # divisible: heads 16 over model=4
    assert rules_lib.spec_for((1024, 16, 128), ("embed", "heads", "head_dim"),
                              rules, mesh) == P(None, "model")
    # non-divisible dim falls back to replication, no uneven padding
    assert rules_lib.spec_for((1024, 10, 128), ("embed", "heads", "head_dim"),
                              rules, mesh) == P()


def test_no_mesh_axis_used_twice():
    mesh = _mesh()
    cfg = configs.get_config("deepseek-v3-671b")
    rules = rules_lib.logical_rules(cfg, mesh)
    spec = rules_lib.spec_for((256, 7168, 2048), ("experts", "embed", "expert_mlp"),
                              rules, mesh)
    used = [n for e in spec if e for n in ((e,) if isinstance(e, str) else e)]
    assert len(used) == len(set(used))
    assert "model" in used and "data" in used   # EP + FSDP


def test_param_shardings_cover_all_archs():
    mesh = _mesh()
    for name in configs.ASSIGNED:
        cfg = configs.get_config(name)
        bundle = registry.build(cfg)
        values, axes = bundle.abstract_params()
        sh = rules_lib.param_shardings(cfg, mesh, values, axes)
        for v, s in zip(jax.tree.leaves(values), jax.tree.leaves(sh)):
            # every sharded dim must divide
            spec = list(s.spec) + [None] * (len(v.shape) - len(s.spec))
            for dim, entry in zip(v.shape, spec):
                if entry is None:
                    continue
                names = (entry,) if isinstance(entry, str) else entry
                total = int(np.prod([mesh.shape[n] for n in names]))
                assert dim % total == 0, (name, v.shape, s.spec)


def test_zero1_adds_data_axis():
    mesh = _mesh(multi=True)
    cfg = configs.get_config("qwen3-0.6b")
    bundle = registry.build(cfg)
    values, axes = bundle.abstract_params()
    base = rules_lib.param_shardings(cfg, mesh, values, axes)
    z1 = rules_lib.zero1_shardings(cfg, mesh, values, base)
    embed_base = jax.tree.leaves(base)[0].spec
    bigger = 0
    for v, b, z in zip(jax.tree.leaves(values), jax.tree.leaves(base),
                       jax.tree.leaves(z1)):
        nb = [n for e in b.spec if e for n in ((e,) if isinstance(e, str) else e)]
        nz = [n for e in z.spec if e for n in ((e,) if isinstance(e, str) else e)]
        assert set(nb) <= set(nz)
        if len(nz) > len(nb):
            bigger += 1
    assert bigger > 0, "ZeRO-1 sharded nothing extra"


def test_cache_layouts():
    mesh = _mesh()
    # GQA arch with divisible heads -> heads sharded; indivisible -> kv_seq
    cfg = configs.get_config("qwen3-0.6b")   # kv=8, model=4 -> divisible
    caches = registry.abstract_caches(cfg, configs.DECODE_32K)
    sh = rules_lib.cache_shardings(cfg, mesh, caches)
    kv_spec = jax.tree.leaves(sh)[0].spec
    flat = [n for e in kv_spec if e for n in ((e,) if isinstance(e, str) else e)]
    assert "model" in flat and "data" in flat


def test_batch_sharding_respects_divisibility():
    mesh = _mesh()
    cfg = configs.get_config("mamba2-370m")
    spec = {"tokens": jax.ShapeDtypeStruct((1, 128), jnp.int32)}   # batch 1
    sh = rules_lib.batch_sharding(cfg, mesh, spec)
    assert sh["tokens"].spec == P()   # batch=1 can't shard over data=2


# ---------------------------------------------------------------------------
# Mesh-sharded serving (sharding/serving.py + runtime/engine.py)
# ---------------------------------------------------------------------------

TINY = ArchConfig(
    name="mesh-tiny", family="dense", num_layers=2, d_model=32,
    num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64,
    qk_norm=True, dtype="float32",
)
STEM_SRV = StemConfig(block_size=8, sink_blocks=1, local_blocks=1,
                      min_budget_blocks=2, stride=4)
TRACE = [  # (prompt_len, max_new_tokens, arrival_step) — mixed + staggered
    (5, 4, 0),
    (13, 6, 0),
    (8, 3, 1),
    (20, 5, 3),
    (9, 4, 5),
]


@pytest.fixture(scope="module")
def served():
    bundle = registry.build(TINY)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return bundle, params


def _serve_requests():
    rng = np.random.RandomState(7)
    return [Request(uid=i,
                    prompt=rng.randint(0, TINY.vocab_size,
                                       size=(p,)).astype(np.int32),
                    max_new_tokens=m, arrival_step=a)
            for i, (p, m, a) in enumerate(TRACE)]


def _serve_ecfg(max_slots=2, **kw):
    per_slot = -(-max(p + n for p, n, _ in TRACE) // STEM_SRV.block_size)
    return EngineConfig(max_slots=max_slots,
                        num_pages=1 + 2 * max_slots * per_slot,
                        max_pages_per_slot=per_slot, **kw)


@pytest.mark.parametrize("mesh", [(1, 2), (2, 1), (2, 2)])
def test_mesh_engine_bitwise_vs_single_device(served, mesh):
    """The whole point of the sharding layer: dp slot groups x tp KV-head
    shards must reproduce the single-device engine token-for-token (same
    trace, same staggered arrivals), with the same TWO compiled traces.
    dp>1 additionally moves requests into different slot groups than the
    single-device run packs them — so this doubles as batch-invariance
    across group placement."""
    bundle, params = served
    ref = StemEngine(bundle, params, STEM_SRV, _serve_ecfg()).run(
        _serve_requests())
    eng = StemEngine(bundle, params, STEM_SRV, _serve_ecfg(mesh=mesh))
    got = eng.run(_serve_requests())
    assert eng.groups == mesh[0]
    assert eng.total_slots == mesh[0] * 2
    for r, g in zip(ref, got):
        assert r.tokens == g.tokens, \
            f"uid {r.uid} diverged under mesh {mesh}"
        assert g.error is None
    assert eng.stats["traces"] == 2, "mesh added unified-step traces"
    # drain: every group's pages back, none orphaned
    for alloc in eng.allocators:
        alloc.check_conservation([])


@pytest.mark.parametrize("mesh", [(1, 2), (2, 1), (2, 2)])
def test_mesh_engine_async_bitwise(served, mesh):
    """The async pipeline under the mesh: on-device sampling replaces the
    per-step logits all-gather with a sharded ``(dp, S) int32`` token
    buffer, and one-step lookahead overlaps dispatch with the id fetch —
    streams must still be bit-identical to the single-device SYNC oracle
    (the strongest cross-product differential), with the same two traces
    and O(finished-requests) blocking host syncs."""
    bundle, params = served
    ref = StemEngine(bundle, params, STEM_SRV, _serve_ecfg()).run(
        _serve_requests())
    eng = StemEngine(bundle, params, STEM_SRV,
                     _serve_ecfg(mesh=mesh, async_depth=1))
    got = eng.run(_serve_requests())
    for r, g in zip(ref, got):
        assert r.tokens == g.tokens, \
            f"uid {r.uid}: async mesh {mesh} diverged from sync 1-device"
        assert g.error is None
    assert eng.stats["traces"] == 2
    assert eng.stats["host_syncs"] <= 2 * len(got)
    assert not eng._inflight
    for alloc in eng.allocators:
        alloc.check_conservation([])


def test_mesh_pallas_matches_single_device_xla(served):
    """Differential across BOTH executors under the mesh: the fused Pallas
    kernels read their KV-head extent from the (local) pool shard, so the
    same registration serves tp-sharded pools unchanged."""
    bundle, params = served
    ref = StemEngine(bundle, params, STEM_SRV, _serve_ecfg()).run(
        _serve_requests())
    for executor in ("xla", "pallas"):
        eng = StemEngine(bundle, params, STEM_SRV,
                         _serve_ecfg(mesh=(2, 2), executor=executor))
        got = eng.run(_serve_requests())
        for r, g in zip(ref, got):
            assert r.tokens == g.tokens, \
                f"uid {r.uid} diverged (executor={executor})"


def test_mesh_preempt_restore_roundtrip_tp2(served):
    """Preempt -> per-shard host snapshot keyed by mesh coordinate ->
    restore into fresh pages must be bit-identical under tp>1: same shard
    bytes at the same (dp, tp) coordinates, same resumed stream, zero
    extra traces."""
    bundle, params = served
    rng = np.random.RandomState(17)
    prompt = rng.randint(0, TINY.vocab_size, size=(20,)).astype(np.int32)
    mk = lambda: Request(uid=0, prompt=prompt.copy(), max_new_tokens=8)
    ecfg = _serve_ecfg(max_slots=1, budget_frac=0.5)

    ref = StemEngine(bundle, params, STEM_SRV, ecfg).run([mk()])[0]

    eng = StemEngine(bundle, params, STEM_SRV,
                     dataclasses.replace(ecfg, mesh=(1, 2)))
    eng.submit(mk())
    for _ in range(4):
        eng.step()
    assert eng.slots[0] is not None and eng.slots[0].phase == "decode"
    eng.preempt(0)
    eng.allocators[0].check_conservation([])
    snap_host = eng.host_store.get(0)
    for leaf in jax.tree.leaves(
            snap_host, is_leaf=lambda x: isinstance(x, offload_lib.HostShards)):
        assert isinstance(leaf, offload_lib.HostShards)
        assert sorted(leaf.shards) == [(0, 0), (0, 1)], \
            "snapshot not keyed by (dp, tp) mesh coordinate"
    traces_before = eng.stats["traces"]

    eng._admit()
    assert eng.slots[0] is not None and not eng.preempted
    assert eng.stats["traces"] == traces_before, "restore retraced"
    # Page-for-page, shard-for-shard: re-extracting the restored pages
    # returns the offloaded bytes at the same mesh coordinates.
    W = eng.ecfg.max_pages_per_slot
    rows = np.zeros((eng.groups, W), np.int32)
    rows[0, :len(eng.slot_pages[0])] = eng.slot_pages[0]
    back = offload_lib.shard_snapshot_to_host(
        eng._extract(eng.pools, jnp.asarray(rows)), eng.smesh, 0)
    for got, want in zip(
            jax.tree.leaves(back, is_leaf=lambda x: isinstance(
                x, offload_lib.HostShards)),
            jax.tree.leaves(snap_host, is_leaf=lambda x: isinstance(
                x, offload_lib.HostShards))):
        assert sorted(got.shards) == sorted(want.shards)
        for c in want.shards:
            assert np.array_equal(got.shards[c], want.shards[c]), \
                f"restored shard {c} differs from snapshot"

    out = eng.run()[0]
    assert out.tokens == ref.tokens, "tp=2 preempt/restore diverged"
    assert out.preemptions == 1 and eng.stats["traces"] == 2
    eng.allocators[0].check_conservation([])


def test_mesh_executor_sharding_contract(served):
    """tp>1 requires the executor to declare per-KV-head independence
    ('kv-head'); a 'replicated' executor must be rejected up front, not
    silently produce garbage.  Both shipped executors declare it."""
    bundle, params = served
    for name in ("xla", "pallas"):
        assert policy_lib.get_paged_executor(name).sharding == "kv-head"
    spec = policy_lib.get_paged_executor("xla")
    policy_lib.register_paged_executor(
        "replicated-probe", decode_fn=spec.decode_fn, chunk_fn=spec.chunk_fn,
        sharding="replicated", overwrite=True)
    with pytest.raises(ValueError, match="kv-head"):
        StemEngine(bundle, params, STEM_SRV,
                   _serve_ecfg(mesh=(1, 2), executor="replicated-probe"))
    # dp-only meshes never touch the head axis: replicated executors fine.
    eng = StemEngine(bundle, params, STEM_SRV,
                     _serve_ecfg(mesh=(2, 1), executor="replicated-probe"))
    got = eng.run(_serve_requests())
    ref = StemEngine(bundle, params, STEM_SRV, _serve_ecfg()).run(
        _serve_requests())
    assert all(r.tokens == g.tokens for r, g in zip(ref, got))


def test_mesh_rejects_bad_shapes(served):
    """Config validation: kv heads (2) not divisible by tp, or a mesh
    bigger than the device count, fails loudly at engine construction."""
    bundle, params = served
    with pytest.raises(ValueError):
        StemEngine(bundle, params, STEM_SRV, _serve_ecfg(mesh=(1, 3)))
    with pytest.raises(ValueError):
        StemEngine(bundle, params, STEM_SRV, _serve_ecfg(mesh=(16, 2)))
