"""Chunk-boundary equivalence of chunked prefill (core/chunked.py).

The load-bearing property: prefilling a prompt in chunks through the paged
pool — TPD budgets and sink/local floors evaluated at *absolute* query
positions, history scored from stored page summaries — must be
differentially equivalent to one-shot prefill (``prefill_kv_pages``), for
any chunk size (aligned or not to the prompt), any budget-driven policy,
and any GQA group.  Plus the page-summary lifecycle property: building a
prompt up chunk by chunk via ``write_chunk_pages`` reproduces the one-shot
``write_prefill_pages`` pooling page-for-page (extending the
``append_token == write_prefill_pages`` pin in ``tests/test_engine.py``).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to fixed-seed parametrized sampling
    from _hypothesis_compat import given, settings, st

from repro.configs.base import ArchConfig
from repro.core import chunked as chunked_lib
from repro.core import policy as policy_lib
from repro.core.config import StemConfig
from repro.models import registry, transformer
from repro.runtime import paged as paged_lib

BS = 8          # block/page size for all test policies

ARCH_BY_GROUP = {
    1: ArchConfig(name="chunk-tiny-g1", family="dense", num_layers=2,
                  d_model=32, num_heads=4, num_kv_heads=4, head_dim=8,
                  d_ff=64, vocab_size=64, qk_norm=True, dtype="float32"),
    4: ArchConfig(name="chunk-tiny-g4", family="dense", num_layers=2,
                  d_model=32, num_heads=4, num_kv_heads=1, head_dim=8,
                  d_ff=64, vocab_size=64, qk_norm=True, dtype="float32"),
}


def _policy(name: str):
    return policy_lib.get_policy(name).with_updates(
        block_size=BS, stride=4, sink_blocks=1, local_blocks=1,
        min_budget_blocks=2, ignore_missing=True)


@pytest.fixture(scope="module")
def built():
    out = {}
    for group, cfg in ARCH_BY_GROUP.items():
        bundle = registry.build(cfg)
        out[group] = (bundle, bundle.init_params(jax.random.PRNGKey(0)))
    return out


def _one_shot(params, cfg, pol, prompt, page_row, num_pages):
    pools = transformer.init_page_pools(cfg, num_pages, pol)
    npages_prompt = -(-len(prompt) // BS)
    toks = np.zeros((1, npages_prompt * BS), np.int32)
    toks[0, :len(prompt)] = prompt
    logits, pools = transformer.prefill_kv_pages(
        params, jnp.asarray(toks), jnp.asarray(len(prompt), jnp.int32),
        pools, jnp.asarray(page_row), cfg, pol)
    return np.asarray(logits), pools


def _chunked(params, cfg, pol, prompt, page_row, num_pages, chunk):
    """Drive the prompt through ``paged_mixed_step`` chunk lane, one lane,
    dummy (trash) decode lane — exactly the engine's dataflow."""
    pools = transformer.init_page_pools(cfg, num_pages, pol)
    pools = paged_lib.reset_pools_stacked(pools, jnp.asarray(page_row))
    plen = len(prompt)
    padded_len = -(-plen // BS) * BS
    ptoks = np.zeros((padded_len,), np.int32)
    ptoks[:plen] = prompt
    k_bound = chunked_lib.chunk_budget_bound(pol, len(page_row))
    nc = chunk // BS
    dec_tokens = jnp.zeros((1, 1), jnp.int32)
    dec_table = jnp.zeros((1, len(page_row)), jnp.int32)
    dec_lens = jnp.zeros((1,), jnp.int32)
    logits = None
    for t0 in range(0, padded_len, chunk):
        ctoks = np.zeros((1, chunk), np.int32)
        avail = ptoks[t0:t0 + chunk]
        ctoks[0, :len(avail)] = avail
        cbud = chunked_lib.chunk_budget_rows(pol, padded_len, t0, nc)[None]
        cd = {"tokens": jnp.asarray(ctoks),
              "page_table": jnp.asarray(page_row)[None],
              "start": jnp.asarray([t0], jnp.int32),
              "true_len": jnp.asarray([plen], jnp.int32),
              "budgets": jnp.asarray(cbud),
              "last": jnp.asarray([min(max(plen - 1 - t0, 0), chunk - 1)],
                                  jnp.int32)}
        _, logits, pools = transformer.paged_mixed_step(
            params, dec_tokens, pools, dec_table, dec_lens, cfg,
            stem_cfg=pol, budget_frac=1.0, chunk=cd, chunk_k_max=k_bound)
    return np.asarray(logits)[0], pools


# Prompt 43 is deliberately awkward: padded to 48 (6 pages), partial final
# page, and 43 % chunk != 0 for every tested chunk size.
PROMPT_LEN = 43


@pytest.mark.parametrize("group", [1, 4])
@pytest.mark.parametrize("policy_name", ["stem", "uniform-sam", "dense"])
@pytest.mark.parametrize("chunk", [BS, 2 * BS, 3 * BS])
def test_chunked_matches_one_shot(built, group, policy_name, chunk):
    bundle, params = built[group]
    cfg = bundle.cfg
    pol = _policy(policy_name)
    rng = np.random.RandomState(17 + group)
    prompt = rng.randint(0, cfg.vocab_size, size=(PROMPT_LEN,)).astype(np.int32)
    npages_prompt = -(-PROMPT_LEN // BS)
    n_reserved = npages_prompt + 2          # a couple of decode-spill pages
    num_pages = 1 + n_reserved + 2          # spare pages stay untouched
    page_row = np.asarray(
        list(range(1, n_reserved + 1)), np.int32)

    ref_logits, ref_pools = _one_shot(params, cfg, pol, prompt, page_row,
                                      num_pages)
    got_logits, got_pools = _chunked(params, cfg, pol, prompt, page_row,
                                     num_pages, chunk)

    np.testing.assert_allclose(got_logits, ref_logits, atol=1e-4, rtol=1e-4)
    # The page pools must agree too — prompt pages *and* summaries (what
    # decode selection will read) are written identically.
    prompt_pages = page_row[:npages_prompt]
    for si in range(len(ref_pools)):
        for sub in ref_pools[si]:
            rp, gp = ref_pools[si][sub], got_pools[si][sub]
            for name in ("k", "v", "kg", "vm"):
                r = np.asarray(getattr(rp, name))[:, :, prompt_pages]
                g = np.asarray(getattr(gp, name))[:, :, prompt_pages]
                np.testing.assert_allclose(g, r, atol=1e-5, rtol=1e-5,
                                           err_msg=f"{sub}.{name}")


def test_threshold_selector_rejected():
    """Cumulative-mass selection has data-dependent budgets — chunked
    prefill must refuse it with a clear error (monolithic still serves it).
    """
    with pytest.raises(NotImplementedError, match="budget-driven"):
        chunked_lib.validate_chunked_policy(policy_lib.get_policy("xattention"))
    chunked_lib.validate_chunked_policy(policy_lib.get_policy("stem"))


# ---------------------------------------------------------------------------
# Page-summary lifecycle property: chunk-by-chunk == one-shot pooling
# ---------------------------------------------------------------------------

STEM = StemConfig(block_size=BS, sink_blocks=1, local_blocks=1,
                  min_budget_blocks=2, stride=4)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    npages=st.integers(2, 6),
    chunk_pages=st.integers(1, 4),
    len_frac=st.floats(0.1, 1.0),
)
def test_chunk_summaries_match_one_shot(seed, npages, chunk_pages, len_frac):
    """Incremental per-chunk page writes (K/V, anti-diag group means, max
    log||V||) equal ``write_prefill_pages`` of the full sequence for every
    (chunk size, prompt length) — including prompts that end mid-page and
    chunk grids that overrun the prompt."""
    hk, d = 2, 16
    L = npages * BS
    plen = max(1, int(len_frac * L))
    chunk = chunk_pages * BS
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    k = jax.random.normal(keys[0], (hk, L, d))
    v = jax.random.normal(keys[1], (hk, L, d))
    n_pool = npages + 2
    rng = np.random.RandomState(seed)
    page_ids = rng.permutation(np.arange(1, n_pool))[:npages].astype(np.int32)

    one = paged_lib.init_pool(1 + n_pool, hk, BS, d, STEM.stride)
    one = paged_lib.write_prefill_pages(one, jnp.asarray(page_ids), k, v,
                                        jnp.asarray(plen), STEM)

    grow = paged_lib.init_pool(1 + n_pool, hk, BS, d, STEM.stride)
    table = jnp.asarray(page_ids)[None]                   # (1, npages)
    for t0 in range(0, L, chunk):
        kc = np.zeros((1, hk, chunk, d), np.float32)
        vc = np.zeros((1, hk, chunk, d), np.float32)
        n_av = min(chunk, L - t0)
        kc[0, :, :n_av] = np.asarray(k[:, t0:t0 + n_av])
        vc[0, :, :n_av] = np.asarray(v[:, t0:t0 + n_av])
        grow = paged_lib.write_chunk_pages(
            grow, table, jnp.asarray([t0], jnp.int32), jnp.asarray(kc),
            jnp.asarray(vc), jnp.asarray([plen], jnp.int32), STEM)

    for got, want, name in zip(grow, one, ("k", "v", "kg", "vm")):
        np.testing.assert_allclose(
            np.asarray(got)[:, page_ids], np.asarray(want)[:, page_ids],
            rtol=1e-5, atol=1e-5, err_msg=name)
