"""Beyond-paper Stem-sparse decode: differential suite vs full-cache decode.

The load-bearing guarantee for the serving engine: at ``budget_frac=1.0``
every valid cache block is selected, so ``sparse_decode_attention`` must
reproduce dense decode *exactly* (<= 1e-4 fp32) across GQA group sizes
{1, 2, 4}, ragged per-sequence cache lengths, and lengths that are not
multiples of ``block_size``.  Sparse budgets are then checked for selection
quality (close to dense, better than sink+local streaming).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StemConfig
from repro.core.decode import sparse_decode_attention, summarize_cache


def _setup(seed, b, hq, hk, L, d):
    """QKV with *concentrated* attention: a few keys strongly aligned with
    the query (per KV group) so the true attention mass sits in findable
    blocks — the regime sparse decode targets."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, hq, 1, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hk, L, d), jnp.float32) * 0.3
    v = jax.random.normal(ks[2], (b, hk, L, d), jnp.float32)
    hot = jnp.arange(L // 8, L, L // 5)
    # group *sum* aligns with every query head in the group: <q_i, sum_j q_j>
    # ~ ||q_i||^2 >> noise, so all heads concentrate on the hot blocks.
    qg = q.reshape(b, hk, hq // hk, d).sum(axis=2)           # (b, hk, d)
    k = k.at[:, :, hot].set(qg[:, :, None, :] * 1.2
                            + 0.1 * jax.random.normal(ks[3], (b, hk, len(hot), d)))
    v = v.at[:, :, hot].multiply(6.0)
    return q, k, v


def _dense_decode(q, k, v, cache_lens):
    """Full-cache oracle; cache_lens scalar or (b,) per-row valid prefix."""
    b, hq, _, d = q.shape
    hk = k.shape[1]
    g = hq // hk
    lens = jnp.broadcast_to(jnp.asarray(cache_lens, jnp.int32), (b,))
    qg = q.reshape(b, hk, g, 1, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhld->bhgql", qg, k.astype(jnp.float32)) * (d ** -0.5)
    valid = jnp.arange(k.shape[2])[None, :] < lens[:, None]        # (b, L)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgql,bhld->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, 1, d)


# ---------------------------------------------------------------------------
# Differential oracle: budget_frac=1.0 == dense decode, <= 1e-4 fp32
# ---------------------------------------------------------------------------

GQA_GROUPS = [(4, 4), (4, 2), (4, 1)]   # group sizes 1, 2, 4


@pytest.mark.parametrize("hq,hk", GQA_GROUPS)
def test_full_budget_matches_dense(hq, hk):
    q, k, v = _setup(0, 2, hq, hk, 512, 32)
    cfg = StemConfig(block_size=64, sink_blocks=1, local_blocks=1,
                     min_budget_blocks=8, stride=8)
    summ = summarize_cache(k, v, cfg)
    clen = jnp.asarray(512, jnp.int32)
    got = sparse_decode_attention(q, k, v, summ, clen, cfg, budget_frac=1.0)
    want = _dense_decode(q, k, v, clen)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("hq,hk", GQA_GROUPS)
def test_full_budget_matches_dense_ragged_lens(hq, hk):
    """Per-sequence cache lengths, none a multiple of block_size."""
    b, L, d = 3, 320, 32
    q, k, v = _setup(3, b, hq, hk, L, d)
    cfg = StemConfig(block_size=64, sink_blocks=1, local_blocks=1,
                     min_budget_blocks=2, stride=8)
    summ = summarize_cache(k, v, cfg)
    lens = jnp.asarray([317, 130, 65], jnp.int32)   # all % 64 != 0
    got = sparse_decode_attention(q, k, v, summ, lens, cfg, budget_frac=1.0)
    want = _dense_decode(q, k, v, lens)
    err = np.max(np.abs(np.asarray(got) - np.asarray(want)))
    assert err <= 1e-4, f"group={hq//hk}: max|err|={err}"


@pytest.mark.parametrize("cache_len", [63, 64, 65, 127, 190])
def test_full_budget_matches_dense_unaligned_scalar(cache_len):
    """Scalar cache_len not a multiple of block_size (partial last block)."""
    q, k, v = _setup(4, 2, 4, 2, 256, 32)
    cfg = StemConfig(block_size=64, sink_blocks=1, local_blocks=1,
                     min_budget_blocks=2, stride=8)
    summ = summarize_cache(k, v, cfg)
    clen = jnp.asarray(cache_len, jnp.int32)
    got = sparse_decode_attention(q, k, v, summ, clen, cfg, budget_frac=1.0)
    want = _dense_decode(q, k, v, clen)
    err = np.max(np.abs(np.asarray(got) - np.asarray(want)))
    assert err <= 1e-4, f"cache_len={cache_len}: max|err|={err}"


def test_scalar_and_vector_lens_agree():
    """A (b,) vector of identical lengths must equal the scalar path."""
    q, k, v = _setup(5, 3, 4, 2, 256, 16)
    cfg = StemConfig(block_size=32, sink_blocks=1, local_blocks=1,
                     min_budget_blocks=2, stride=8)
    summ = summarize_cache(k, v, cfg)
    a = sparse_decode_attention(q, k, v, summ, jnp.asarray(200, jnp.int32),
                                cfg, budget_frac=0.5)
    bvec = sparse_decode_attention(q, k, v, summ,
                                   jnp.full((3,), 200, jnp.int32),
                                   cfg, budget_frac=0.5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(bvec))


# ---------------------------------------------------------------------------
# Sparse budgets: selection quality
# ---------------------------------------------------------------------------

def test_sparse_budget_close_to_dense():
    q, k, v = _setup(1, 2, 4, 2, 1024, 32)
    cfg = StemConfig(block_size=64, sink_blocks=1, local_blocks=1,
                     min_budget_blocks=2, stride=8)
    summ = summarize_cache(k, v, cfg)
    clen = jnp.asarray(1024, jnp.int32)
    dense = _dense_decode(q, k, v, clen)
    # 5 hot blocks + sink + local = 7 of 16 blocks -> 50% budget covers them
    sparse = sparse_decode_attention(q, k, v, summ, clen, cfg, budget_frac=0.5)
    rel = float(jnp.linalg.norm(sparse - dense) / jnp.linalg.norm(dense))
    assert rel < 0.25, rel
    # and far better than an arbitrary (sink+local only) selection
    streaming = sparse_decode_attention(q, k, v, summ, clen, cfg, budget_frac=0.0)
    rel_stream = float(jnp.linalg.norm(streaming - dense) / jnp.linalg.norm(dense))
    assert rel < rel_stream, (rel, rel_stream)


def test_partial_cache_masking():
    """Tokens beyond cache_len must not contribute."""
    q, k, v = _setup(2, 1, 2, 2, 512, 16)
    cfg = StemConfig(block_size=64, sink_blocks=1, local_blocks=1,
                     min_budget_blocks=2, stride=8)
    clen = jnp.asarray(300, jnp.int32)
    summ = summarize_cache(k, v, cfg)
    out1 = sparse_decode_attention(q, k, v, summ, clen, cfg, budget_frac=1.0)
    # poison the invalid tail: output must not change
    k2 = k.at[:, :, 300:].set(99.0)
    v2 = v.at[:, :, 300:].set(99.0)
    out2 = sparse_decode_attention(q, k2, v2, summarize_cache(k2, v2, cfg),
                                   clen, cfg, budget_frac=1.0)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-5)


def test_partial_cache_masking_ragged():
    """Per-row poison: each row ignores its own invalid tail independently."""
    q, k, v = _setup(7, 3, 4, 2, 256, 16)
    cfg = StemConfig(block_size=32, sink_blocks=1, local_blocks=1,
                     min_budget_blocks=2, stride=8)
    lens = jnp.asarray([250, 100, 33], jnp.int32)
    out1 = sparse_decode_attention(q, k, v, summarize_cache(k, v, cfg),
                                   lens, cfg, budget_frac=1.0)
    mask = jnp.arange(256)[None, None, :, None] >= lens[:, None, None, None]
    k2 = jnp.where(mask, 99.0, k)
    v2 = jnp.where(mask, 99.0, v)
    out2 = sparse_decode_attention(q, k2, v2, summarize_cache(k2, v2, cfg),
                                   lens, cfg, budget_frac=1.0)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-5)
