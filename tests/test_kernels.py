"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StemConfig
from repro.core.sparse_attention import select_for
from repro.kernels import ops, ref


def _qkv(seed, b, hq, hk, n, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (b, hq, n, d), dtype),
        jax.random.normal(ks[1], (b, hk, n, d), dtype),
        jax.random.normal(ks[2], (b, hk, n, d), dtype),
    )


def _tol(dtype):
    return dict(atol=2e-6, rtol=2e-6) if dtype == jnp.float32 else dict(atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hk,n,d,bq,bk",
    [
        (1, 1, 1, 128, 32, 64, 64),
        (2, 4, 2, 256, 64, 64, 64),
        (1, 8, 1, 256, 128, 128, 128),   # MQA, head_dim 128
        (1, 2, 2, 512, 256, 128, 128),   # gemma-style head_dim 256
        (2, 2, 1, 384, 64, 128, 128),    # non-power-of-two block count
    ],
)
def test_flash_attention_sweep(b, hq, hk, n, d, bq, bk, dtype):
    q, k, v = _qkv(0, b, hq, hk, n, d, dtype)
    got = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hk,n,d,bs,frac",
    [
        (1, 2, 2, 256, 32, 64, 0.5),
        (2, 4, 2, 512, 64, 64, 0.3),
        (1, 4, 1, 512, 128, 128, 0.5),
        (1, 2, 2, 1024, 64, 128, 0.2),
    ],
)
def test_block_sparse_attention_sweep(b, hq, hk, n, d, bs, frac, dtype):
    q, k, v = _qkv(1, b, hq, hk, n, d, dtype)
    cfg = StemConfig(block_size=bs, k_start_frac=frac, mu=0.7, sink_blocks=1,
                     local_blocks=1, min_budget_blocks=1, stride=8)
    sel, _ = select_for(q, k, v, cfg)
    got = ops.block_sparse_attention(q, k, v, sel.indices, sel.slot_mask, block_size=bs)
    want = ref.block_sparse_attention_ref(q, k, v, sel.indices, sel.slot_mask, block_size=bs)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_block_sparse_full_budget_equals_flash():
    """With every block selected, the sparse kernel must equal dense flash."""
    q, k, v = _qkv(2, 1, 2, 2, 256, 64, jnp.float32)
    cfg = StemConfig(block_size=64, k_start_frac=1.0, mu=1.0, sink_blocks=0,
                     local_blocks=1, min_budget_blocks=0, stride=8)
    sel, _ = select_for(q, k, v, cfg)
    got = ops.block_sparse_attention(q, k, v, sel.indices, sel.slot_mask, block_size=64)
    want = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-6, rtol=3e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bs,stride,d", [(64, 8, 32), (128, 16, 64), (128, 16, 128)])
def test_antidiag_pool_sweep(bs, stride, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 512, d), dtype)
    got = ops.antidiag_pool(x, block_size=bs, stride=stride)
    want = ref.antidiag_pool_ref(x, bs, stride)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bs,d", [(64, 32), (128, 64), (128, 256)])
def test_value_magnitude_sweep(bs, d, dtype):
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 512, d), dtype) * 3.0
    got = ops.value_magnitude(v, block_size=bs)
    want = ref.value_magnitude_ref(v, bs)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=1e-5 if dtype == jnp.float32 else 3e-2, rtol=3e-2,
    )


def test_kernel_vmem_budget_static():
    """Static check: the declared VMEM working set fits a TPU core.

    q + k + v + out tiles + fp32 accumulators, double-buffered inputs —
    must stay well under the ~16 MiB VMEM of a v5e core for every tile
    configuration the configs use.
    """
    VMEM = 16 * 1024 * 1024
    for bs, d, in_bytes in [(128, 128, 2), (128, 256, 2), (128, 64, 4)]:
        tiles = 2 * (bs * d * in_bytes) * 2      # k + v, double buffered
        tiles += bs * d * in_bytes               # q
        tiles += bs * d * in_bytes               # out
        tiles += bs * d * 4 + 2 * bs * 4         # fp32 acc + m + l scratch
        assert tiles < 0.25 * VMEM, (bs, d, tiles)
