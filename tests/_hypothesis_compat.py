"""Fixed-seed fallback for ``hypothesis`` (see requirements-dev.txt).

When hypothesis is installed the property tests use it directly; when it is
absent (e.g. the minimal CI image) this module provides API-compatible
``given`` / ``settings`` / ``st`` shims that degrade each property test to a
deterministic, fixed-seed parametrized sample — the properties still run,
just over 25 pseudo-random cases instead of an adaptive search.
"""
from __future__ import annotations

import random

import pytest

N_EXAMPLES = 25
_SEED = 0x5EED


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(lo, hi))


def _floats(lo: float, hi: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(lo, hi))


class st:
    """Namespace mimicking ``hypothesis.strategies`` (the subset we use)."""

    integers = staticmethod(_integers)
    floats = staticmethod(_floats)


def settings(**_kwargs):
    def deco(fn):
        return fn

    return deco


def given(**strategies):
    """Parametrize over N_EXAMPLES fixed-seed draws from the strategies."""
    names = list(strategies)

    def deco(fn):
        rng = random.Random(_SEED)
        cases = [
            tuple(strategies[n].draw(rng) for n in names)
            for _ in range(N_EXAMPLES)
        ]
        return pytest.mark.parametrize(",".join(names), cases)(fn)

    return deco
