"""Fault tolerance: kill -> restart -> bit-identical continuation; elastic
reshard across meshes; straggler + failure injection in the real driver."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import train as train_mod
from repro.runtime import FailureInjector, InjectedFailure, run_with_restarts


def _run(argv):
    return train_mod.main(argv)


def test_train_smoke_and_loss_decreases(tmp_path):
    out = _run(["--arch", "qwen3-0.6b", "--reduced", "--steps", "12",
                "--batch", "4", "--seq", "64",
                "--checkpoint-dir", str(tmp_path / "ckpt"),
                "--checkpoint-every", "4", "--lr", "1e-2"])
    losses = out["losses"]
    assert len(losses) == 12
    assert all(np.isfinite(l) for l in losses)
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_restart_is_bit_identical(tmp_path):
    """Uninterrupted run == (run to step 8, die, restore at 8, continue).

    This is the core fault-tolerance contract: atomic checkpoints + O(1)
    seekable data mean a restarted job replays nothing and diverges nowhere.
    """
    a = str(tmp_path / "a")
    ref = _run(["--arch", "qwen3-0.6b", "--reduced", "--steps", "10",
                "--batch", "4", "--seq", "64", "--checkpoint-dir", a,
                "--checkpoint-every", "100", "--lr", "1e-2"])

    b = str(tmp_path / "b")
    first = _run(["--arch", "qwen3-0.6b", "--reduced", "--steps", "8",
                  "--batch", "4", "--seq", "64", "--checkpoint-dir", b,
                  "--checkpoint-every", "8", "--lr", "1e-2"])
    second = _run(["--arch", "qwen3-0.6b", "--reduced", "--steps", "10",
                   "--batch", "4", "--seq", "64", "--checkpoint-dir", b,
                   "--restore", "--checkpoint-every", "100", "--lr", "1e-2"])
    np.testing.assert_allclose(ref["losses"][8:], second["losses"],
                               rtol=1e-5, atol=1e-6)


def test_injected_failure_with_restart_harness(tmp_path):
    """The restart harness re-runs the driver after an injected node
    failure; the checkpoint makes the retry resume, not restart."""
    ckpt = str(tmp_path / "ckpt")
    calls = []

    def attempt():
        calls.append(1)
        restore = ["--restore"] if len(calls) > 1 else []
        return _run(["--arch", "qwen3-0.6b", "--reduced", "--steps", "10",
                     "--batch", "4", "--seq", "64", "--checkpoint-dir", ckpt,
                     "--checkpoint-every", "4", "--lr", "1e-2",
                     "--fail-at", "6" if len(calls) == 1 else "-1"] + restore)

    out = run_with_restarts(attempt, max_restarts=2)
    assert len(calls) == 2
    # restart resumed from step 4's checkpoint: 6 more steps (4..9)
    assert len(out["losses"]) == 6


def test_failure_injector_fires_once():
    inj = FailureInjector((3,))
    inj.maybe_fail(2)
    with pytest.raises(InjectedFailure):
        inj.maybe_fail(3)
    inj.maybe_fail(3)   # second pass (post-restart) sails through


def test_elastic_reshard_between_meshes(tmp_path):
    """Checkpoint written under one mesh restores onto a different mesh —
    the elastic-scaling path (pod count changed between runs)."""
    from repro import configs, optim
    from repro.checkpoint import CheckpointManager
    from repro.launch import mesh as mesh_lib, steps as steps_lib
    from repro.models import registry
    from repro.sharding import rules as rules_lib

    cfg = configs.reduced(configs.get_config("qwen3-0.6b")).replace(dtype="float32")
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, params)

    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    abstract_values, axes = bundle.abstract_params()
    sh = rules_lib.param_shardings(cfg, mesh, abstract_values, axes)
    restored, meta = mgr.restore(abstract_values, shardings=sh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
