"""SSD (mamba2) and RG-LRU mixers vs naive recurrence oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ArchConfig, RGLRUConfig, SSDConfig
from repro.models import common, rglru, ssd


def _ssd_cfg(chunk=8):
    return ArchConfig(
        name="t", family="ssm", num_layers=1, d_model=16, num_heads=4,
        num_kv_heads=4, head_dim=8, d_ff=0, vocab_size=16,
        ssd=SSDConfig(state_dim=8, head_dim=8, expand=2, conv_width=4,
                      chunk_size=chunk))


def test_ssd_chunked_matches_naive_recurrence():
    """The chunked SSD algorithm must equal the O(N) per-step recurrence."""
    cfg = _ssd_cfg(chunk=8)
    ini = common.Initializer(jax.random.PRNGKey(0), jnp.float32)
    params = common.unzip(ssd.init(ini, cfg))[0]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16), jnp.float32)

    full = ssd.apply_full(params, x, cfg)

    # naive: decode step by step from the initial state
    state = ssd.init_state(cfg, 2, jnp.float32)
    outs = []
    for t in range(32):
        o, state = ssd.apply_decode(params, x[:, t : t + 1], cfg, state)
        outs.append(o)
    naive = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(naive),
                               rtol=2e-3, atol=2e-4)


def test_ssd_chunk_size_invariance():
    cfg8, cfg16 = _ssd_cfg(8), _ssd_cfg(16)
    ini = common.Initializer(jax.random.PRNGKey(2), jnp.float32)
    params = common.unzip(ssd.init(ini, cfg8))[0]
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 16), jnp.float32)
    y8 = ssd.apply_full(params, x, cfg8)
    y16 = ssd.apply_full(params, x, cfg16)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), rtol=1e-4, atol=1e-5)


def test_ssd_prefill_state_continues():
    cfg = _ssd_cfg(8)
    ini = common.Initializer(jax.random.PRNGKey(4), jnp.float32)
    params = common.unzip(ssd.init(ini, cfg))[0]
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 24, 16), jnp.float32)
    full = ssd.apply_full(params, x, cfg)
    _, state = ssd.prefill_into_state(params, x[:, :16], cfg)
    outs = []
    for t in range(16, 24):
        o, state = ssd.apply_decode(params, x[:, t : t + 1], cfg, state)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full[:, 16:]), rtol=2e-3, atol=2e-4)


def _rg_cfg():
    return ArchConfig(
        name="t", family="hybrid", num_layers=3, d_model=16, num_heads=2,
        num_kv_heads=1, head_dim=8, d_ff=32, vocab_size=16,
        rglru=RGLRUConfig(lru_width=16, conv_width=4, attn_period=3, window=8))


def test_rglru_scan_matches_stepwise():
    cfg = _rg_cfg()
    ini = common.Initializer(jax.random.PRNGKey(6), jnp.float32)
    params = common.unzip(rglru.init(ini, cfg))[0]
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 20, 16), jnp.float32)
    full = rglru.apply_full(params, x, cfg)
    state = rglru.init_state(cfg, 2)
    outs = []
    for t in range(20):
        o, state = rglru.apply_decode(params, x[:, t : t + 1], cfg, state)
        outs.append(o)
    naive = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(naive),
                               rtol=2e-3, atol=2e-4)


def test_rglru_decay_bounded():
    """|a_t| < 1 always — the recurrence cannot blow up."""
    cfg = _rg_cfg()
    ini = common.Initializer(jax.random.PRNGKey(8), jnp.float32)
    params = common.unzip(rglru.init(ini, cfg))[0]
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 64, 16)) * 10.0
    xb = jnp.einsum("bsd,dw->bsw", x, params["w_in"])
    a, _ = rglru._gates(params, xb)
    assert float(a.max()) <= 1.0   # r -> 0 gives a = exp(0) = 1 exactly
    assert float(a.min()) >= 0.0
    assert float(a.mean()) < 1.0


def test_local_attention_window_exact():
    """Banded local attention == dense attention with a window mask."""
    from repro.models.attention import local_attention
    from repro.core.sparse_attention import dense_attention
    b, h, n, d, w = 1, 2, 64, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    q = jax.random.normal(ks[0], (b, h, n, d))
    k = jax.random.normal(ks[1], (b, h, n, d))
    v = jax.random.normal(ks[2], (b, h, n, d))
    got = local_attention(q, k, v, w)
    qi = jnp.arange(n)[:, None]
    kj = jnp.arange(n)[None, :]
    mask = jnp.broadcast_to((kj <= qi) & (kj > qi - w), (b, h, n, n))
    want = dense_attention(q, k, v, causal=True, mask=mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)
