"""MoE dispatch correctness: capacity semantics vs a naive per-token oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models import common, moe


def _setup(seed, b, s, d, e, k, cap_factor=8.0, **kw):
    cfg = MoEConfig(num_experts=e, top_k=k, expert_d_ff=16,
                    capacity_factor=cap_factor, **kw)
    ini = common.Initializer(jax.random.PRNGKey(seed), jnp.float32)
    params = common.unzip(moe.init(ini, d, cfg, "silu"))[0]
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, d), jnp.float32)
    return cfg, params, x


def _oracle(params, x, cfg):
    """Per-token loop: every token goes through its top-k experts (no
    capacity drops — compare with a huge capacity_factor)."""
    b, s, d = x.shape
    logits = np.einsum("bsd,de->bse", np.asarray(x, np.float64),
                       np.asarray(params["router"], np.float64))
    gates = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    w, e_idx = jax.lax.top_k(gates, cfg.top_k)
    w = np.asarray(w / w.sum(-1, keepdims=True))
    e_idx = np.asarray(e_idx)
    wg = np.asarray(params["w_gate"], np.float64)
    wu = np.asarray(params["w_up"], np.float64)
    wd = np.asarray(params["w_down"], np.float64)
    xx = np.asarray(x, np.float64)
    out = np.zeros_like(xx)
    for bi in range(b):
        for si in range(s):
            for kk in range(cfg.top_k):
                ee = e_idx[bi, si, kk]
                h = xx[bi, si] @ wg[ee]
                h = h / (1 + np.exp(-h))          # silu
                u = xx[bi, si] @ wu[ee]
                out[bi, si] += w[bi, si, kk] * ((h * u) @ wd[ee])
    return out


def test_moe_matches_per_token_oracle():
    cfg, params, x = _setup(0, 2, 16, 8, 4, 2)
    y, aux = moe.apply(params, x, cfg, "silu")
    want = _oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_capacity_drops_tokens():
    """With capacity_factor ~0, almost everything is dropped -> tiny output."""
    cfg, params, x = _setup(1, 1, 32, 8, 4, 1, cap_factor=0.01)
    y, _ = moe.apply(params, x, cfg, "silu")
    cfg_big, params, x = _setup(1, 1, 32, 8, 4, 1, cap_factor=100.0)
    y_big, _ = moe.apply(params, x, cfg_big, "silu")
    assert float(jnp.abs(y).sum()) < float(jnp.abs(y_big).sum())


def test_shared_and_residual_branches():
    cfg, params, x = _setup(2, 1, 8, 8, 4, 2, shared_experts=1, shared_d_ff=16)
    y, _ = moe.apply(params, x, cfg, "silu")
    assert y.shape == x.shape
    cfg2, params2, x2 = _setup(3, 1, 8, 8, 4, 2, residual_dense=True, residual_d_ff=16)
    y2, _ = moe.apply(params2, x2, cfg2, "silu")
    assert y2.shape == x2.shape


def test_grads_flow():
    cfg, params, x = _setup(4, 2, 8, 8, 4, 2)

    def f(p):
        y, aux = moe.apply(p, x, cfg, "silu")
        return (y ** 2).mean() + aux

    g = jax.grad(f)(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["w_gate"]).max()) > 0
