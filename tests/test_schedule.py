"""Property tests for the Token Position-Decay schedule (Eq. 2/3/4)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to fixed-seed parametrized sampling
    from _hypothesis_compat import given, settings, st

from repro.core import config as config_lib
from repro.core import schedule


@given(
    seq_len=st.integers(64, 8192),
    k_start=st.integers(1, 2048),
    mu=st.floats(0.05, 1.0),
)
@settings(max_examples=200, deadline=None)
def test_token_budget_monotone_decay(seq_len, k_start, mu):
    k = schedule.tpd_budget_tokens(seq_len, k_start, mu)
    assert k.shape == (seq_len,)
    assert (np.diff(k) <= 0).all(), "budgets must be non-increasing in position"
    assert k[0] == max(k_start, 1)
    # Eq. 3 endpoint: k(N-1) ~ mu * k_start (within one floor step).
    expected_end = k_start - k_start * (1.0 - mu) * (seq_len - 1) / seq_len
    assert abs(int(k[-1]) - expected_end) <= 1.0


@given(
    nq=st.integers(1, 512),
    k_start=st.integers(1, 256),
    mu=st.floats(0.1, 1.0),
    min_budget=st.integers(0, 64),
)
@settings(max_examples=200, deadline=None)
def test_block_budget_bounds(nq, k_start, mu, min_budget):
    b = schedule.tpd_budget_blocks(nq, nq, k_start, mu, min_budget_blocks=min_budget)
    admissible = np.arange(1, nq + 1)
    assert (b <= admissible).all(), "can't exceed causally admissible blocks"
    floor = np.minimum(np.maximum(1, min_budget), admissible)
    assert (b >= floor).all(), "per-row floor must hold"
    assert b.dtype == np.int32


@given(
    seq_len=st.integers(256, 16384),
    k_start=st.integers(16, 1024),
    mu=st.floats(0.3, 1.0),
)
@settings(max_examples=100, deadline=None)
def test_cost_model_eq4_matches_measured(seq_len, k_start, mu):
    """Eq. (4) should approximate the exact computed-pair count in the
    paper's operating regime (k_start <= ~0.2 N; the approximation ignores
    the interaction between the causal triangle and the decay, which only
    matters for very large k_start/N)."""
    k_start = min(k_start, seq_len // 5)
    measured = schedule.measured_cost_tokens(seq_len, k_start, mu)
    analytic = schedule.cost_decay(seq_len, k_start, mu)
    assert measured <= schedule.cost_uniform(seq_len, k_start) + k_start
    rel = abs(measured - analytic) / max(analytic, 1.0)
    # Two approximation sources: the dropped triangle-decay interaction
    # (~ (1-mu) k_start/N) and Eq. 3's floor() (~ 1 token/row -> ~ 1/k_avg).
    k_avg = max(k_start * (1.0 + mu) / 2.0, 1.0)
    bound = (1.0 - mu) * k_start / seq_len + 1.0 / k_avg + 0.005
    assert rel < bound, (measured, analytic, rel, bound)


def test_decay_saves_vs_uniform():
    """Eq. (4)'s savings term: decay must be cheaper than uniform@k_start."""
    for mu in (0.5, 0.7, 0.9):
        c_dec = schedule.measured_cost_tokens(8192, 1024, mu)
        c_uni = schedule.measured_cost_tokens(8192, 1024, 1.0)
        assert c_dec < c_uni
        # savings grow as mu shrinks
    s = [
        schedule.measured_cost_tokens(8192, 1024, 1.0)
        - schedule.measured_cost_tokens(8192, 1024, mu)
        for mu in (0.9, 0.7, 0.5)
    ]
    assert s[0] < s[1] < s[2]


def test_uniform_equivalent_budget_matches_paper():
    """Table 5 setup: k_uni = k_start (1+mu)/2; mu=0.7 -> 0.85 k_start."""
    assert config_lib.uniform_equivalent_budget(100, 0.7) == 85
    assert config_lib.uniform_equivalent_budget(64, 1.0) == 64


def test_paper_length_rule():
    cfg = config_lib.StemConfig()
    assert cfg.k_start_fraction(8192) == 0.2
    assert cfg.k_start_fraction(16384) == 0.2
    assert cfg.k_start_fraction(32768) == 0.1
    # 32k: N_blk = 256 -> k_start 25 blocks, floored later by min budget 54.
    assert cfg.k_start_blocks(32768) == 25


def test_schedule_for_respects_min_budget():
    cfg = config_lib.StemConfig(block_size=128, min_budget_blocks=54)
    b = schedule.schedule_for(cfg, 32768)
    assert b.shape == (256,)
    assert b[-1] >= 54
    assert b[0] == 1  # causal clamp at the first row
    assert int(b.max()) <= 256


def test_decode_shapes_use_kv_offset():
    """Decode: 1 query block against a long cache — all budgets clamp to nk."""
    cfg = config_lib.StemConfig(block_size=128, min_budget_blocks=4, k_start_frac=0.5)
    b = schedule.schedule_for(cfg, 128, kv_len=4096)
    assert b.shape == (1,)
    assert 1 <= int(b[0]) <= 32
