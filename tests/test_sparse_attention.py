"""Integration tests: Stem attention end-to-end vs dense, across executors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StemConfig, dense_attention, stem_attention
from repro.core.baselines import baseline_attention


def _qkv(seed, b, hq, hk, n, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, n, d), dtype)
    k = jax.random.normal(ks[1], (b, hk, n, d), dtype)
    v = jax.random.normal(ks[2], (b, hk, n, d), dtype)
    return q, k, v


def _structured_qkv(seed, b, h, n, d):
    """QKV with realistic attention structure: a sink token, a few heavy
    hitters, and locally-correlated queries — the regime the paper targets."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, h, n, d))
    k = jax.random.normal(ks[1], (b, h, n, d)) * 0.3
    v = jax.random.normal(ks[2], (b, h, n, d))
    # sink: every query aligns with key 0
    shared = jax.random.normal(ks[3], (b, h, 1, d))
    k = k.at[:, :, 0:1].set(shared * 2.0)
    q = q + shared * 1.5
    # heavy hitters: keys at a few positions carry large values
    hot = jnp.arange(0, n, max(1, n // 7))
    v = v.at[:, :, hot].multiply(8.0)
    k = k.at[:, :, hot].add(jax.random.normal(ks[3], (b, h, len(hot), d)) * 0.5)
    return q, k, v


@pytest.mark.parametrize("hq,hk", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_executors_agree(hq, hk, dtype):
    """The xla gather executor and the dense-oracle executor implement the
    same selection — outputs must match to numerical tolerance."""
    q, k, v = _qkv(0, 2, hq, hk, 512, 32, dtype)
    base = dict(block_size=64, k_start_frac=0.5, mu=0.7, sink_blocks=1,
                local_blocks=1, min_budget_blocks=2, stride=8)
    o_x = stem_attention(q, k, v, StemConfig(backend="xla", **base))
    o_d = stem_attention(q, k, v, StemConfig(backend="dense", **base))
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o_x, np.float32), np.asarray(o_d, np.float32), atol=tol, rtol=tol
    )


def test_full_budget_equals_dense():
    """With budget = 100% and no decay, Stem must reproduce dense attention."""
    q, k, v = _qkv(1, 1, 2, 2, 256, 32)
    cfg = StemConfig(block_size=64, k_start_frac=1.0, mu=1.0, sink_blocks=0,
                     local_blocks=1, min_budget_blocks=0, stride=8)
    o = stem_attention(q, k, v, cfg)
    o_ref = dense_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=3e-6, rtol=3e-6)


def test_error_decreases_with_budget():
    q, k, v = _structured_qkv(2, 2, 4, 1024, 32)
    dense = dense_attention(q, k, v)
    errs = []
    for frac, mu in ((0.125, 0.7), (0.25, 0.7), (0.5, 0.7), (1.0, 1.0)):
        cfg = StemConfig(block_size=64, k_start_frac=frac, mu=mu, sink_blocks=1,
                         local_blocks=1, min_budget_blocks=1, stride=8)
        o = stem_attention(q, k, v, cfg)
        errs.append(float(jnp.mean((o - dense) ** 2)))
    assert errs[-1] < 1e-8
    assert errs[0] > errs[2] > errs[-1], errs


def test_oam_beats_sam_on_structured_data():
    """Paper Table 1: at a fixed budget, OAM reconstruction error <= SAM
    (structured data where value magnitudes vary across tokens)."""
    q, k, v = _structured_qkv(3, 4, 4, 1024, 32)
    dense = dense_attention(q, k, v)
    base = dict(block_size=64, k_start_frac=0.2, mu=1.0, sink_blocks=1,
                local_blocks=1, min_budget_blocks=1, stride=8)
    e = {}
    for met in ("oam", "sam"):
        o = stem_attention(q, k, v, StemConfig(metric=met, **base))
        e[met] = float(jnp.mean((o - dense) ** 2))
    assert e["oam"] <= e["sam"] * 1.02, e


def test_tpd_beats_uniform_at_matched_budget():
    """Paper Table 5 mechanism proxy: under a *matched total budget*, TPD's
    early-heavy allocation reconstructs early rows better; overall error
    should not be worse than uniform by more than noise, and early-row error
    must be strictly lower."""
    q, k, v = _structured_qkv(4, 2, 4, 2048, 32)
    dense = dense_attention(q, k, v)
    cfg = StemConfig(block_size=64, k_start_frac=0.3, mu=0.6, sink_blocks=1,
                     local_blocks=1, min_budget_blocks=1, stride=8)
    o_tpd = stem_attention(q, k, v, cfg)
    o_uni, _ = baseline_attention(q, k, v, cfg, method="uniform_sam")
    n = q.shape[2]
    early = slice(0, n // 4)
    err_tpd_early = float(jnp.mean((o_tpd[:, :, early] - dense[:, :, early]) ** 2))
    err_uni_early = float(jnp.mean((o_uni[:, :, early] - dense[:, :, early]) ** 2))
    assert err_tpd_early <= err_uni_early + 1e-9, (err_tpd_early, err_uni_early)


def test_stats_sane():
    q, k, v = _qkv(5, 1, 2, 2, 512, 16)
    cfg = StemConfig(block_size=64, k_start_frac=0.4, mu=0.7, sink_blocks=1,
                     local_blocks=1, min_budget_blocks=1, stride=8)
    o, stats = stem_attention(q, k, v, cfg, return_stats=True)
    assert o.shape == q.shape
    assert 0.0 < float(stats.density) <= 1.0
    assert not bool(jnp.isnan(o).any())


def test_no_nan_bf16_long():
    q, k, v = _qkv(6, 1, 2, 1, 2048, 64, jnp.bfloat16)
    cfg = StemConfig(block_size=128, k_start_frac=0.2, mu=0.7, min_budget_blocks=2,
                     sink_blocks=1, local_blocks=1)
    o = stem_attention(q, k, v, cfg)
    assert not bool(jnp.isnan(o.astype(jnp.float32)).any())


def test_baseline_budget_comparability():
    """Realized density of TPD must be below the uniform@k_start baseline —
    the decay savings of Eq. (4)."""
    q, k, v = _qkv(7, 1, 2, 2, 2048, 32)
    cfg = StemConfig(block_size=64, k_start_frac=0.4, mu=0.5, sink_blocks=1,
                     local_blocks=1, min_budget_blocks=1, stride=8)
    _, stats = stem_attention(q, k, v, cfg, return_stats=True)
    _, uni_density = baseline_attention(q, k, v, cfg, method="uniform_sam", k_uni=13)
    assert float(stats.density) < float(uni_density)
