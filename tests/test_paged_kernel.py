"""Differential + contract tests for the fused paged-attention kernels
(kernels/paged_attn.py) against the XLA gather oracle (runtime/paged.py,
core/chunked.py).

Both executors are reached through the public entry points
(``paged_sparse_decode`` / ``chunked_prefill_attention``) with the
``executor`` knob, exactly like the serving engine — so the differential
also pins the ``core/policy.py`` paged-executor registry dispatch.  The
Pallas side runs in interpret mode on CPU CI (kernels/paged_attn.INTERPRET);
the same tests compile to Mosaic on TPU.

Covers the ISSUE matrix: GQA groups {1, 2, 4}, unaligned per-slot cache
lengths (including zero-length trash slots), budget_frac {0.25, 1.0},
shared-prefix page tables (two slots aliasing leading physical pages),
antidiag/mean metric pooling, group_reduce none/mean, and the streaming
(content-free metric) policy.  Plus the decode zero-live-row contract
(TestZeroLiveRows — referenced from ``core/decode.attend_selected``) and
the REPRO_DEBUG_DECODE assert.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to fixed-seed parametrized sampling
    from _hypothesis_compat import given, settings, st

from repro.core import chunked as chunked_lib
from repro.core import decode as decode_lib
from repro.core import policy as policy_lib
from repro.kernels import paged_attn  # noqa: F401  (registers "pallas")
from repro.runtime import paged as paged_lib

BS = 8        # block/page size for all test policies
STRIDE = 4
D = 8         # head dim
HQ = 4        # query heads (hk = HQ // group)
TOL = 1e-4

GROUPS = (1, 2, 4)
FRACS = (0.25, 1.0)


def _policy(name: str = "stem", **updates):
    base = dict(block_size=BS, stride=STRIDE, sink_blocks=1, local_blocks=1,
                min_budget_blocks=2)
    base.update(updates)
    return policy_lib.get_policy(name).with_updates(ignore_missing=True,
                                                    **base)


def test_pallas_executor_registered():
    assert "pallas" in policy_lib.available_paged_executors()
    assert "xla" in policy_lib.available_paged_executors()
    spec = policy_lib.get_paged_executor("pallas")
    assert spec.decode_fn is paged_attn.fused_paged_decode
    assert spec.chunk_fn is paged_attn.fused_paged_chunk


# ---------------------------------------------------------------------------
# Decode lane
# ---------------------------------------------------------------------------

def _decode_pool(rng, lens, hk, npages, pol, shared_prefix=0):
    """Pool + page table for len(lens) slots, npages pages each.  With
    ``shared_prefix=p`` slot 1 aliases slot 0's first p physical pages
    (the prefix cache's copy-on-write layout)."""
    b = len(lens)
    pool = paged_lib.init_pool(1 + b * npages, hk, BS, D, STRIDE)
    pt = np.zeros((b, npages), np.int32)
    kv = []
    for i in range(b):
        ids = 1 + i * npages + np.arange(npages, dtype=np.int32)
        pt[i] = ids
        k = rng.standard_normal((hk, npages * BS, D)).astype(np.float32)
        v = rng.standard_normal((hk, npages * BS, D)).astype(np.float32)
        kv.append((k, v))
    if shared_prefix:
        # identical prefix content, then alias the physical pages
        kv[1][0][:, : shared_prefix * BS] = kv[0][0][:, : shared_prefix * BS]
        kv[1][1][:, : shared_prefix * BS] = kv[0][1][:, : shared_prefix * BS]
        pt[1, :shared_prefix] = pt[0, :shared_prefix]
    for i in range(b):
        pool = paged_lib.write_prefill_pages(
            pool, jnp.asarray(pt[i]), jnp.asarray(kv[i][0]),
            jnp.asarray(kv[i][1]), jnp.asarray(int(lens[i]), jnp.int32), pol)
    return pool, jnp.asarray(pt)


def _decode_diff(group, lens, budget_frac, policy_name="stem", seed=0,
                 npages=4, shared_prefix=0):
    hk = HQ // group
    rng = np.random.default_rng(seed)
    pol = _policy(policy_name)
    pool, pt = _decode_pool(rng, lens, hk, npages, pol,
                            shared_prefix=shared_prefix)
    q = jnp.asarray(
        rng.standard_normal((len(lens), HQ, 1, D)).astype(np.float32))
    lens_a = jnp.asarray(lens, jnp.int32)
    ref = paged_lib.paged_sparse_decode(q, pool, pt, lens_a, pol,
                                        budget_frac, executor="xla")
    out = paged_lib.paged_sparse_decode(q, pool, pt, lens_a, pol,
                                        budget_frac, executor="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=TOL, rtol=0)
    return np.asarray(out), lens


@settings(max_examples=20, deadline=None)
@given(gi=st.integers(0, 2), fi=st.integers(0, 1),
       l0=st.integers(0, 32), l1=st.integers(0, 32),
       seed=st.integers(0, 1 << 16))
def test_decode_fused_matches_xla(gi, fi, l0, l1, seed):
    """Fused decode == XLA gather decode, per-slot ragged cache lengths
    (any alignment, including empty slots), both budget fractions, all
    GQA groups."""
    _decode_diff(GROUPS[gi], [l0, l1], FRACS[fi], seed=seed)


@pytest.mark.parametrize("group", GROUPS)
@pytest.mark.parametrize("budget_frac", FRACS)
def test_decode_shared_prefix_pages(group, budget_frac):
    """Two slots whose page tables alias the same leading physical pages
    (prefix-cache CoW): the kernel's scalar-prefetched indirection must
    fetch the shared pages for both rows."""
    _decode_diff(group, [29, 23], budget_frac, seed=7, shared_prefix=2)


def test_decode_streaming_policy():
    """Content-free metric: the fused path skips the scoring kernel and
    feeds a zero metric into the same selection — still must match."""
    _decode_diff(2, [17, 32, 5], 1.0, policy_name="streaming", seed=3)


class _OddMetric:
    """Behaves like RoutingMetric without being an instance of any class
    the kernel classifies — forces the full-XLA fallback branch."""

    stride = STRIDE

    def __init__(self):
        self._inner = policy_lib.RoutingMetric(stride=STRIDE)

    def prefill_scores(self, q, k, v, *, block_size):
        return self._inner.prefill_scores(q, k, v, block_size=block_size)

    def decode_scores(self, q, k_groups, v_mag):
        return self._inner.decode_scores(q, k_groups, v_mag)

    def chunk_scores(self, q, k_groups, v_mag, *, block_size):
        return self._inner.chunk_scores(q, k_groups, v_mag,
                                        block_size=block_size)


def test_decode_unsupported_metric_falls_back():
    """A metric class the kernel does not know routes to the XLA oracle
    inside the fused entry point (no crash, identical output)."""
    base = _policy()
    pol = base.__class__(metric=_OddMetric(), schedule=base.schedule,
                         selector=base.selector, block_size=BS, name="odd")
    assert paged_attn._metric_kind(pol.metric) is None
    rng = np.random.default_rng(0)
    pool, pt = _decode_pool(rng, [19, 11], 2, 4, pol)
    q = jnp.asarray(rng.standard_normal((2, HQ, 1, D)).astype(np.float32))
    lens = jnp.asarray([19, 11], jnp.int32)
    ref = paged_lib.paged_sparse_decode(q, pool, pt, lens, pol, 1.0,
                                        executor="xla")
    out = paged_lib.paged_sparse_decode(q, pool, pt, lens, pol, 1.0,
                                        executor="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=TOL)


# ---------------------------------------------------------------------------
# Chunk lane
# ---------------------------------------------------------------------------

def _chunk_diff(group, hist_pages, tail, policy_name="stem", seed=0,
                nc=2, pooling=None, group_reduce=None):
    """History pages + one written chunk, differential across executors.
    ``tail``: valid tokens of the chunk (1..nc*BS, any alignment)."""
    hk = HQ // group
    rng = np.random.default_rng(seed)
    updates = {}
    if pooling is not None:
        updates["pooling"] = pooling
    if group_reduce is not None:
        updates["group_reduce"] = group_reduce
    pol = _policy(policy_name, **updates)

    b = 2
    maxp = hist_pages + nc
    chunk = nc * BS
    pool = paged_lib.init_pool(1 + b * maxp, hk, BS, D, STRIDE)
    pt = np.zeros((b, maxp), np.int32)
    start = np.full((b,), hist_pages * BS, np.int32)
    true_len = np.asarray([start[0] + tail,
                           start[1] + max(1, tail - 3)], np.int32)
    for i in range(b):
        ids = 1 + i * maxp + np.arange(maxp, dtype=np.int32)
        pt[i] = ids
        if hist_pages:
            k = rng.standard_normal((hk, hist_pages * BS, D)).astype(np.float32)
            v = rng.standard_normal((hk, hist_pages * BS, D)).astype(np.float32)
            pool = paged_lib.write_prefill_pages(
                pool, jnp.asarray(ids[:hist_pages]), jnp.asarray(k),
                jnp.asarray(v), jnp.asarray(int(start[i]), jnp.int32), pol)
    kc = rng.standard_normal((b, hk, chunk, D)).astype(np.float32)
    vc = rng.standard_normal((b, hk, chunk, D)).astype(np.float32)
    pool = paged_lib.write_chunk_pages(
        pool, jnp.asarray(pt), jnp.asarray(start), jnp.asarray(kc),
        jnp.asarray(vc), jnp.asarray(true_len), pol)

    q = jnp.asarray(rng.standard_normal((b, HQ, chunk, D)).astype(np.float32))
    budgets = np.stack([
        chunked_lib.chunk_budget_rows(pol, maxp * BS, int(start[i]), nc)
        for i in range(b)])
    args = (q, pool, jnp.asarray(pt), jnp.asarray(start),
            jnp.asarray(budgets), pol)
    ref = chunked_lib.chunked_prefill_attention(*args, executor="xla")
    out = chunked_lib.chunked_prefill_attention(*args, executor="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=TOL, rtol=0)


@settings(max_examples=15, deadline=None)
@given(gi=st.integers(0, 2), hist=st.integers(0, 3),
       tail=st.integers(1, 2 * BS), seed=st.integers(0, 1 << 16))
def test_chunk_fused_matches_xla(gi, hist, tail, seed):
    """Fused chunk attention == XLA oracle for any history depth, any
    (unaligned) chunk tail, all GQA groups — in-chunk causal masking and
    history pages both exercised."""
    _chunk_diff(GROUPS[gi], hist, tail, seed=seed)


@pytest.mark.parametrize("group,pooling,group_reduce", [
    (1, "antidiag", None),
    (2, "antidiag", "mean"),
    (4, "mean", None),
])
def test_chunk_pooling_and_group_reduce(group, pooling, group_reduce):
    """Antidiag vs mean query pooling and GQA group_reduce variants route
    through the same kernel scoring + XLA-side reduce as the oracle."""
    _chunk_diff(group, 2, 11, pooling=pooling, group_reduce=group_reduce,
                seed=5)


def test_chunk_routing_metric_policy():
    _chunk_diff(2, 1, 13, policy_name="stem-sam", seed=9)


# ---------------------------------------------------------------------------
# Zero-live-row contract (referenced from core/decode.attend_selected)
# ---------------------------------------------------------------------------

class TestZeroLiveRows:
    """A slot with ``cache_lens == 0`` (trash slot riding in a serving
    batch) selects no live blocks and must return an *exact zero* output
    vector — not NaN, not garbage — on every executor."""

    @pytest.mark.parametrize("executor", ["xla", "pallas"])
    def test_paged_decode_empty_slot_exact_zero(self, executor):
        rng = np.random.default_rng(11)
        pol = _policy()
        lens = [37, 0, 13]
        pool, pt = _decode_pool(rng, lens, 2, 5, pol)
        q = jnp.asarray(rng.standard_normal((3, HQ, 1, D)).astype(np.float32))
        out = np.asarray(paged_lib.paged_sparse_decode(
            q, pool, pt, jnp.asarray(lens, jnp.int32), pol, 0.25,
            executor=executor))
        assert np.all(np.isfinite(out))
        assert np.all(out[1] == 0.0), "empty slot must be exactly zero"
        assert np.any(out[0] != 0.0) and np.any(out[2] != 0.0)

    def test_attend_selected_contract(self):
        """The fixed-batch core path honors the same contract."""
        rng = np.random.default_rng(2)
        pol = _policy()
        L = 4 * BS
        k = jnp.asarray(rng.standard_normal((2, 2, L, D)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((2, 2, L, D)).astype(np.float32))
        q = jnp.asarray(rng.standard_normal((2, HQ, 1, D)).astype(np.float32))
        summ = decode_lib.summarize_cache(k, v, pol)
        out = np.asarray(decode_lib.sparse_decode_attention(
            q, k, v, summ, jnp.asarray([27, 0], jnp.int32), pol, 0.25))
        assert np.all(np.isfinite(out))
        assert np.all(out[1] == 0.0)
        assert np.any(out[0] != 0.0)


class TestDebugAssert:
    """REPRO_DEBUG_DECODE=1 turns the silent-zero failure mode (non-empty
    cache, zero live selection) into a loud AssertionError."""

    def _degenerate_case(self):
        # no forced floors, no minimum budget, budget_frac 0 -> every row
        # with a non-empty cache selects zero live blocks
        pol = _policy(sink_blocks=0, local_blocks=0, min_budget_blocks=0)
        rng = np.random.default_rng(4)
        pool, pt = _decode_pool(rng, [21], HQ, 3, pol)
        q = jnp.asarray(rng.standard_normal((1, HQ, 1, D)).astype(np.float32))
        return q, pool, pt, pol

    def test_fires_on_zero_live_selection(self, monkeypatch):
        monkeypatch.setenv("REPRO_DEBUG_DECODE", "1")
        q, pool, pt, pol = self._degenerate_case()
        with pytest.raises(Exception, match="zero live"):
            out = paged_lib.paged_sparse_decode(
                q, pool, pt, jnp.asarray([21], jnp.int32), pol, 0.0,
                executor="xla")
            jax.block_until_ready(out)

    def test_silent_without_env(self, monkeypatch):
        monkeypatch.delenv("REPRO_DEBUG_DECODE", raising=False)
        q, pool, pt, pol = self._degenerate_case()
        out = np.asarray(paged_lib.paged_sparse_decode(
            q, pool, pt, jnp.asarray([21], jnp.int32), pol, 0.0,
            executor="xla"))
        assert np.all(out == 0.0)  # the documented silent-zero behaviour

    def test_empty_cache_rows_allowed(self, monkeypatch):
        """Trash slots (cache_lens == 0) must NOT trip the assert."""
        monkeypatch.setenv("REPRO_DEBUG_DECODE", "1")
        rng = np.random.default_rng(6)
        pol = _policy()
        pool, pt = _decode_pool(rng, [15, 0], 2, 3, pol)
        q = jnp.asarray(rng.standard_normal((2, HQ, 1, D)).astype(np.float32))
        out = paged_lib.paged_sparse_decode(
            q, pool, pt, jnp.asarray([15, 0], jnp.int32), pol, 0.25,
            executor="pallas")
        jax.block_until_ready(out)  # no raise
