"""Preemption + host offload round-trip tests (runtime/offload.py, engine).

The load-bearing property: preempt -> offload to host -> restore into
*different* physical pages -> resume must be **bit-identical** to the
uninterrupted run — same greedy tokens, same pool contents page-for-page,
zero prefill recompute (no replayed chunks, no extra traces).  Pages carry
their own OAM/SAM selection summaries, which is what makes this possible:
a restored request's selection state is entirely in its pages + the
engine's cursor snapshot.

Property-tested over GQA group sizes and unaligned cache lengths at the
paged-primitive level (cheap), plus full-engine differentials preempting
mid-decode and mid-prefill.
"""
import copy
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to fixed-seed parametrized sampling
    from _hypothesis_compat import given, settings, st

from repro.configs.base import ArchConfig
from repro.core.config import StemConfig
from repro.models import registry
from repro.runtime import offload as offload_lib
from repro.runtime.engine import EngineConfig, Request, StemEngine
from repro.runtime.paged import (PageAllocator, append_token, init_pool,
                                 paged_sparse_decode, reset_pages,
                                 write_prefill_pages)

TINY = ArchConfig(
    name="preempt-tiny", family="dense", num_layers=2, d_model=32,
    num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64,
    qk_norm=True, dtype="float32",
)
STEM = StemConfig(block_size=8, sink_blocks=1, local_blocks=1,
                  min_budget_blocks=2, stride=4)
HK_CHOICES = (1, 2, 4)      # kv heads
GROUP_CHOICES = (1, 2, 4)   # GQA group size (hq = hk * group)


@pytest.fixture(scope="module")
def built():
    bundle = registry.build(TINY)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return bundle, params


def _stack(pool):
    """Single-layer pool -> the engine's stacked-leaf layout (1, hk, P, ...)."""
    return jax.tree.map(lambda x: x[None], pool)


def _unstack(pool):
    return jax.tree.map(lambda x: x[0], pool)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    hk_idx=st.integers(0, len(HK_CHOICES) - 1),
    group_idx=st.integers(0, len(GROUP_CHOICES) - 1),
    true_len=st.integers(1, 3 * STEM.block_size),  # includes unaligned lengths
)
def test_offload_roundtrip_property(seed, hk_idx, group_idx, true_len):
    """gather -> host -> scatter into *different* pages reproduces the pool
    bitwise, and decode + incremental growth off the restored pages is
    bit-identical to the uninterrupted pool — across GQA group sizes and
    cache lengths that end mid-page."""
    hk, group, d = HK_CHOICES[hk_idx], GROUP_CHOICES[group_idx], 8
    npages_req, n_pages, maxp = 3, 8, 4
    L = npages_req * STEM.block_size
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    k = jax.random.normal(ks[0], (hk, L, d))
    v = jax.random.normal(ks[1], (hk, L, d))
    q = jax.random.normal(ks[2], (1, hk * group, 1, d))

    pages_a, pages_b = [2, 5, 3], [6, 1, 4]      # deliberately different ids
    row = lambda pages: jnp.asarray(pages + [0] * (maxp - len(pages)))
    table = lambda pages: row(pages)[None]

    pool_a = write_prefill_pages(
        init_pool(n_pages, hk, STEM.block_size, d, STEM.stride),
        jnp.asarray(pages_a), k, v, jnp.asarray(true_len), STEM)

    # Preempt: snapshot, evict (pool pages go back to pristine for reuse),
    # restore into a different set of physical pages of a fresh pool.
    snap = jax.tree.map(lambda x: np.asarray(x),
                        offload_lib.gather_pages(_stack(pool_a), row(pages_a)))
    evicted = reset_pages(pool_a, jnp.asarray(pages_a))        # device reuse
    pool_b = _unstack(offload_lib.scatter_pages(
        _stack(init_pool(n_pages, hk, STEM.block_size, d, STEM.stride)),
        row(pages_b), snap))

    # Page-for-page: gathering the restored pages returns the snapshot bitwise.
    back = offload_lib.gather_pages(_stack(pool_b), row(pages_b))
    for got, want, name in zip(jax.tree.leaves(back), jax.tree.leaves(snap),
                               ("k", "v", "kg", "vm")):
        assert np.array_equal(np.asarray(got), want), f"{name} not bitwise"

    # Decode off the restored pages == decode off the original pool, bitwise.
    lens = jnp.asarray([true_len], jnp.int32)
    out_a = paged_sparse_decode(q, write_prefill_pages(
        evicted, jnp.asarray(pages_a), k, v, jnp.asarray(true_len), STEM),
        table(pages_a), lens, STEM, budget_frac=0.5)
    out_b = paged_sparse_decode(q, pool_b, table(pages_b), lens, STEM,
                                budget_frac=0.5)
    assert np.array_equal(np.asarray(out_a), np.asarray(out_b))

    # Incremental growth continues seamlessly mid-page after the swap.
    if true_len < L:
        kn = jax.random.normal(ks[0], (1, hk, 1, d))
        vn = jax.random.normal(ks[1], (1, hk, 1, d))
        grown = append_token(pool_b, table(pages_b), lens, kn, vn, STEM)
        ref = append_token(
            write_prefill_pages(
                init_pool(n_pages, hk, STEM.block_size, d, STEM.stride),
                jnp.asarray(pages_a), k, v, jnp.asarray(true_len), STEM),
            table(pages_a), lens, kn, vn, STEM)
        got = offload_lib.gather_pages(_stack(grown), row(pages_b))
        want = offload_lib.gather_pages(_stack(ref), row(pages_a))
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            assert np.array_equal(np.asarray(g), np.asarray(w))


def _ecfg(max_slots, plen, mnt, **kw):
    per_slot = -(-(plen + mnt) // STEM.block_size)
    return EngineConfig(max_slots=max_slots,
                        num_pages=1 + max_slots * per_slot,
                        max_pages_per_slot=per_slot, **kw)


@pytest.mark.parametrize("preempt_after", [1, 4])  # mid-prefill / mid-decode
def test_engine_preempt_restore_differential(built, preempt_after):
    """Full-engine differential: force a preemption (mid-prefill at step 1
    with a 20-token prompt; mid-decode at step 4), drain, and require the
    run to be indistinguishable from an uninterrupted one — identical
    greedy tokens, identical chunk/prefill work (zero recompute), no extra
    traces, restored pages bitwise equal to the offloaded snapshot."""
    bundle, params = built
    rng = np.random.RandomState(17)
    req = Request(uid=0,
                  prompt=rng.randint(0, TINY.vocab_size, size=(20,)).astype(np.int32),
                  max_new_tokens=8)
    ecfg = _ecfg(1, 20, 8, budget_frac=0.5)

    ref_eng = StemEngine(bundle, params, STEM, ecfg)
    ref = ref_eng.run([Request(uid=0, prompt=req.prompt, max_new_tokens=8)])[0]

    eng = StemEngine(bundle, params, STEM, ecfg)
    eng.submit(req)
    for _ in range(preempt_after):
        eng.step()
    assert eng.slots[0] is not None
    phase = eng.slots[0].phase
    eng.preempt(0)
    eng.allocator.check_conservation([])           # all pages free while out
    assert eng.slots[0] is None and len(eng.preempted) == 1
    snap_host = copy.deepcopy(eng.host_store.get(0))
    traces_before = eng.stats["traces"]

    # Restore happens at admission; verify page-for-page before the next
    # mixed step advances the slot.
    eng._admit()
    assert eng.slots[0] is not None and not eng.preempted
    assert eng.stats["traces"] == traces_before, "restore retraced the step"
    new_row = jnp.asarray(eng.page_table[0])
    back = jax.tree.map(lambda x: np.asarray(x),
                        eng._extract(eng.pools, new_row))
    for got, want in zip(jax.tree.leaves(back), jax.tree.leaves(snap_host)):
        assert np.array_equal(got, want), "restored pages differ from snapshot"
    eng.allocator.check_conservation(
        [p for pages in eng.slot_pages if pages for p in pages])

    out = eng.run()[0]
    assert out.tokens == ref.tokens, f"preempted ({phase}) run diverged"
    assert out.preemptions == 1 and out.error is None
    # Zero recompute: same chunk count and exactly one prefill completion,
    # and the preempt/restore jits added no unified-step traces.
    assert eng.stats["chunks"] == ref_eng.stats["chunks"]
    assert eng.stats["prefills"] == ref_eng.stats["prefills"] == 1
    assert eng.stats["traces"] == 2
    assert eng.stats["restores"] == 1
    assert len(eng.host_store) == 0
    eng.allocator.check_conservation([])           # drained: no leaks


def test_priority_admission_preempts_lower(built):
    """A high-priority arrival may evict a running lower-priority request
    (slot-blocked case): the victim swaps out, the HP request completes
    first, the victim restores and finishes with its uninterrupted stream."""
    bundle, params = built
    rng = np.random.RandomState(23)
    mk = lambda uid, plen, mnt, **kw: Request(
        uid=uid, prompt=rng.randint(0, TINY.vocab_size, size=(plen,)).astype(np.int32),
        max_new_tokens=mnt, **kw)
    lp = mk(0, 20, 8, priority=0)
    hp = mk(1, 13, 4, priority=1, arrival_step=4)
    ecfg = _ecfg(1, 20, 8)

    ref_lp = StemEngine(bundle, params, STEM, ecfg).run(
        [Request(uid=0, prompt=lp.prompt, max_new_tokens=8)])[0]
    ref_hp = StemEngine(bundle, params, STEM, ecfg).run(
        [Request(uid=1, prompt=hp.prompt, max_new_tokens=4)])[0]

    eng = StemEngine(bundle, params, STEM, ecfg)
    fin = eng.run([lp, hp])
    assert eng.stats["preemptions"] == 1 and eng.stats["restores"] == 1
    assert fin[1].finished_step < fin[0].finished_step, "HP did not jump queue"
    assert fin[0].tokens == ref_lp.tokens
    assert fin[1].tokens == ref_hp.tokens
    assert fin[0].preemptions == 1 and fin[1].preemptions == 0
    # Swapped-out time shows up in the victim's inter-token gaps, not the
    # winner's; its TTFT was set before eviction and stays.
    eng.allocator.check_conservation([])


def test_preemption_disabled_keeps_fcfs_order(built):
    """With preemption off (or the fcfs scheduler), a high-priority arrival
    waits like anyone else — no eviction, single admission order."""
    bundle, params = built
    rng = np.random.RandomState(29)
    lp = Request(uid=0, prompt=rng.randint(0, 64, size=(20,)).astype(np.int32),
                 max_new_tokens=8, priority=0)
    hp = Request(uid=1, prompt=rng.randint(0, 64, size=(13,)).astype(np.int32),
                 max_new_tokens=4, priority=1, arrival_step=4)
    for kw in ({"preemption": False}, {"scheduler": "fcfs"}):
        eng = StemEngine(bundle, params, STEM, _ecfg(1, 20, 8, **kw))
        fin = eng.run([dataclasses.replace(lp), dataclasses.replace(hp)])
        assert eng.stats["preemptions"] == 0
        assert fin[0].finished_step < fin[1].finished_step
        eng.allocator.check_conservation([])


def test_preemption_victim_minimizes_restore_cost(built):
    """Victim choice is a cost model, not just recency: among the lowest
    strictly-lower priority class the engine evicts the request with the
    fewest PRIVATE pages — the cheapest host round-trip (shared prefix
    pages never move).  Pinned: small request in slot 0 and big request in
    slot 1, both priority 0 and admitted the same step; a pure recency/slot
    tie-break would evict slot 1, the cost model must evict slot 0."""
    bundle, params = built
    rng = np.random.RandomState(31)
    small = Request(uid=0, prompt=rng.randint(0, 64, size=(5,)).astype(np.int32),
                    max_new_tokens=3)                    # 1 page
    big = Request(uid=1, prompt=rng.randint(0, 64, size=(20,)).astype(np.int32),
                  max_new_tokens=8)                      # 4 pages
    hp = Request(uid=2, prompt=rng.randint(0, 64, size=(13,)).astype(np.int32),
                 max_new_tokens=4, priority=1, arrival_step=2)
    per_slot = -(-28 // STEM.block_size)
    ecfg = EngineConfig(max_slots=2, num_pages=1 + 3 * per_slot,
                        max_pages_per_slot=per_slot)

    refs = {}
    for r in (small, big, hp):
        solo = StemEngine(bundle, params, STEM, ecfg)
        refs[r.uid] = solo.run([Request(uid=r.uid, prompt=r.prompt,
                                        max_new_tokens=r.max_new_tokens)])[0]

    eng = StemEngine(bundle, params, STEM, ecfg)
    fin = eng.run([dataclasses.replace(small), dataclasses.replace(big),
                   dataclasses.replace(hp)])
    assert eng.stats["preemptions"] == 1 and eng.stats["restores"] == 1
    assert fin[0].preemptions == 1, \
        "victim was not the cheapest-restore (fewest private pages) request"
    assert fin[1].preemptions == 0
    for f in fin:
        assert f.tokens == refs[f.uid].tokens and f.error is None
    eng.allocator.check_conservation([])


def test_restore_cost_model_prices_bytes_over_bandwidth(built):
    """The victim cost is host->device BYTES over a measured-bandwidth
    EMA, not a page count: ``_restore_cost_s`` must be exactly
    ``private_pages * page_nbytes / bandwidth`` (monotone in private
    pages, so the ordering pin above is implied), the EMA must be seeded
    before any measurement and populated after a real preempt/restore
    cycle, and the moved bytes must be accounted in stats/metrics."""
    bundle, params = built
    rng = np.random.RandomState(31)
    small = Request(uid=0, prompt=rng.randint(0, 64, size=(5,)).astype(np.int32),
                    max_new_tokens=3)
    big = Request(uid=1, prompt=rng.randint(0, 64, size=(20,)).astype(np.int32),
                  max_new_tokens=8)
    hp = Request(uid=2, prompt=rng.randint(0, 64, size=(13,)).astype(np.int32),
                 max_new_tokens=4, priority=1, arrival_step=2)
    per_slot = -(-28 // STEM.block_size)
    ecfg = EngineConfig(max_slots=2, num_pages=1 + 3 * per_slot,
                        max_pages_per_slot=per_slot)
    eng = StemEngine(bundle, params, STEM, ecfg)
    assert eng._page_nbytes > 0
    assert eng._h2d_bw_ema is None          # unmeasured: seed bandwidth

    eng.submit(dataclasses.replace(small))
    eng.submit(dataclasses.replace(big))
    eng.step(); eng.step()
    s_small = next(s for s, st in enumerate(eng.slots) if st.req.uid == 0)
    s_big = next(s for s, st in enumerate(eng.slots) if st.req.uid == 1)
    n_small = len([p for p in eng.slot_pages[s_small] if p != 0])
    n_big = len([p for p in eng.slot_pages[s_big] if p != 0])
    assert n_big > n_small
    for s, n in ((s_small, n_small), (s_big, n_big)):
        assert eng._restore_cost_s(s) == pytest.approx(
            n * eng._page_nbytes / eng._BW_SEED)
    assert eng._restore_cost_s(s_small) < eng._restore_cost_s(s_big)

    eng.submit(dataclasses.replace(hp))
    fin = eng.run()
    assert eng.stats["preemptions"] == 1 and eng.stats["restores"] == 1
    assert all(f.error is None for f in fin)
    # the round-trip measured real bandwidth and accounted the bytes
    assert eng._h2d_bw_ema is not None and eng._h2d_bw_ema > 0
    assert eng.metrics["h2d_bw_bytes_per_s"] == eng._h2d_bw_ema
    assert any(f.preemptions == 1 for f in fin)
    assert eng.stats["restore_bytes"] > 0
    assert eng.stats["restore_bytes"] % eng._page_nbytes == 0
    eng.allocator.check_conservation([])


def test_allocator_evict_restore_conservation():
    a = PageAllocator(8)
    held = a.alloc(3)
    other = a.alloc(2)
    a.check_conservation(held + other)
    a.evict(held)                       # preempt: pages back to the free list
    a.check_conservation(other)
    back = a.restore(3)                 # re-admission draws a fresh set
    a.check_conservation(other + back)
    assert a.evictions == 1 and a.restores == 1
    a.free(back)
    a.free(other)
    a.check_conservation([])
