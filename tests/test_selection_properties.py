"""Property-based invariants of the decode-side block selection.

``core.decode.select_decode_blocks`` feeds both the contiguous sparse
decode and the paged engine, so its invariants are load-bearing for
serving correctness:

  1. forced sink + local blocks are always among the live selected set;
  2. the live block count never exceeds the static ``k_max`` bound
     (``decode_budget_bound``) — the gather width the executors allocate;
  3. no live selected block index falls at/beyond ``ceil(len / block)``.

Runs under ``hypothesis`` when installed; degrades to fixed-seed
parametrized sampling via ``_hypothesis_compat`` otherwise.
"""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to fixed-seed parametrized sampling
    from _hypothesis_compat import given, settings, st

from repro.core import StemConfig
from repro.core.decode import decode_budget_bound, select_decode_blocks

BLOCK_SIZES = (16, 32, 64)


def _selection(seed, b, hk, group, nblk, lens, cfg, budget_frac):
    m = jax.random.normal(jax.random.PRNGKey(seed), (b, hk, group, nblk),
                          jnp.float32) * 3.0
    return select_decode_blocks(m, jnp.asarray(lens, jnp.int32), cfg,
                                budget_frac)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    bs_idx=st.integers(0, len(BLOCK_SIZES) - 1),
    nblk=st.integers(2, 24),
    b=st.integers(1, 4),
    group=st.integers(1, 4),
    budget_frac=st.floats(0.0, 1.0),
    sink=st.integers(0, 2),
    local=st.integers(1, 2),
    len_frac=st.floats(0.05, 1.0),
)
def test_selection_invariants(seed, bs_idx, nblk, b, group, budget_frac,
                              sink, local, len_frac):
    bs = BLOCK_SIZES[bs_idx]
    cfg = StemConfig(block_size=bs, sink_blocks=sink, local_blocks=local,
                     min_budget_blocks=2, stride=8)
    # per-row lengths in [1, nblk*bs], deliberately not block-aligned
    rng = np.random.RandomState(seed)
    max_len = nblk * bs
    lens = np.maximum(1, (rng.uniform(0.05, len_frac, size=b)
                          * max_len).astype(np.int64))
    sel = _selection(seed, b, 2, group, nblk, lens, cfg, budget_frac)
    idx = np.asarray(sel.indices)
    live = np.asarray(sel.live)
    n_valid = np.asarray(sel.n_valid)
    k_max = decode_budget_bound(nblk, cfg, budget_frac)

    assert idx.shape[-1] == k_max

    budgets = np.asarray(sel.budgets)
    for row in range(b):
        nv = int(n_valid[row])
        assert nv == -(-int(lens[row]) // bs)
        live_sets = live[row] & True
        sel_ids = idx[row]
        # (2) live count never exceeds the per-row budget (which itself
        # never exceeds the static k_max gather width)
        assert int(budgets[row]) <= k_max
        assert live_sets.sum(axis=-1).max() <= min(budgets[row], nv)
        # (3) no live selected block beyond ceil(len / block)
        live_ids = sel_ids[live_sets]
        if live_ids.size:
            assert live_ids.max() < nv, (live_ids.max(), nv)
        # (1) forced sink + local blocks are always in the live set
        forced = set(range(min(sink, nv))) | set(range(max(0, nv - local), nv))
        for h in range(live_sets.shape[0]):
            for g in range(live_sets.shape[1]):
                got = set(sel_ids[h, g][live_sets[h, g]].tolist())
                missing = forced - got
                assert not missing, (
                    f"row {row} head {h} group {g}: forced {sorted(forced)} "
                    f"missing {sorted(missing)} (len={lens[row]}, nv={nv})")


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    nblk=st.integers(2, 32),
    budget_frac=st.floats(0.0, 1.0),
)
def test_full_budget_selects_every_valid_block(seed, nblk, budget_frac):
    """At budget_frac=1.0 the live set is exactly the valid prefix — the
    precondition for the dense-equivalence oracle tests."""
    cfg = StemConfig(block_size=16, sink_blocks=1, local_blocks=1,
                     min_budget_blocks=2, stride=8)
    rng = np.random.RandomState(seed)
    lens = np.maximum(1, (rng.uniform(0.05, 1.0, size=2) * nblk * 16)
                      .astype(np.int64))
    sel = _selection(seed, 2, 2, 2, nblk, lens, cfg, 1.0)
    idx = np.asarray(sel.indices)
    live = np.asarray(sel.live)
    for row in range(2):
        nv = -(-int(lens[row]) // 16)
        for h in range(idx.shape[1]):
            for g in range(idx.shape[2]):
                got = sorted(idx[row, h, g][live[row, h, g]].tolist())
                assert got == list(range(nv)), (got, nv)
