"""Validate the structural HLO analyzer against unrolled ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis


def _cost(fn, *avals):
    txt = jax.jit(fn).lower(*avals).compile().as_text()
    return hlo_analysis.analyze_hlo(txt)


def test_single_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = _cost(lambda x, y: x @ y, a, b)
    want = 2 * 128 * 256 * 64
    assert abs(c.flops - want) / want < 0.05, (c.flops, want)


def test_scan_trip_count_multiplied():
    """The whole point: a scan of 10 matmuls must cost ~10x one matmul."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)

    def scanned(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c

    def unrolled(x, w):
        for i in range(10):
            x = jnp.tanh(x @ w[i])
        return x

    cs = _cost(scanned, x, w)
    cu = _cost(unrolled, x, w)
    assert abs(cs.flops - cu.flops) / cu.flops < 0.1, (cs.flops, cu.flops)
    want = 10 * 2 * 128 ** 3
    assert abs(cs.flops - want) / want < 0.1


def test_matches_xla_cost_analysis_when_no_loops():
    a = jax.ShapeDtypeStruct((64, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 512), jnp.float32)

    def f(x, y):
        return jax.nn.relu(x @ y) @ y

    compiled = jax.jit(f).lower(a, b).compile()
    xla_cost = compiled.cost_analysis()
    xla_cost = xla_cost[0] if isinstance(xla_cost, (list, tuple)) else xla_cost
    ours = hlo_analysis.analyze_hlo(compiled.as_text())
    want = float(xla_cost["flops"])
    assert abs(ours.flops - want) / want < 0.1, (ours.flops, want)


def test_collectives_counted_with_trip_counts():
    """A psum inside a scanned body must be multiplied by the trip count."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices for a real all-reduce; on CPU set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    trips = 10

    def inner(x, w):
        def body(c, wi):
            return jax.lax.psum(jnp.tanh(c @ wi), "data"), None
        c, _ = jax.lax.scan(body, x, w)
        return c

    f = shard_map(inner, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                  check_rep=False)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((trips, 64, 64), jnp.float32)
    cost = _cost(f, x, w)
    assert cost.coll_counts.get("all-reduce", 0) == trips, cost.coll_counts
    # each iteration all-reduces a (64, 64) f32
    want_bytes = trips * 64 * 64 * 4
    assert cost.coll_bytes >= want_bytes * 0.9


def test_collectives_visible_in_sharded_grad():
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices for a real all-reduce; on CPU set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    ps = NamedSharding(mesh, P())
    xs = NamedSharding(mesh, P("data"))

    def f(p, x):
        return jnp.sum((x @ p) ** 2)

    lowered = jax.jit(jax.grad(f), in_shardings=(ps, xs)).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((128, 64), jnp.float32))
    cost = hlo_analysis.analyze_hlo(lowered.compile().as_text())
    # grad of replicated param from sharded data => all-reduce of (64,64) f32
    assert cost.coll_counts.get("all-reduce", 0) >= 1
    assert cost.coll_bytes >= 2 * 64 * 64 * 4 * 0.9
