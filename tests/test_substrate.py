"""Substrate tests: data pipeline, optimizer, checkpoint manager, straggler."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMData
from repro.runtime import StragglerMonitor


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_and_seekable():
    d = SyntheticLMData(vocab_size=100, seq_len=64, global_batch=4, seed=7)
    b1 = d.batch_at(123)
    b2 = d.batch_at(123)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch_at(124)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next tokens
    assert b1["tokens"].shape == b1["labels"].shape == (4, 64)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 100).all()


def test_data_has_copied_motifs():
    d = SyntheticLMData(vocab_size=5000, seq_len=256, global_batch=2, seed=1,
                        motif_len=16)
    b = d.batch_at(0)
    toks = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    # a 16-gram from the first half must recur in the second half
    found = 0
    for row in toks:
        first = {tuple(row[i:i + 16]) for i in range(0, len(row) // 2 - 16)}
        for i in range(len(row) // 2, len(row) - 16):
            if tuple(row[i:i + 16]) in first:
                found += 1
                break
    assert found == toks.shape[0]


def test_data_modalities():
    d = SyntheticLMData(vocab_size=10, seq_len=32, global_batch=2, kind="vlm",
                        d_model=8)
    b = d.batch_at(0)
    assert b["patch_embeds"].shape == (2, 8, 8)
    assert b["tokens"].shape == (2, 24)
    d2 = SyntheticLMData(vocab_size=10, seq_len=32, global_batch=2,
                         kind="encdec", d_model=8, frames=5)
    assert d2.batch_at(0)["frames"].shape == (2, 5, 8)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = optim.AdamWConfig(peak_lr=0.1, warmup_steps=1, decay_steps=100,
                            weight_decay=0.0, grad_dtype=None)
    params = {"w": jnp.array([2.0, -3.0, 1.0])}
    state = optim.init_state(params, cfg)

    def loss(m):
        return jnp.sum(m["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(state.master)
        state, metrics = optim.update(g, state, cfg)
    assert float(loss(state.master)) < 1e-2


def test_adamw_clipping_and_schedule():
    cfg = optim.AdamWConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                            clip_norm=1.0)
    assert float(optim.lr_at(jnp.asarray(0), cfg)) == 0.0
    assert abs(float(optim.lr_at(jnp.asarray(10), cfg)) - 1.0) < 1e-6
    assert float(optim.lr_at(jnp.asarray(100), cfg)) <= 1.0 * (cfg.min_lr_ratio + 1e-6)
    params = {"w": jnp.ones((4,))}
    state = optim.init_state(params, cfg)
    g = {"w": jnp.full((4,), 100.0)}
    state2, metrics = optim.update(g, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-3)
    # effective update magnitude bounded by lr despite the huge gradient
    assert float(jnp.abs(state2.master["w"] - state.master["w"]).max()) < 1.0


def test_bf16_moments_still_converge():
    cfg = optim.AdamWConfig(peak_lr=0.1, warmup_steps=1, decay_steps=100,
                            weight_decay=0.0, moment_dtype="bfloat16")
    params = {"w": jnp.array([5.0])}
    state = optim.init_state(params, cfg)
    assert state.mu["w"].dtype == jnp.bfloat16
    for _ in range(100):
        g = jax.grad(lambda m: jnp.sum(m["w"] ** 2))(state.master)
        state, _ = optim.update(g, state, cfg)
    assert abs(float(state.master["w"][0])) < 0.2


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_keep(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda t: t * step, tree), extra={"s": step})
    assert mgr.steps() == [2, 3]   # keep-K GC
    target = jax.tree.map(lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype), tree)
    restored, meta = mgr.restore(target)
    assert meta["step"] == 3
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) * 3)
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"w": jnp.zeros((1000, 100))}
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1
    # a stale tmp dir must not count as a checkpoint
    os.makedirs(tmp_path / "step_9.tmp", exist_ok=True)
    assert mgr.latest_step() == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jax.ShapeDtypeStruct((5,), jnp.float32)})


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

def test_straggler_detection():
    hits = []
    mon = StragglerMonitor(threshold=2.0, warmup_steps=2,
                           on_straggler=lambda s, dt, ema: hits.append(s))
    for step in range(10):
        mon.observe(step, 0.1)
    mon.observe(10, 0.5)        # 5x the EMA -> straggler
    mon.observe(11, 0.1)        # baseline not poisoned
    assert hits == [10]
    assert abs(mon.ema - 0.1) < 0.02
