"""Ragged, budget-aware execution engine (DESIGN.md).

Covers: equivalence of ragged-Pallas / deduped-gather / padded-XLA /
dense-oracle outputs across GQA groups and decay ratios, the prefix-live /
live-count invariants, the budget-sorted segment schedule, and the
zero-new-DMA property of the revisit index map.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StemConfig, schedule, stem_attention
from repro.core import selection as sel_lib
from repro.core.sparse_attention import select_for


def _qkv(seed, b, hq, hk, n, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, n, d), dtype)
    k = jax.random.normal(ks[1], (b, hk, n, d), dtype)
    v = jax.random.normal(ks[2], (b, hk, n, d), dtype)
    return q, k, v


def _cfg(group, mu, **kw):
    base = dict(
        block_size=64, k_start_frac=0.5, mu=mu, sink_blocks=1, local_blocks=1,
        min_budget_blocks=2, stride=8,
        group_reduce="mean" if group > 1 else "none",
    )
    base.update(kw)
    return StemConfig(**base)


@pytest.mark.parametrize("group", [1, 4])
@pytest.mark.parametrize("mu", [0.125, 1.0])
def test_all_executors_agree(group, mu):
    """ragged-Pallas == deduped-gather == padded-XLA == dense oracle.

    mu=0.125 gives strongly uneven budgets (8x decay); mu=1.0 is the
    uniform schedule (ragged layout collapses to a single segment).
    """
    hk = 2
    q, k, v = _qkv(0, 2, hk * group, hk, 512, 32)
    o_dense = stem_attention(q, k, v, _cfg(group, mu, backend="dense"))
    o_padded = stem_attention(q, k, v, _cfg(group, mu, backend="xla", ragged=False))
    o_ragged = stem_attention(q, k, v, _cfg(group, mu, backend="xla", ragged=True))
    o_pallas = stem_attention(q, k, v, _cfg(group, mu, backend="pallas", ragged=True))
    tol = dict(atol=2e-6, rtol=2e-6)
    for name, o in (("padded", o_padded), ("ragged", o_ragged), ("pallas", o_pallas)):
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(o_dense, np.float32),
            err_msg=name, **tol,
        )


def test_ragged_matches_padded_uneven_budgets():
    """Strongly uneven budgets (decay + causal ramp): segment schedule must
    reproduce the padded executor exactly."""
    q, k, v = _qkv(1, 1, 4, 4, 1024, 32)
    cfg_kw = dict(group=1, mu=0.25, k_start_frac=0.4, min_budget_blocks=1)
    o_pad = stem_attention(q, k, v, _cfg(backend="xla", ragged=False, **cfg_kw))
    o_rag = stem_attention(q, k, v, _cfg(backend="xla", ragged=True, **cfg_kw))
    np.testing.assert_allclose(
        np.asarray(o_rag), np.asarray(o_pad), atol=1e-6, rtol=1e-6
    )


def test_live_counts_prefix_and_budgets():
    """Live slots form a prefix and live_counts equals the TPD budgets."""
    q, k, v = _qkv(2, 2, 4, 2, 512, 32)
    cfg = _cfg(2, 0.3)
    sel, _ = select_for(q, k, v, cfg, with_block_mask=False)
    msk = np.asarray(sel.slot_mask)
    cnt = np.asarray(sel.live_counts)
    assert cnt.shape == msk.shape[:-1]
    # prefix-live: mask must equal (slot < count)
    slots = np.arange(msk.shape[-1])
    np.testing.assert_array_equal(msk, slots[None, None, None, :] < cnt[..., None])
    # count == schedule budget for every (batch, head) row
    np.testing.assert_array_equal(
        cnt, np.broadcast_to(np.asarray(sel.budgets), cnt.shape)
    )


def test_revisit_dead_slots_cost_zero_new_dmas():
    """Regression: with the revisit index map, no dead slot changes the K/V
    block index — the Pallas pipeline issues zero DMAs for dead slots."""
    q, k, v = _qkv(3, 2, 4, 2, 1024, 32)
    cfg = _cfg(2, 0.125)
    sel, _ = select_for(q, k, v, cfg, with_block_mask=False)
    ridx = np.asarray(sel_lib.revisit_indices(sel.indices, sel.slot_mask))
    live = np.asarray(sel.slot_mask)
    assert (~live).sum() > 0, "test needs dead slots to be meaningful"
    # A DMA is issued when the block index differs from the previous slot's.
    changed = ridx[..., 1:] != ridx[..., :-1]
    dead_dma = changed & ~live[..., 1:]
    assert int(dead_dma.sum()) == 0
    # Live slots are untouched by the revisit fill.
    np.testing.assert_array_equal(
        np.where(live, ridx, 0), np.asarray(sel.indices)
    )


def test_budget_sorted_segments_schedule():
    """Segments partition rows budget-descending and allocate exactly
    ceil(budget/chunk) chunks to each row's segment."""
    budgets = np.array([1, 2, 5, 9, 8, 7, 3, 2, 1], np.int32)
    chunk = 4
    segs = sel_lib.budget_sorted_segments(budgets, chunk)
    rows = np.concatenate([np.asarray(s.rows) for s in segs])
    assert sorted(rows.tolist()) == list(range(len(budgets)))
    n_chunks = [s.n_chunks for s in segs]
    assert n_chunks == sorted(n_chunks, reverse=True)
    for s in segs:
        for r in s.rows:
            assert s.n_chunks == max(1, -(-int(budgets[r]) // chunk))
    # total chunk-work is the ragged sum, not len(budgets) * max
    total = sum(len(s.rows) * s.n_chunks for s in segs)
    assert total == sum(max(1, -(-int(x) // chunk)) for x in budgets)
    assert total < len(budgets) * max(n_chunks)


def test_selection_density_without_block_mask():
    """return_stats works on the production (mask-free) path and matches the
    block-mask computation."""
    q, k, v = _qkv(4, 1, 2, 2, 512, 16)
    cfg = _cfg(1, 0.7)
    sel_no_mask, _ = select_for(q, k, v, cfg, with_block_mask=False)
    sel_mask, _ = select_for(q, k, v, cfg, with_block_mask=True)
    assert sel_no_mask.block_mask is None
    nk = 512 // cfg.block_size
    d0 = float(sel_lib.selection_density(sel_no_mask, nk))
    d1 = float(np.asarray(sel_mask.block_mask).sum(axis=(-1, -2)).mean()
               / np.asarray(sel_lib.causal_block_mask(nk, nk)).sum())
    assert 0.0 < d0 <= 1.0
    assert abs(d0 - d1) < 1e-6
    # and the jitted stats path runs without a block mask
    _, stats = stem_attention(q, k, v, cfg, return_stats=True)
    assert abs(float(stats.density) - d0) < 1e-6


def test_dedup_requires_shared_selection():
    """With group_reduce="none" the ragged path must keep per-head selection
    (no dedup) and still match the dense oracle."""
    q, k, v = _qkv(5, 1, 8, 2, 512, 32)
    cfg = _cfg(1, 0.5, group_reduce="none", backend="xla", ragged=True)
    o = stem_attention(q, k, v, cfg)
    o_dense = stem_attention(q, k, v, _cfg(1, 0.5, group_reduce="none", backend="dense"))
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_dense), atol=2e-6, rtol=2e-6)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_bf16_ragged_close_to_oracle(backend):
    """bf16 ragged outputs stay within kernel-test tolerance of the oracle."""
    q, k, v = _qkv(6, 1, 8, 2, 512, 64, jnp.bfloat16)
    cfg = _cfg(4, 0.25, backend=backend, ragged=True)
    o = stem_attention(q, k, v, cfg)
    o_dense = stem_attention(q, k, v, _cfg(4, 0.25, backend="dense"))
    err = float(jnp.abs(o.astype(jnp.float32) - o_dense.astype(jnp.float32)).max())
    assert err <= 2e-2, err
