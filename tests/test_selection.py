"""Tests for Top-k(i) block selection with sink/local floors."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to fixed-seed parametrized sampling
    from _hypothesis_compat import given, settings, st

from repro.core import selection as sel_lib


def _metric(key, b, h, nq, nk):
    return jax.random.normal(jax.random.PRNGKey(key), (b, h, nq, nk), jnp.float32)


def test_causal_admissibility():
    m = _metric(0, 1, 1, 8, 8)
    budgets = jnp.full((8,), 8, jnp.int32)
    s = sel_lib.select_blocks(m, budgets, 8, sink_blocks=1, local_blocks=1)
    mask = np.asarray(s.block_mask)[0, 0]
    for i in range(8):
        assert not mask[i, i + 1 :].any(), f"row {i} selected a future block"


def test_forced_sink_and_local_always_kept():
    m = _metric(1, 2, 3, 16, 16) - 100.0  # make everything unattractive
    budgets = jnp.full((16,), 6, jnp.int32)
    s = sel_lib.select_blocks(m, budgets, 6, sink_blocks=2, local_blocks=2)
    mask = np.asarray(s.block_mask)
    for i in range(16):
        for j in range(min(2, i + 1)):  # sinks (causally admissible)
            assert mask[..., i, j].all(), f"sink block {j} dropped at row {i}"
        for j in range(max(0, i - 1), i + 1):  # local
            if i >= 2 and mask.shape[-1] > j:
                assert mask[..., i, j].all(), f"local block {j} dropped at row {i}"


@given(
    nq=st.integers(2, 24),
    budget=st.integers(1, 24),
    seed=st.integers(0, 100),
)
@settings(max_examples=50, deadline=None)
def test_budget_exactly_respected(nq, budget, seed):
    m = _metric(seed, 1, 2, nq, nq)
    budgets = jnp.minimum(jnp.full((nq,), budget, jnp.int32), jnp.arange(1, nq + 1))
    s = sel_lib.select_blocks(m, budgets, int(budgets.max()), sink_blocks=1, local_blocks=1)
    counts = np.asarray(s.block_mask).sum(axis=-1)
    want = np.asarray(budgets)
    assert (counts == want[None, None, :]).all(), (counts, want)


def test_indices_and_mask_agree():
    m = _metric(7, 2, 2, 12, 12)
    budgets = jnp.minimum(jnp.full((12,), 5, jnp.int32), jnp.arange(1, 13))
    s = sel_lib.select_blocks(m, budgets, 5, sink_blocks=1, local_blocks=1)
    idx = np.asarray(s.indices)
    live = np.asarray(s.slot_mask)
    mask = np.asarray(s.block_mask)
    rebuilt = np.zeros_like(mask)
    b, h, nq, km = idx.shape
    for bi in range(b):
        for hi in range(h):
            for i in range(nq):
                for t in range(km):
                    if live[bi, hi, i, t]:
                        rebuilt[bi, hi, i, idx[bi, hi, i, t]] = True
    np.testing.assert_array_equal(mask, rebuilt)


def test_selected_are_topk_of_metric():
    """Non-forced selected blocks must dominate non-selected ones."""
    m = _metric(9, 1, 1, 10, 10)
    budgets = jnp.minimum(jnp.full((10,), 4, jnp.int32), jnp.arange(1, 11))
    s = sel_lib.select_blocks(m, budgets, 4, sink_blocks=1, local_blocks=1)
    mask = np.asarray(s.block_mask)[0, 0]
    mm = np.asarray(m)[0, 0]
    forced = np.asarray(sel_lib.forced_block_mask(10, 10, 1, 1))
    for i in range(10):
        sel_vals = mm[i, mask[i] & ~forced[i]]
        not_sel = mm[i, : i + 1][~mask[i, : i + 1] & ~forced[i, : i + 1]]
        if len(sel_vals) and len(not_sel):
            assert sel_vals.min() >= not_sel.max() - 1e-5


def test_token_mask_exact_causal_inside_diagonal():
    bm = jnp.ones((1, 1, 2, 2), jnp.bool_)
    tm = np.asarray(sel_lib.block_mask_to_token_mask(bm, 4, 4, 8, 8))[0, 0]
    for i in range(8):
        for j in range(8):
            assert tm[i, j] == (j <= i)


def test_density_full_budget_is_one():
    m = _metric(11, 1, 1, 6, 6)
    budgets = jnp.arange(1, 7, dtype=jnp.int32)
    s = sel_lib.select_blocks(m, budgets, 6, sink_blocks=1, local_blocks=1)
    assert float(sel_lib.selection_density(s, 6)) == 1.0
