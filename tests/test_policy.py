"""SparsityPolicy API: registry, parity with the legacy flag pipeline, and
the one-policy-drives-all-three-paths contract.

Parity is pinned two ways:

  * **Baseline bit-for-bit** — the registered baseline policies must
    reproduce the *seed implementations* of ``uniform_sam_selection`` /
    ``streaming_selection`` / ``xattention_like_selection`` exactly.  The
    seed code is frozen inline here (``_ref_*``) so the comparison stays
    meaningful after ``core/baselines.py`` collapsed onto the policy stack.
  * **StemConfig shim 0 ulp** — ``stem_attention(q, k, v, cfg)`` and
    ``sparse_attention(q, k, v, cfg.policy())`` must be bitwise identical
    on the dense and xla executors.

The differential section registers a *new* metric once and checks it runs
prefill, fixed-batch decode, and the paged serving path with consistent
results — the acceptance contract of the policy API.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SparsityPolicy, StemConfig, TopKSelector, TPDSchedule,
                        as_policy, available_policies, dense_attention,
                        get_executor, get_policy, register_policy,
                        sparse_attention, stem_attention)
from repro.core import metric as metric_lib
from repro.core import selection as selection_lib
from repro.core.baselines import (streaming_selection, uniform_sam_selection,
                                  xattention_like_selection)
from repro.core.config import uniform_equivalent_budget
from repro.core.decode import (select_decode_blocks, sparse_decode_attention,
                               summarize_cache)

NEG_INF = -1e30


def _qkv(seed, b, hq, hk, n, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, hq, n, d), dtype),
            jax.random.normal(ks[1], (b, hk, n, d), dtype),
            jax.random.normal(ks[2], (b, hk, n, d), dtype))


CFG = StemConfig(block_size=64, k_start_frac=0.5, mu=0.7, sink_blocks=1,
                 local_blocks=1, min_budget_blocks=2, stride=8)


# ---------------------------------------------------------------------------
# Registry + config plumbing
# ---------------------------------------------------------------------------

def test_registry_names():
    for name in ("stem", "stem-sam", "uniform-sam", "uniform-oam",
                 "streaming", "xattention", "dense"):
        assert name in available_policies()
        assert isinstance(get_policy(name), SparsityPolicy)
    with pytest.raises(KeyError, match="registered"):
        get_policy("no-such-policy")
    with pytest.raises(ValueError, match="already registered"):
        register_policy("stem", get_policy("stem"))
    for name in ("xla", "pallas", "dense"):
        assert get_executor(name).fn is not None
    with pytest.raises(KeyError):
        get_executor("no-such-executor")


def test_as_policy_spellings():
    p = get_policy("stem")
    assert as_policy(p) is p
    assert as_policy("stem") is p
    cp = as_policy(CFG)
    assert isinstance(cp, SparsityPolicy)
    assert cp.block_size == CFG.block_size and cp.stride == CFG.stride
    assert as_policy(CFG) is cp     # cached per config
    with pytest.raises(TypeError):
        as_policy(42)


def test_with_updates_routing():
    p = get_policy("streaming").with_updates(
        block_size=32, sink_blocks=2, local_blocks=3)
    assert p.block_size == 32
    assert p.selector.sink_blocks == 2 and p.schedule.sink_blocks == 2
    assert p.selector.local_blocks == 3 and p.schedule.local_blocks == 3
    with pytest.raises(ValueError, match="no component defines"):
        get_policy("stem").with_updates(not_a_field=1)
    # ignore_missing: content-free metrics have no stride to rewrite
    q = get_policy("streaming").with_updates(stride=4, ignore_missing=True)
    assert q == get_policy("streaming")


def test_policy_construction_validation():
    """Invalid compositions fail at construction with a clear message —
    the same invariant class StemConfig enforces — instead of deep inside
    jit tracing."""
    with pytest.raises(ValueError, match="divide"):
        get_policy("stem").with_updates(block_size=64, stride=12)
    with pytest.raises(ValueError, match="multiple of 8"):
        get_policy("stem").with_updates(block_size=63)
    with pytest.raises(ValueError, match="group_reduce"):
        get_policy("stem").with_updates(group_reduce="bogus")
    with pytest.raises(ValueError, match="mu"):
        get_policy("stem").with_updates(mu=1.5)
    with pytest.raises(ValueError, match="tau"):
        get_policy("xattention").with_updates(tau=0.0)
    with pytest.raises(ValueError, match="sink/local"):
        TopKSelector(sink_blocks=-1)
    # cross-component invariants see the *combined* update: block_size and
    # stride changed together must validate as a pair, not sequentially
    p = get_policy("stem").with_updates(block_size=24, stride=4)
    assert p.block_size == 24 and p.stride == 4


def test_sparse_segment_validation():
    with pytest.raises(ValueError, match="2-tuple"):
        StemConfig(sparse_segment=(0.1,))
    with pytest.raises(ValueError, match="2-tuple"):
        StemConfig(sparse_segment=[0.1, 0.5])
    with pytest.raises(ValueError, match="lo < hi"):
        StemConfig(sparse_segment=(0.5, 0.5))
    with pytest.raises(ValueError, match="lo < hi"):
        StemConfig(sparse_segment=(-0.1, 0.5))
    with pytest.raises(ValueError, match="lo < hi"):
        StemConfig(sparse_segment=(0.2, 1.5))
    with pytest.raises(ValueError, match="numbers"):
        StemConfig(sparse_segment=("a", "b"))
    StemConfig(sparse_segment=(0.25, 0.5))   # valid


# ---------------------------------------------------------------------------
# Seed reference implementations (frozen from commit d99c617 baselines.py)
# ---------------------------------------------------------------------------

def _ref_uniform_budgets(nq, nk, k_uni):
    offset = nk - nq
    i = jnp.arange(nq)
    admissible = jnp.minimum(i + 1 + offset, nk)
    return jnp.minimum(jnp.full((nq,), k_uni, jnp.int32),
                       admissible.astype(jnp.int32))


def _ref_uniform_sam_selection(q, k, v, cfg, k_uni=None):
    sam_cfg = dataclasses.replace(cfg, metric="sam", mu=1.0)
    m = metric_lib.oam_metric(q, k, v, sam_cfg)
    group = q.shape[1] // k.shape[1]
    m = metric_lib.group_reduce_metric(m, group, cfg.group_reduce)
    nq, nk = m.shape[-2], m.shape[-1]
    if k_uni is None:
        k_uni = uniform_equivalent_budget(cfg.k_start_blocks(k.shape[2]), cfg.mu)
        k_uni = max(k_uni, min(cfg.min_budget_blocks, nk))
    budgets = _ref_uniform_budgets(nq, nk, k_uni)
    return selection_lib.select_blocks(
        m, budgets, int(min(k_uni, nk)),
        sink_blocks=cfg.sink_blocks, local_blocks=cfg.local_blocks)


def _ref_streaming_selection(nq, nk, batch, heads, sink_blocks, local_blocks):
    mask2d = selection_lib.forced_block_mask(nq, nk, sink_blocks, local_blocks)
    block_mask = jnp.broadcast_to(mask2d, (batch, heads, nq, nk))
    k_max = sink_blocks + local_blocks
    score = jnp.where(mask2d, 1.0, NEG_INF)
    _, idx = jax.lax.top_k(score, min(k_max, nk))
    vals = jnp.take_along_axis(score, idx, axis=-1)
    slot2d = vals > NEG_INF / 2
    indices = jnp.broadcast_to(jnp.where(slot2d, idx, 0),
                               (batch, heads) + idx.shape)
    slot_mask = jnp.broadcast_to(slot2d, indices.shape)
    budgets = mask2d.sum(axis=-1).astype(jnp.int32)
    return selection_lib.BlockSelection(
        indices=indices.astype(jnp.int32), slot_mask=slot_mask,
        block_mask=block_mask, budgets=budgets)


def _ref_xattention_like_selection(q, k, v, cfg, tau=0.9):
    sam_cfg = dataclasses.replace(cfg, metric="sam")
    m = metric_lib.oam_metric(q, k, v, sam_cfg)
    nq, nk = m.shape[-2], m.shape[-1]
    causal = selection_lib.causal_block_mask(nq, nk)
    m = jnp.where(causal, m, NEG_INF)
    probs = jax.nn.softmax(m, axis=-1)
    order = jnp.argsort(-probs, axis=-1)
    sorted_p = jnp.take_along_axis(probs, order, axis=-1)
    cum = jnp.cumsum(sorted_p, axis=-1)
    keep_sorted = (cum - sorted_p) < tau
    onehot = jax.nn.one_hot(order, nk, dtype=jnp.bool_)
    block_mask = jnp.any(onehot & keep_sorted[..., None], axis=-2) & causal
    forced = selection_lib.forced_block_mask(nq, nk, cfg.sink_blocks,
                                             cfg.local_blocks)
    block_mask = block_mask | (forced & causal)
    k_max = int(nk)
    score = jnp.where(block_mask, probs + 1.0, NEG_INF)
    vals, idx = jax.lax.top_k(score, k_max)
    slot_mask = vals > NEG_INF / 2
    indices = jnp.where(slot_mask, idx, 0).astype(jnp.int32)
    budgets = jnp.max(block_mask.sum(axis=-1), axis=(0, 1)).astype(jnp.int32)
    return selection_lib.BlockSelection(
        indices=indices, slot_mask=slot_mask, block_mask=block_mask,
        budgets=budgets)


def _assert_selection_equal(got, want):
    np.testing.assert_array_equal(np.asarray(got.indices), np.asarray(want.indices))
    np.testing.assert_array_equal(np.asarray(got.slot_mask), np.asarray(want.slot_mask))
    np.testing.assert_array_equal(np.asarray(got.block_mask), np.asarray(want.block_mask))
    np.testing.assert_array_equal(np.asarray(got.budgets), np.asarray(want.budgets))


# ---------------------------------------------------------------------------
# Satellite: policy parity with the seed baselines, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k_uni", [None, 3])
def test_uniform_sam_parity_bitwise(k_uni):
    q, k, v = _qkv(0, 2, 4, 2, 512, 32)
    _assert_selection_equal(uniform_sam_selection(q, k, v, CFG, k_uni),
                            _ref_uniform_sam_selection(q, k, v, CFG, k_uni))


def test_streaming_parity_bitwise():
    got = streaming_selection(16, 16, 2, 3, sink_blocks=2, local_blocks=2)
    want = _ref_streaming_selection(16, 16, 2, 3, 2, 2)
    _assert_selection_equal(got, want)


@pytest.mark.parametrize("tau", [0.5, 0.9])
def test_xattention_parity_bitwise(tau):
    q, k, v = _qkv(1, 1, 2, 2, 512, 32)
    _assert_selection_equal(xattention_like_selection(q, k, v, CFG, tau=tau),
                            _ref_xattention_like_selection(q, k, v, CFG, tau=tau))


@pytest.mark.parametrize("backend", ["dense", "xla"])
def test_stem_config_shim_0ulp(backend):
    """cfg.policy() and the stem_attention shim are the same computation —
    outputs must be bitwise identical."""
    q, k, v = _qkv(2, 2, 4, 2, 512, 32)
    cfg = dataclasses.replace(CFG, backend=backend)
    legacy = stem_attention(q, k, v, cfg)
    via_policy = sparse_attention(q, k, v, cfg.policy())
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(via_policy))
    # stats path too
    _, s1 = stem_attention(q, k, v, cfg, return_stats=True)
    _, s2 = sparse_attention(q, k, v, cfg.policy(), return_stats=True)
    assert float(s1.density) == float(s2.density)
    assert s1.k_max == s2.k_max


def test_dense_policy_equals_dense_attention():
    q, k, v = _qkv(3, 1, 4, 2, 256, 32)
    out = sparse_attention(q, k, v, "dense")
    ref = dense_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-6, rtol=3e-6)


# ---------------------------------------------------------------------------
# Acceptance: a new metric registered once works on all three paths
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _VNormMetric:
    """Test-only metric: rank blocks purely by pooled value magnitude
    (content-free in Q — a shape the flag pipeline could never express)."""

    stride: int = 8   # sizes the cache summaries like the antidiag metrics

    def prefill_scores(self, q, k, v, *, block_size):
        mv = metric_lib.value_block_magnitude(v, block_size)   # (b, hk, nk)
        group = q.shape[1] // k.shape[1]
        mv = jnp.repeat(mv, group, axis=1)
        nq = q.shape[2] // block_size
        return jnp.broadcast_to(mv[:, :, None, :],
                                mv.shape[:2] + (nq, mv.shape[-1]))

    def decode_scores(self, q, k_groups, v_mag):
        b, hq = q.shape[0], q.shape[1]
        hk, n = v_mag.shape[1], v_mag.shape[2]
        return jnp.broadcast_to(v_mag[:, :, None, :], (b, hk, hq // hk, n))


VNORM = SparsityPolicy(
    metric=_VNormMetric(), schedule=TPDSchedule(k_start_frac=0.5, mu=0.7,
                                                min_budget_blocks=2),
    selector=TopKSelector(sink_blocks=1, local_blocks=1),
    block_size=64, name="test-vnorm")
register_policy("test-vnorm", VNORM, overwrite=True)


def test_new_metric_prefill_executors_agree():
    q, k, v = _qkv(4, 2, 4, 2, 512, 32)
    o_x = sparse_attention(q, k, v, "test-vnorm", executor="xla")
    o_d = sparse_attention(q, k, v, "test-vnorm", executor="dense")
    np.testing.assert_allclose(np.asarray(o_x), np.asarray(o_d),
                               atol=2e-6, rtol=2e-6)


def _dense_decode(q, k, v, cache_lens):
    b, hq, _, d = q.shape
    hk = k.shape[1]
    g = hq // hk
    lens = jnp.broadcast_to(jnp.asarray(cache_lens, jnp.int32), (b,))
    qg = q.reshape(b, hk, g, 1, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhld->bhgql", qg, k.astype(jnp.float32)) * (d ** -0.5)
    valid = jnp.arange(k.shape[2])[None, :] < lens[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgql,bhld->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, 1, d)


def test_new_metric_decode_full_budget_matches_dense():
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (2, 4, 1, 32))
    k = jax.random.normal(ks[1], (2, 2, 256, 32))
    v = jax.random.normal(ks[2], (2, 2, 256, 32))
    lens = jnp.asarray([250, 130], jnp.int32)
    summ = summarize_cache(k, v, "test-vnorm")
    got = sparse_decode_attention(q, k, v, summ, lens, "test-vnorm",
                                  budget_frac=1.0)
    want = _dense_decode(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_new_metric_paged_matches_contiguous():
    """The paged executor and the contiguous decode path run the same
    policy objects — outputs must agree at a *sparse* budget too."""
    from repro.runtime import paged as paged_lib

    pol = get_policy("test-vnorm")
    bs = pol.block_size
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    L = 4 * bs
    q = jax.random.normal(ks[0], (1, 4, 1, 32))
    k = jax.random.normal(ks[1], (1, 2, L, 32))
    v = jax.random.normal(ks[2], (1, 2, L, 32))
    lens = jnp.asarray([L - 7], jnp.int32)

    contiguous = sparse_decode_attention(
        q, k, v, summarize_cache(k, v, pol), lens, pol, budget_frac=0.5)

    nblk = L // bs
    pool = paged_lib.init_pool(nblk + 1, 2, bs, 32, pol.stride)
    page_ids = jnp.arange(1, nblk + 1)
    keep = jnp.arange(L) < lens[0]
    kz = jnp.where(keep[None, :, None], k[0], 0)
    vz = jnp.where(keep[None, :, None], v[0], 0)
    pool = paged_lib.write_prefill_pages(pool, page_ids, kz, vz, lens[0], pol)
    page_table = page_ids[None, :]
    paged = paged_lib.paged_sparse_decode(q, pool, page_table, lens, pol,
                                          budget_frac=0.5)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(contiguous),
                               rtol=1e-4, atol=1e-5)


def test_streaming_decode_selects_only_sink_local():
    """The streaming policy's decode selection keeps exactly the forced
    sink + local pages — budget-free policies flow through the shared
    decode stages."""
    pol = get_policy("streaming").with_updates(block_size=32, sink_blocks=1,
                                               local_blocks=1)
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    m = jax.random.normal(ks[0], (2, 2, 2, 8))     # (b, hk, g, nblk)
    lens = jnp.asarray([8 * 32, 5 * 32], jnp.int32)
    sel = select_decode_blocks(m, lens, pol, budget_frac=0.7)
    live_counts = np.asarray(sel.live.sum(axis=-1))
    np.testing.assert_array_equal(live_counts,
                                  np.full_like(live_counts, 2))  # sink + local
    # the selected ids are block 0 and the last valid block, per row
    idx = np.asarray(sel.indices)
    live = np.asarray(sel.live)
    for b, last in ((0, 7), (1, 4)):
        picked = set(idx[b][live[b]].ravel().tolist())
        assert picked == {0, last}


# ---------------------------------------------------------------------------
# Acceptance: per-layer policy overrides in the transformer
# ---------------------------------------------------------------------------

def test_per_layer_policies_change_density():
    from repro.configs.base import ArchConfig
    from repro.models import registry, transformer

    cfg = ArchConfig(
        name="policy-smoke", family="dense", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        qk_norm=True, dtype="float32")
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)

    rich = get_policy("stem").with_updates(
        block_size=16, stride=4, sink_blocks=1, local_blocks=1,
        min_budget_blocks=1, k_start_frac=0.9, mu=1.0)
    lean = rich.with_updates(k_start_frac=0.3, mu=0.5)

    logits, records = transformer.forward_with_stats(
        params, {"tokens": toks}, cfg, stem_cfg=rich, policies={2: lean})
    assert np.isfinite(np.asarray(logits)).all()
    assert [r["layer"] for r in records] == [0, 1, 2]
    dens = [float(r["stats"].density) for r in records]
    assert dens[0] == dens[1]                  # same policy, same schedule
    assert dens[2] < dens[0]                   # leaner override bites
    # loss path accepts the same overrides (scan split at the boundary)
    loss_u, _ = bundle.loss_fn(
        params, {"tokens": toks, "labels": jnp.roll(toks, -1, 1)},
        stem_cfg=rich, remat=False)
    loss_o, _ = bundle.loss_fn(
        params, {"tokens": toks, "labels": jnp.roll(toks, -1, 1)},
        stem_cfg=rich, policies={2: lean}, remat=False)
    assert np.isfinite(float(loss_u)) and np.isfinite(float(loss_o))
    assert float(loss_u) != float(loss_o)      # the override changed layer 2
    with pytest.raises(ValueError, match="out of range"):
        transformer.forward_with_stats(
            params, {"tokens": toks}, cfg, stem_cfg=rich, policies={9: lean})


def test_prefill_scan_split_is_mathematically_neutral():
    """Splitting the layer scan at an override boundary must not change the
    math.  The override differs only by ``name`` (a non-computational
    field), so it forces a genuine 1+1+1 split whose result must match the
    unsplit 3-layer scan; an equal override must coalesce back into one
    run (checked via _policy_runs)."""
    from repro.configs.base import ArchConfig
    from repro.models import registry, transformer

    cfg = ArchConfig(
        name="policy-smoke2", family="dense", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        qk_norm=True, dtype="float32")
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 64), 0, cfg.vocab_size)
    pol = get_policy("stem").with_updates(
        block_size=16, stride=4, sink_blocks=1, local_blocks=1,
        min_budget_blocks=1, k_start_frac=0.75, mu=0.8)
    alias = dataclasses.replace(pol, name="stem-alias")

    # equal policies coalesce into one scan run; the alias splits it
    assert transformer._policy_runs([pol, pol, pol]) == [(0, 3, pol)]
    assert [r[:2] for r in transformer._policy_runs([pol, alias, pol])] == \
        [(0, 1), (1, 1), (2, 1)]

    base_logits, _ = bundle.prefill(params, {"tokens": toks}, max_len=72,
                                    stem_cfg=pol)
    split_logits, _ = bundle.prefill(params, {"tokens": toks}, max_len=72,
                                     stem_cfg=pol, policies={1: alias})
    np.testing.assert_allclose(np.asarray(base_logits),
                               np.asarray(split_logits),
                               rtol=2e-5, atol=2e-5)
