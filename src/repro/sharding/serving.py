"""Mesh-sharded serving: tensor-parallel page pools + data-parallel slot groups.

The serving mesh is ``(dp, tp)``:

* **tp** shards every ``PagePool`` leaf over the KV-head axis.  Stem
  selection is already per KV head (the GQA dedup fetches one K/V page
  set per KV head), so scoring and attention run shard-local on
  ``hk // tp`` heads with no cross-device math.  The only collective in
  the whole step is one ``all_gather`` of the per-head attention outputs
  right before the output projection — psum-free, so the sharded step is
  **bitwise identical** to the single-device step.
* **dp** adds a leading *slot-group* axis to the pools and to every
  host-side batch array.  One engine instance drives ``dp`` independent
  slot groups (each with its own ``PageAllocator`` and page table)
  through the same two compiled traces; the host scheduler partitions
  its token budget per group.

Page tables, selections, and live counts stay replicated host-side: they
are tiny int32 arrays, and keeping them replicated means the scheduler
needs no device round-trips to make decisions (no per-step host syncs
beyond the two logits fetches the single-device engine already does).

The TP head slicing is threaded into ``models/attention.py`` via a
threadlocal *head-sharding context* (:func:`head_sharding`) that the
shard-mapped unified step activates during tracing.  Outside the
context, :func:`local_heads` / :func:`gather_heads` are identity — the
single-device path is untouched.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP_AXIS = "dp"
TP_AXIS = "tp"

# PagePool leaves are stacked ``(n_layers, hk, num_pages, ...)`` and gain a
# leading slot-group axis under the mesh: ``(dp, n_layers, hk, ...)``.  A
# PartitionSpec is a *prefix* spec, so one spec covers every leaf rank
# (k/v are rank 6, kg rank 6, vm rank 4).
POOL_SPEC = P(DP_AXIS, None, TP_AXIS)
# Host-side batch arrays carry the slot-group axis first: (dp, ...).
GROUP_SPEC = P(DP_AXIS)
# Parameters are replicated — full projections run on every shard so the
# head slicing commutes bitwise with the single-device computation.
REPLICATED = P()


@dataclass(frozen=True)
class ServingMesh:
    """A ``(dp, tp)`` serving mesh plus its JAX mesh object."""
    dp: int
    tp: int
    mesh: Mesh

    @property
    def n_devices(self) -> int:
        return self.dp * self.tp


def make_serving_mesh(dp: int, tp: int, devices=None) -> ServingMesh:
    """Build a ``(dp, tp)`` mesh from the first ``dp*tp`` devices.

    ``jax.make_mesh`` grabs *all* devices; serving meshes are often a
    subset (e.g. dp=2,tp=1 on an 8-device host), so build the Mesh
    explicitly."""
    if dp < 1 or tp < 1:
        raise ValueError(f"mesh axes must be >= 1, got dp={dp}, tp={tp}")
    devices = list(devices if devices is not None else jax.devices())
    need = dp * tp
    if len(devices) < need:
        raise ValueError(
            f"mesh ({dp},{tp}) needs {need} devices, have {len(devices)}")
    grid = np.asarray(devices[:need]).reshape(dp, tp)
    return ServingMesh(dp=dp, tp=tp, mesh=Mesh(grid, (DP_AXIS, TP_AXIS)))


def validate_serving(cfg, executor: Optional[str], smesh: ServingMesh) -> None:
    """Check the model + executor against the mesh's sharding contract."""
    if smesh.tp > 1 and cfg.num_kv_heads % smesh.tp != 0:
        raise ValueError(
            f"tp={smesh.tp} must divide num_kv_heads={cfg.num_kv_heads}")
    if smesh.tp > 1 and cfg.num_heads % smesh.tp != 0:
        raise ValueError(
            f"tp={smesh.tp} must divide num_heads={cfg.num_heads}")
    if smesh.tp > 1 and executor is not None:
        from repro.core import policy as policy_lib
        spec = policy_lib.get_paged_executor(executor)
        if spec.sharding != "kv-head":
            raise ValueError(
                f"executor {executor!r} declares sharding="
                f"{spec.sharding!r}; tp>1 requires 'kv-head'")


def pool_sharding(smesh: ServingMesh) -> NamedSharding:
    return NamedSharding(smesh.mesh, POOL_SPEC)


def group_sharding(smesh: ServingMesh) -> NamedSharding:
    """Sharding for per-slot-group host arrays — leading ``(dp,)`` axis
    split over data-parallel groups, everything else replicated.  The
    async engine's device-resident fed-back-token buffer lives here:
    each group's decode lanes read their own sampled ids locally, so the
    per-step logits all-gather is replaced by a ``(dp, S) int32`` fetch."""
    return NamedSharding(smesh.mesh, GROUP_SPEC)


def shard_pools(pools, smesh: ServingMesh):
    """Broadcast freshly-initialised pools to ``(dp,)+shape`` and place
    them: dp slot groups each get a full pool copy, KV-head axis sharded
    over tp.  All groups start from the same pristine pool, so group 0 of
    a dp>1 engine is bit-identical to a single-device pool."""
    sh = pool_sharding(smesh)

    def place(leaf):
        grouped = jnp.broadcast_to(leaf, (smesh.dp,) + leaf.shape)
        return jax.device_put(grouped, sh)

    return jax.tree.map(place, pools)


# ---------------------------------------------------------------------------
# Head-sharding context (consumed by models/attention.py)
# ---------------------------------------------------------------------------

_TP_CTX = threading.local()


@contextlib.contextmanager
def head_sharding(tp: int):
    """Activate TP head slicing for code traced inside this context.

    The shard-mapped unified step wraps its trace in this context so
    ``apply_decode_paged`` / ``apply_chunk_paged`` slice their local
    heads and all-gather the attention output.  tp<=1 keeps the helpers
    as identity."""
    prev = getattr(_TP_CTX, "tp", None)
    _TP_CTX.tp = tp if tp and tp > 1 else None
    try:
        yield
    finally:
        _TP_CTX.tp = prev


def active_tp() -> Optional[int]:
    return getattr(_TP_CTX, "tp", None)


def local_heads(x: jnp.ndarray, axis: int = 1) -> jnp.ndarray:
    """Slice this shard's contiguous head block out of a full-head tensor.

    Inside the head-sharding context the full projections are computed
    replicated (bitwise equal on every shard); each shard then keeps
    heads ``[rank*h_loc, (rank+1)*h_loc)``.  Slicing whole KV-head groups
    keeps the GQA group_reduce intact.  No-op outside the context."""
    tp = active_tp()
    if tp is None:
        return x
    h = x.shape[axis]
    h_loc = h // tp
    start = jax.lax.axis_index(TP_AXIS) * h_loc
    return jax.lax.dynamic_slice_in_dim(x, start, h_loc, axis)


def gather_heads(x: jnp.ndarray, axis: int = 1) -> jnp.ndarray:
    """Reassemble per-shard head blocks into the full head axis.

    ``tiled=True`` concatenates along ``axis`` in rank order — the exact
    inverse of :func:`local_heads` — so the output projection sees the
    same operand it would single-device.  No-op outside the context."""
    tp = active_tp()
    if tp is None:
        return x
    return jax.lax.all_gather(x, TP_AXIS, axis=axis, tiled=True)
