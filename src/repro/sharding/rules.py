"""Logical-axis sharding rules (MaxText-style) with shape-aware resolution.

Every parameter/activation carries a tuple of logical axis names (set at
init time in models/*).  ``logical_rules`` maps logical axes to mesh axes
for a given arch + mesh; ``spec_for`` resolves a concrete shape to a
``PartitionSpec``, dropping any mesh axis that does not divide the dimension
(so e.g. gemma's 8 heads on a model=16 axis fall back to replication instead
of uneven padding — recorded in the roofline notes).

Parallelism mapping (DESIGN.md §4):
  pod   — data parallelism across pods (gradient all-reduce only)
  data  — data parallelism + FSDP weight sharding (``fsdp_weights`` archs)
  model — tensor parallelism: heads / mlp / vocab / experts / rnn channels
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

MeshAxes = Optional[tuple[str, ...]]


def _mesh_size(mesh: Mesh, names: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[n] for n in names]))


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def logical_rules(cfg: ArchConfig, mesh: Mesh) -> dict[str, tuple[str, ...]]:
    """logical axis -> mesh axes (tuple; () means replicate)."""
    batch = data_axes(mesh)
    rules: dict[str, tuple[str, ...]] = {
        "vocab": ("model",),
        "mlp": ("model",),
        "experts": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "rnn": ("model",),
        "rnn_in": (),       # gate-weight contraction dim (see rglru.init)
        "expert_mlp": (),
        "embed": (),
        "head_dim": (),
        "q_lora": (),
        "kv_lora": (),
        "conv": (),
        "layers": (),
        "batch": batch,
        "seq": (),
        "kv_seq": ("model",),   # cache fallback: shard cache length over TP
        "frames": (),
        "expert_capacity": (),
    }
    if cfg.fsdp_weights:
        # ZeRO-3-style: additionally shard the big replicated weight dim over
        # the data axis; GSPMD all-gathers at use and reduce-scatters grads.
        rules["embed"] = ("data",)
        rules["expert_mlp"] = ("data",) if cfg.moe else ()
    return rules


def spec_for(shape: tuple[int, ...], axes, rules: dict, mesh: Mesh) -> P:
    """Shape-aware PartitionSpec: only keep mesh axes that divide the dim."""
    if axes is None:
        return P()
    entries = []
    used: set[str] = set()
    for dim, name in zip(shape, axes):
        if name is None:
            entries.append(None)
            continue
        mesh_names = rules.get(name, ())
        mesh_names = tuple(m for m in mesh_names if m not in used)
        if mesh_names and dim % _mesh_size(mesh, mesh_names) == 0:
            entries.append(mesh_names if len(mesh_names) > 1 else mesh_names[0])
            used.update(mesh_names)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_shardings(cfg: ArchConfig, mesh: Mesh, values_tree, axes_tree):
    """NamedSharding tree matching a (ShapeDtypeStruct|array) values tree."""
    rules = logical_rules(cfg, mesh)

    def one(v, axes):
        return NamedSharding(mesh, spec_for(tuple(v.shape), axes, rules, mesh))

    return jax.tree.map(one, values_tree, axes_tree,
                        is_leaf=lambda t: isinstance(t, tuple) and all(
                            isinstance(e, (str, type(None))) for e in t))


def zero1_shardings(cfg: ArchConfig, mesh: Mesh, values_tree, base_shardings):
    """ZeRO-1: optimizer-state tree additionally sharded over the data (and
    pod) axes on the largest divisible dims.  Params stay DP-replicated for
    the forward (one all-gather per step, not per layer); pinned grads
    reduce-scatter into the ZeRO shard."""
    extra = [a for a in ("pod", "data") if a in mesh.axis_names]

    def upgrade(v, sh):
        spec = list(sh.spec) + [None] * (len(v.shape) - len(sh.spec))
        used = {n for e in spec if e is not None
                for n in ((e,) if isinstance(e, str) else e)}
        for ax in extra:
            if ax in used:
                continue
            order = sorted(range(len(v.shape)), key=lambda i: -v.shape[i])
            for i in order:
                entry = spec[i]
                names = () if entry is None else (
                    (entry,) if isinstance(entry, str) else tuple(entry))
                cur = _mesh_size(mesh, names) if names else 1
                if v.shape[i] % (cur * int(mesh.shape[ax])) == 0:
                    spec[i] = (ax,) + names if names else ax
                    used.add(ax)
                    break
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(upgrade, values_tree, base_shardings)


def batch_sharding(cfg: ArchConfig, mesh: Mesh, spec_tree):
    """Shardings for a batch dict of (b, ...) arrays: batch dim on data axes,
    everything else replicated."""
    rules = logical_rules(cfg, mesh)

    def one(v):
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        return NamedSharding(mesh, spec_for(tuple(v.shape), axes, rules, mesh))

    return jax.tree.map(one, spec_tree)


def cache_shardings(cfg: ArchConfig, mesh: Mesh, cache_tree):
    """Shardings for serve-step caches, assigned by leaf shape heuristics.

    Known leaf layouts (all with a leading stacked-layers dim):
      (L, b, hk, S, dh)  attention KV         -> batch data, heads model;
                          when kv_heads doesn't divide the model axis, the
                          cache *length* S shards over model instead
                          (flash-decoding layout; spec_for's shape-aware
                          fallback realizes this via axis-order preference)
      (L, b, S, r)       MLA latent / rope    -> batch data, S model
      (L, b, w)          RG-LRU state         -> width model
      (L, b, cw, w)      conv tails           -> width model
      (L, b, h, p, N)    SSD state            -> heads model
      (L,) / scalar      positions            -> replicated
    """
    rules = logical_rules(cfg, mesh)

    def one(v):
        shp = tuple(v.shape)
        nd = len(shp)
        if nd == 5:
            axes = (None, "batch", "kv_heads", "kv_seq", None)
            if cfg.ssd is not None:
                axes = (None, "batch", "heads", None, None)
        elif nd == 4:
            if cfg.mla is not None:
                axes = (None, "batch", "kv_seq", None)
            else:
                axes = (None, "batch", None, "rnn")
        elif nd == 3:
            axes = (None, "batch", "rnn") if (cfg.rglru or cfg.ssd) else (None, "batch", "kv_seq")
        else:
            axes = (None,) * nd
        return NamedSharding(mesh, spec_for(shp, axes[:nd], rules, mesh))

    return jax.tree.map(one, cache_tree)
