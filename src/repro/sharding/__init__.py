from repro.sharding.rules import (
    batch_sharding,
    cache_shardings,
    logical_rules,
    param_shardings,
    spec_for,
)

__all__ = [
    "logical_rules",
    "spec_for",
    "param_shardings",
    "batch_sharding",
    "cache_shardings",
]
