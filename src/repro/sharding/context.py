"""Activation-sharding context: lets model code pin intermediate shardings
by *logical* axes without knowing mesh axis names.

The launch layer (dryrun/train/serve) wraps tracing in ``use(cfg, mesh)``;
model code calls ``constrain(x, ("batch", "experts", None, None))`` at the
few points where GSPMD's propagation is known to give up (data-dependent
scatters: the MoE dispatch buffer) or where we want to force a boundary
(post-attention / post-FFN residuals).  Outside the context (unit tests,
single-device runs) ``constrain`` is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding

from repro.sharding import rules as rules_lib

_STATE = threading.local()


@contextlib.contextmanager
def use(cfg, mesh):
    rules = rules_lib.logical_rules(cfg, mesh)
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (rules, mesh)
    try:
        yield
    finally:
        _STATE.ctx = prev


def current():
    """(rules, mesh) if inside a ``use`` context, else None."""
    return getattr(_STATE, "ctx", None)


def constrain(x: jax.Array, axes: tuple) -> jax.Array:
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    rules, mesh = ctx
    spec = rules_lib.spec_for(tuple(x.shape), axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
