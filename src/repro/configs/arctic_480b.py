"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000; dense-MoE hybrid: 128 experts top-2 + parallel dense residual
FFN. [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    activation="silu",
    tie_embeddings=False,
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        expert_d_ff=4864,
        residual_dense=True,
        residual_d_ff=4864,
    ),
    use_stem=True,
    fsdp_weights=True,
    train_microbatches=8,
)
