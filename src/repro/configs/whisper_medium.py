"""whisper-medium [audio] — 24L(+24 enc) d_model=1024 16H d_ff=4096
vocab=51865; encoder-decoder, conv frontend STUB (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]

Stem applies to decoder self-attention only (encoder is bidirectional —
no causal-flow asymmetry; DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    activation="gelu_mlp",
    norm="layer",
    tie_embeddings=True,
    encdec=EncDecConfig(encoder_layers=24, encoder_frames=1500),
    use_stem=True,
    train_microbatches=4,
)
