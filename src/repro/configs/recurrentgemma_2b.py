"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000; RG-LRU + local attention 1:2 (griffin).  [arXiv:2402.19427; hf]

Sub-quadratic: RG-LRU recurrence + 2048-token windowed local attention, so
the long_500k decode cell runs.  Stem is documented inapplicable to the
RG-LRU layers and degenerate for the 2048-window local layers (DESIGN §5).
"""
from repro.configs.base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    activation="gelu",
    tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4, attn_period=3, window=2048),
    use_stem=False,
    sub_quadratic=True,
    train_microbatches=4,
)
