"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff(expert)=2048
vocab=129280; MLA, 1 shared + 256 routed experts top-8, first 3 layers
dense (d_ff 18432), MTP head. [arXiv:2412.19437; hf]

Stem integration mirrors the paper's DeepSeek-V3.2 DSA experiment: the TPD
schedule wraps block top-k over MLA's expanded keys, OAM uses latent norms.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=192,               # nope 128 + rope 64 (MLA)
    d_ff=2048,                  # routed-expert FFN width
    vocab_size=129280,
    activation="silu",
    tie_embeddings=False,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        expert_d_ff=2048,
        shared_experts=1,
        shared_d_ff=2048,
        first_k_dense=3,
        first_dense_d_ff=18432,
        capacity_factor=1.25,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
    ),
    mtp=True,
    use_stem=True,
    fsdp_weights=True,
    train_microbatches=8,
)
