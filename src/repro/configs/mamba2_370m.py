"""mamba2-370m [ssm] — 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]

Stem is inapplicable (no attention map to sparsify) — the arch runs without
it, per DESIGN.md §Arch-applicability.  Sub-quadratic by construction.
"""
from repro.configs.base import ArchConfig, SSDConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=32,               # d_inner / head_dim = 2048 / 64
    num_kv_heads=32,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssd=SSDConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk_size=128),
    use_stem=False,
    sub_quadratic=True,
    train_microbatches=4,
)
