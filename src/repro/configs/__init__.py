"""Config registry: ``get_config(arch_id)`` + reduced smoke variants.

Also includes the paper's own evaluation backbones (llama3.1-8b-class and
qwen3-8b-class) so the benchmark harness can exercise the exact families the
paper reports on.
"""
from __future__ import annotations

import dataclasses

from repro.configs import base
from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ArchConfig,
    EncDecConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    RunShape,
    SSDConfig,
    shapes_for,
)

from repro.configs.qwen3_0_6b import CONFIG as QWEN3_0_6B
from repro.configs.glm4_9b import CONFIG as GLM4_9B
from repro.configs.gemma_2b import CONFIG as GEMMA_2B
from repro.configs.qwen1_5_4b import CONFIG as QWEN1_5_4B
from repro.configs.recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B
from repro.configs.arctic_480b import CONFIG as ARCTIC_480B
from repro.configs.deepseek_v3_671b import CONFIG as DEEPSEEK_V3_671B
from repro.configs.mamba2_370m import CONFIG as MAMBA2_370M
from repro.configs.whisper_medium import CONFIG as WHISPER_MEDIUM
from repro.configs.pixtral_12b import CONFIG as PIXTRAL_12B

# The paper's own dense evaluation backbones (Section 3.1).
LLAMA31_8B = ArchConfig(
    name="llama3.1-8b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
    vocab_size=128256, activation="silu", rope_theta=5e5,
    tie_embeddings=False, use_stem=True,
)
QWEN3_8B = ArchConfig(
    name="qwen3-8b", family="dense", num_layers=36, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=12288,
    vocab_size=151936, activation="silu", qk_norm=True, rope_theta=1e6,
    tie_embeddings=False, use_stem=True,
)

ASSIGNED = {
    c.name: c
    for c in (
        QWEN3_0_6B, GLM4_9B, GEMMA_2B, QWEN1_5_4B, RECURRENTGEMMA_2B,
        ARCTIC_480B, DEEPSEEK_V3_671B, MAMBA2_370M, WHISPER_MEDIUM,
        PIXTRAL_12B,
    )
}
EXTRA = {c.name: c for c in (LLAMA31_8B, QWEN3_8B)}
ALL = {**ASSIGNED, **EXTRA}


def get_config(name: str) -> ArchConfig:
    if name not in ALL:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ALL)}")
    return ALL[name]


def reduced(cfg: ArchConfig) -> ArchConfig:
    """CPU smoke-test variant of the same family: tiny widths/layers/tables,
    identical code paths (GQA ratios, MoE routing, MLA, hybrid pattern,
    leftover-layer handling, MTP, stubs all preserved)."""
    kv_ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    heads = 4
    kv = max(1, heads // kv_ratio)
    kw: dict = dict(
        name=cfg.name + "-reduced",
        num_layers=2,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
    )
    if cfg.family == "hybrid":
        kw["num_layers"] = 4  # one full (rec, rec, attn) group + 1 leftover rec
        kw["rglru"] = RGLRUConfig(lru_width=64, conv_width=4, attn_period=3, window=32)
    if cfg.ssd is not None:
        kw["ssd"] = SSDConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                              chunk_size=32)
        kw["num_heads"] = kw["num_kv_heads"] = 8  # d_inner 128 / head_dim 16
    if cfg.moe is not None:
        kw["num_layers"] = 3
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=8,
            top_k=min(cfg.moe.top_k, 2),
            expert_d_ff=64,
            shared_d_ff=64 if cfg.moe.shared_experts else 0,
            residual_d_ff=64 if cfg.moe.residual_dense else 0,
            first_k_dense=1 if cfg.moe.first_k_dense else 0,
            first_dense_d_ff=128 if cfg.moe.first_k_dense else 0,
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                              nope_head_dim=16, v_head_dim=16)
        kw["head_dim"] = 24
    if cfg.encdec is not None:
        kw["encdec"] = EncDecConfig(encoder_layers=2, encoder_frames=16)
    return cfg.replace(**kw)
