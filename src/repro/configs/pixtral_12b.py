"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; pixtral-ViT frontend STUB + mistral-nemo-style decoder
backbone.  [hf:mistralai/Pixtral-12B-2409; unverified]

The vision tower is a stub per the brief: input_specs supplies precomputed
patch embeddings occupying the first 1/4 of the sequence; loss is computed
on the text positions.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    activation="silu",
    rope_theta=1e6,
    tie_embeddings=False,
    vlm_stub=True,
    use_stem=True,
    fsdp_weights=True,
    train_microbatches=4,
)
