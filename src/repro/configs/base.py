"""Architecture + run-shape configuration dataclasses.

One ``ArchConfig`` instance per assigned architecture lives in
``src/repro/configs/<id>.py``; ``reduced()`` derives the CPU smoke-test
variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    capacity_factor: float = 1.25
    shared_experts: int = 0          # deepseek: 1 shared expert
    shared_d_ff: int = 0
    residual_dense: bool = False     # arctic: dense FFN branch in parallel
    residual_d_ff: int = 0
    first_k_dense: int = 0           # deepseek: first 3 layers are dense
    first_dense_d_ff: int = 0
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int
    conv_width: int = 4
    attn_period: int = 3        # 1 attention layer per `period` (griffin 1:2)
    window: int = 2048          # local-attention window


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 128


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int
    encoder_frames: int = 1500   # whisper: fixed 30 s of 2x-downsampled frames


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    activation: str = "silu"      # silu -> SwiGLU; gelu -> GeGLU; gelu_mlp -> plain GELU
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    norm: str = "rms"             # rms | layer
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    ssd: Optional[SSDConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm_stub: bool = False        # inputs include precomputed patch embeddings
    mtp: bool = False             # deepseek multi-token prediction head
    mtp_weight: float = 0.3
    use_stem: bool = True         # paper technique applies to this arch
    embed_scale: bool = False     # gemma-family sqrt(d_model) embedding scale
    sub_quadratic: bool = False   # supports 500k decode (SSM / windowed attn)
    fsdp_weights: bool = False    # additionally shard big weight dims on data
    train_microbatches: int = 1   # gradient accumulation (activation memory)
    dtype: str = "bfloat16"
    # Parameter count for MODEL_FLOPS = 6 N D (filled by configs; computed if 0).
    approx_params: float = 0.0
    approx_active_params: float = 0.0   # MoE: active per token

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def embed_scale_flag(self) -> bool:
        return self.embed_scale or self.family == "hybrid"

    @property
    def padded_vocab(self) -> int:
        """Embedding-table / logits vocab padded to a multiple of 256 so the
        vocab axis always TP-shards (Megatron-style padding; whisper's 51865
        and mamba2's 50280 are otherwise indivisible and replicate fp32
        logits).  Token ids stay < vocab_size."""
        return -(-self.vocab_size // 256) * 256

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class RunShape:
    """One (input-shape) cell: what gets lowered in the dry-run."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


TRAIN_4K = RunShape("train_4k", 4096, 256, "train")
PREFILL_32K = RunShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = RunShape("decode_32k", 32768, 128, "decode")
LONG_500K = RunShape("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ArchConfig):
    """The assigned shape set, with the brief's long_500k skip for pure
    full-attention architectures."""
    if cfg.sub_quadratic:
        return ALL_SHAPES
    return (TRAIN_4K, PREFILL_32K, DECODE_32K)
