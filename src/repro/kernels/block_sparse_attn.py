"""Stem block-sparse attention as a Pallas TPU kernel (scalar prefetch).

TPU adaptation of the paper's Triton Block-Sparse-Attention execution phase
(Algorithm 1, lines 18-22).  The per-query-block Top-k(i) key-block indices
are computed outside the kernel (the coarse metric is only (N/B)^2) and
passed as **scalar-prefetch** operands so the DMA engine streams exactly the
selected HBM key/value blocks into VMEM — the TPU-native replacement for a
GPU gather:

  * ``pltpu.PrefetchScalarGridSpec(num_scalar_prefetch=2)`` carries
    ``indices`` (b, hq, nq, k_max) and ``slot_mask`` (same shape, int32).
  * The K/V ``BlockSpec.index_map`` reads ``indices[b, h, i, s]`` to pick the
    HBM block for grid step (bh, i, s); dead (padded) slots point at block 0
    and are skipped with ``@pl.when`` so they cost one redundant DMA but no
    FLOPs and no softmax mass.
  * The slot axis is the sequential ("arbitrary") grid dimension; the
    online-softmax state (m, l, acc) lives in VMEM scratch across slots.
  * Per-row variable budget k(i) (Token Position-Decay) is exactly the
    pattern this supports: rows simply have more or fewer live slots.

VMEM per program: q + k + v tiles (block x d) + acc (block_q x d fp32)
+ m/l vectors — ~0.5 MiB at B = 128, d = 128 (double-buffered K/V included),
comfortably inside the ~16 MiB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _sparse_kernel(
    idx_ref, msk_ref,          # scalar prefetch (SMEM)
    q_ref, k_ref, v_ref,       # VMEM tiles
    o_ref,
    acc_ref, m_ref, l_ref,     # VMEM scratch
    *,
    scale: float,
    block_q: int,
    block_k: int,
    k_max: int,
    q_heads: int,
):
    bh = pl.program_id(0)
    i = pl.program_id(1)
    s = pl.program_id(2)
    bi = bh // q_heads
    hi = bh % q_heads

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    live = msk_ref[bi, hi, i, s] != 0

    @pl.when(live)
    def _compute():
        j = idx_ref[bi, hi, i, s]
        q = q_ref[0, ...].astype(jnp.float32) * scale     # (bq, d)
        k = k_ref[0, 0, ...].astype(jnp.float32)          # (bk, d)
        sc = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        causal = k_pos <= q_pos
        sc = jnp.where(causal, sc, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, sc.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[:, None])
        p = jnp.where(causal, p, 0.0)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        v = v_ref[0, 0, ...].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(s == k_max - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_size", "scale", "interpret")
)
def block_sparse_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    indices: jnp.ndarray,
    slot_mask: jnp.ndarray,
    *,
    block_size: int = 128,
    scale: float | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Sparse attention over selected key blocks.

    Args:
      q: (b, hq, n, d); k, v: (b, hk, n_k, d).
      indices: (b, hq, nq, k_max) int32 selected key-block ids.
      slot_mask: (b, hq, nq, k_max) bool validity of each slot.
      block_size: B (query and key tiles share it, as in the paper).

    Returns:
      (b, hq, n, d) attention output.
    """
    b, hq, n, d = q.shape
    _, hk, n_k, _ = k.shape
    dv = v.shape[-1]
    group = hq // hk
    nq = n // block_size
    k_max = indices.shape[-1]
    scale = (d ** -0.5) if scale is None else scale

    qr = q.reshape(b * hq, n, d)
    msk = slot_mask.astype(jnp.int32)

    def q_map(bh, i, s, idx_ref, msk_ref):
        return (bh, i, 0)

    def kv_map(bh, i, s, idx_ref, msk_ref):
        bi = bh // hq
        hi = bh % hq
        j = idx_ref[bi, hi, i, s]
        return (bi, hi // group, j, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * hq, nq, k_max),
        in_specs=[
            pl.BlockSpec((1, block_size, d), q_map),
            pl.BlockSpec((1, 1, block_size, d), kv_map),
            pl.BlockSpec((1, 1, block_size, dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_size, dv), q_map),
        scratch_shapes=[
            pltpu.VMEM((block_size, dv), jnp.float32),
            pltpu.VMEM((block_size,), jnp.float32),
            pltpu.VMEM((block_size,), jnp.float32),
        ],
    )

    kernel = functools.partial(
        _sparse_kernel,
        scale=scale,
        block_q=block_size,
        block_k=block_size,
        k_max=k_max,
        q_heads=hq,
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hq, n, dv), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="stem_block_sparse_attention",
    )(indices, msk, qr, k, v)
    return out.reshape(b, hq, n, dv)
