"""Stem block-sparse attention as a Pallas TPU kernel (scalar prefetch).

TPU adaptation of the paper's Triton Block-Sparse-Attention execution phase
(Algorithm 1, lines 18-22).  The per-query-block Top-k(i) key-block indices
are computed outside the kernel (the coarse metric is only (N/B)^2) and
passed as **scalar-prefetch** operands so the DMA engine streams exactly the
selected HBM key/value blocks into VMEM — the TPU-native replacement for a
GPU gather:

  * ``pltpu.PrefetchScalarGridSpec(num_scalar_prefetch=2)`` carries
    ``indices`` (b, h_sel, nq, k_max) and per-row ``live_counts``
    (b, h_sel, nq) int32.
  * The K/V ``BlockSpec.index_map`` reads ``indices[b, h, i, s]`` to pick the
    HBM block for grid step (bh, i, s).  Indices are *revisit-filled*
    (selection.revisit_indices): every dead (padded) slot re-points at the
    row's last live block, so consecutive dead steps map to the same block
    index and the Pallas pipeline skips the DMA entirely — dead slots cost
    **zero new DMAs** (splash-attention's revisit trick), not one redundant
    fetch each as in the padded layout.
  * Per-row variable budget k(i) (Token Position-Decay) is exactly the
    pattern this supports: rows compute only their ``live_count`` slots
    (``@pl.when(s < cnt)``) and finalize at ``live_count - 1`` instead of
    ``k_max - 1``.
  * GQA block dedup (``group_dedup=True``): when selection is shared across
    the query heads of a KV group (cfg.group_reduce != "none"), the grid
    iterates KV heads and the query tile fuses the whole group,
    (group * block_q, d) — each K/V block is fetched once per *KV head*,
    cutting DMA traffic by the group factor (8x on glm4-9b).

VMEM per program: q tile (group x block x d) + k/v tiles (block x d) + acc
(group * block_q x d fp32) + m/l vectors — ~0.5 MiB at B = 128, d = 128,
group 1 (double-buffered K/V included) and still < 4 MiB at group 8,
comfortably inside the ~16 MiB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# No import cycle: repro.core.selection depends only on jax/numpy, and
# repro.core.sparse_attention defers its kernels import to call time.
from repro.core.selection import revisit_indices
from repro.kernels import pltpu_compat

NEG_INF = -1e30


def _sparse_kernel(
    idx_ref, cnt_ref,          # scalar prefetch (SMEM)
    q_ref, k_ref, v_ref,       # VMEM tiles
    o_ref,
    acc_ref, m_ref, l_ref,     # VMEM scratch
    *,
    scale: float,
    block_q: int,
    block_k: int,
    group: int,
    sel_heads: int,
):
    bh = pl.program_id(0)
    i = pl.program_id(1)
    s = pl.program_id(2)
    bi = bh // sel_heads
    hi = bh % sel_heads
    rows = group * block_q

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cnt = cnt_ref[bi, hi, i]

    @pl.when(s < cnt)
    def _compute():
        j = idx_ref[bi, hi, i, s]
        # (group, bq, d) -> fused (group * bq, d) query tile.
        q = q_ref[0, ...].reshape(rows, q_ref.shape[-1])
        q = q.astype(jnp.float32) * scale
        k = k_ref[0, 0, ...].astype(jnp.float32)          # (bk, d)
        sc = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        # Row r of the fused tile is query position i*bq + (r % bq) (the
        # group axis is the leading tile dim, so positions repeat per head).
        r = jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0)
        q_pos = i * block_q + jax.lax.rem(r, block_q)
        k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 1)
        causal = k_pos <= q_pos
        sc = jnp.where(causal, sc, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, sc.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[:, None])
        p = jnp.where(causal, p, 0.0)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        v = v_ref[0, 0, ...].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    # Ragged finalize: each row writes its output at its *own* last live
    # slot; the trailing dead steps touch nothing (and fetch nothing, thanks
    # to the revisit index map).  max() guards pathological cnt == 0 rows.
    @pl.when(s == jnp.maximum(cnt - 1, 0))
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        out = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        o_ref[0, ...] = out.reshape(group, block_q, o_ref.shape[-1])


@functools.partial(
    jax.jit, static_argnames=("block_size", "scale", "interpret", "group_dedup")
)
def block_sparse_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    indices: jnp.ndarray,
    slot_mask: jnp.ndarray,
    *,
    block_size: int = 128,
    scale: float | None = None,
    interpret: bool = True,
    group_dedup: bool = False,
    live_counts: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Sparse attention over selected key blocks.

    Args:
      q: (b, hq, n, d); k, v: (b, hk, n_k, d).
      indices: (b, h_sel, nq, k_max) int32 selected key-block ids, where
        h_sel = hq normally or hk with ``group_dedup`` (selection shared
        across each KV group, e.g. one head sliced out per group).
      slot_mask: (b, h_sel, nq, k_max) bool validity of each slot.  Live
        slots must form a prefix (the select_blocks contract); the kernel
        consumes the per-row count, not the mask.
      live_counts: (b, h_sel, nq) int32 per-row live-slot counts
        (BlockSelection.live_counts); derived from slot_mask when omitted.
      block_size: B (query and key tiles share it, as in the paper).
      group_dedup: fetch K/V once per KV head with a fused
        (group * block_q, d) query tile; requires identical selection across
        each group (cfg.group_reduce != "none").

    Returns:
      (b, hq, n, d) attention output.
    """
    b, hq, n, d = q.shape
    _, hk, n_k, _ = k.shape
    dv = v.shape[-1]
    nq = n // block_size
    k_max = indices.shape[-1]
    scale = (d ** -0.5) if scale is None else scale

    sel_heads = indices.shape[1]
    if group_dedup:
        if sel_heads != hk:
            raise ValueError(f"group_dedup expects {hk} selection heads, got {sel_heads}")
        group = hq // hk
        kv_div = 1
    else:
        if sel_heads != hq:
            raise ValueError(f"expected {hq} selection heads, got {sel_heads}")
        group = 1
        kv_div = hq // hk

    cnt = (slot_mask.astype(jnp.int32).sum(axis=-1)
           if live_counts is None else live_counts.astype(jnp.int32))
    idx = revisit_indices(indices, slot_mask)
    # (b, hk, group, n, d) -> grid rows over selection heads, fused q tile.
    qr = q.reshape(b, sel_heads, group, n, d).reshape(b * sel_heads, group, n, d)

    def q_map(bh, i, s, idx_ref, cnt_ref):
        return (bh, 0, i, 0)

    def kv_map(bh, i, s, idx_ref, cnt_ref):
        bi = bh // sel_heads
        hi = bh % sel_heads
        j = idx_ref[bi, hi, i, s]
        return (bi, hi // kv_div, j, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * sel_heads, nq, k_max),
        in_specs=[
            pl.BlockSpec((1, group, block_size, d), q_map),
            pl.BlockSpec((1, 1, block_size, d), kv_map),
            pl.BlockSpec((1, 1, block_size, dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, group, block_size, dv), q_map),
        scratch_shapes=[
            pltpu.VMEM((group * block_size, dv), jnp.float32),
            pltpu.VMEM((group * block_size,), jnp.float32),
            pltpu.VMEM((group * block_size,), jnp.float32),
        ],
    )

    kernel = functools.partial(
        _sparse_kernel,
        scale=scale,
        block_q=block_size,
        block_k=block_size,
        group=group,
        sel_heads=sel_heads,
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * sel_heads, group, n, dv), q.dtype),
        compiler_params=pltpu_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="stem_block_sparse_attention",
    )(idx, cnt, qr, k, v)
    return out.reshape(b, sel_heads * group, n, dv)
