"""Dense causal flash attention as a Pallas TPU kernel.

This is the dense baseline of the paper (FlashAttention-2 role) expressed
TPU-natively:

  * grid = (batch * q_heads, num_q_blocks, num_k_blocks); the last grid
    dimension is sequential ("arbitrary") so the online-softmax state lives
    in VMEM scratch across key steps,
  * Q/K/V tiles are (block, head_dim) VMEM blocks (BlockSpec index maps fold
    the GQA head mapping: key/value blocks come from head h // group),
  * causal masking skips whole key blocks above the diagonal via
    ``@pl.when`` and applies an exact intra-block mask on the diagonal,
  * accumulation in fp32, output cast back to the input dtype.

VMEM working set per program (fp32): q(bq x d) + k,v(bk x d each, double
buffered) + acc(bq x d) + m,l(bq) — for bq = bk = 128, d <= 256 this is
< 1 MiB, far under the ~16 MiB/core budget; the MXU sees native 128-wide
matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pltpu_compat

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # VMEM tiles
    o_ref,                # output tile
    acc_ref, m_ref, l_ref,  # VMEM scratch
    *,
    scale: float,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    i = pl.program_id(1)  # query block
    j = pl.program_id(2)  # key block (sequential)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal: key block j is admissible iff j <= i (aligned grids).
    @pl.when(j <= i)
    def _compute():
        q = q_ref[0, ...].astype(jnp.float32) * scale    # (bq, d)
        k = k_ref[0, 0, ...].astype(jnp.float32)         # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)

        # Exact intra-block causal mask on the diagonal block.
        q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(k_pos <= q_pos, p, 0.0)
        l_ref[...] = l_prev * corr + p.sum(axis=-1)
        v = v_ref[0, 0, ...].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(j == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "scale", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_q: int = 128,
    block_k: int = 128,
    scale: float | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Causal flash attention.  q: (b, hq, n, d); k, v: (b, hk, n, d)."""
    b, hq, n, d = q.shape
    _, hk, nk_len, _ = k.shape
    dv = v.shape[-1]
    if n != nk_len:
        raise ValueError("flash_attention requires seq_q == seq_k (causal self-attn)")
    if n % block_q or n % block_k:
        raise ValueError("sequence length must be divisible by block sizes")
    group = hq // hk
    scale = (d ** -0.5) if scale is None else scale
    num_q, num_k = n // block_q, n // block_k

    qr = q.reshape(b * hq, n, d)

    grid = (b * hq, num_q, num_k)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=num_k,
    )

    def q_map(bh, i, j):
        return (bh, i, 0)

    def kv_map(bh, i, j):
        # Fold GQA: query head bh % hq maps to kv head (bh % hq) // group.
        return (bh // hq, (bh % hq) // group, j, 0)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
            pl.BlockSpec((1, 1, block_k, dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, n, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dv), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=pltpu_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="dense_flash_attention",
    )(qr, k, v)
    return out.reshape(b, hq, n, dv)
