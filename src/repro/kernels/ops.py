"""jit'd public wrappers for the Pallas kernels.

On a real TPU fleet these dispatch to compiled Mosaic kernels
(``interpret=False``); in this CPU container they default to interpret mode,
which executes the identical kernel body in Python and is what the
per-kernel allclose tests sweep against ``ref.py``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import block_sparse_attn as _bsa
from repro.kernels import flash_attention as _fa
from repro.kernels import stem_metric as _sm

# Flip to False on real TPU hardware (launch scripts do this via env).
INTERPRET = True


def flash_attention(q, k, v, *, block_q=128, block_k=128, scale=None):
    return _fa.flash_attention(
        q, k, v, block_q=block_q, block_k=block_k, scale=scale, interpret=INTERPRET
    )


def block_sparse_attention(q, k, v, indices, slot_mask, *, block_size=128, scale=None,
                           group_dedup=False, live_counts=None):
    return _bsa.block_sparse_attention(
        q, k, v, indices, slot_mask,
        block_size=block_size, scale=scale, interpret=INTERPRET,
        group_dedup=group_dedup, live_counts=live_counts,
    )


def antidiag_pool(x, *, block_size=128, stride=16):
    return _sm.antidiag_pool(x, block_size=block_size, stride=stride, interpret=INTERPRET)


def value_magnitude(v, *, block_size=128):
    return _sm.value_magnitude(v, block_size=block_size, interpret=INTERPRET)
