"""Fused metric-downsampling Pallas kernels (Algorithm 1, lines 4-6).

Two small memory-bound kernels that stream Q/K/V once through VMEM:

  * ``antidiag_pool``     — per 128-token block, the ``stride`` group-mean
    vectors used by separable anti-diagonal scoring (DESIGN.md §3).
  * ``value_magnitude``   — per block, max-pooled log ||V_j||_2.

Both read each HBM element exactly once (arithmetic intensity ~ O(1)), so a
fused single-pass kernel is the right TPU shape — the jnp fallback
materializes a (n, d) reshape + reduce which XLA usually also fuses, but the
kernel guarantees it and keeps the block layout aligned with the attention
kernel's 128-token granularity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pltpu_compat


def _pool_kernel(x_ref, o_ref, *, block_size: int, stride: int):
    x = x_ref[0, ...].astype(jnp.float32)           # (block, d)
    d = x.shape[-1]
    xg = x.reshape(block_size // stride, stride, d)  # position p = g*stride + u
    o_ref[0, 0, ...] = xg.mean(axis=0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_size", "stride", "interpret"))
def antidiag_pool(
    x: jnp.ndarray, *, block_size: int = 128, stride: int = 16, interpret: bool = True
) -> jnp.ndarray:
    """(b, h, n, d) -> (b, h, n/block, stride, d) group means."""
    b, h, n, d = x.shape
    nb = n // block_size
    xr = x.reshape(b * h, n, d)
    out = pl.pallas_call(
        functools.partial(_pool_kernel, block_size=block_size, stride=stride),
        grid=(b * h, nb),
        in_specs=[pl.BlockSpec((1, block_size, d), lambda bh, i: (bh, i, 0))],
        out_specs=pl.BlockSpec((1, 1, stride, d), lambda bh, i: (bh, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nb, stride, d), jnp.float32),
        compiler_params=pltpu_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="stem_antidiag_pool",
    )(xr)
    return out.reshape(b, h, nb, stride, d)


def _vmag_kernel(v_ref, o_ref, *, block_size: int):
    v = v_ref[0, ...].astype(jnp.float32)  # (block, d)
    norms = jnp.sqrt(jnp.maximum((v * v).sum(axis=-1), 1e-40))
    o_ref[0, 0, :] = jnp.max(jnp.log(norms))[None]


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def value_magnitude(
    v: jnp.ndarray, *, block_size: int = 128, interpret: bool = True
) -> jnp.ndarray:
    """(b, h, n, d) -> (b, h, n/block) block-max log ||V_j||_2."""
    b, h, n, d = v.shape
    nb = n // block_size
    vr = v.reshape(b * h, n, d)
    out = pl.pallas_call(
        functools.partial(_vmag_kernel, block_size=block_size),
        grid=(b * h, nb),
        in_specs=[pl.BlockSpec((1, block_size, d), lambda bh, i: (bh, i, 0))],
        out_specs=pl.BlockSpec((1, 1, 1), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nb, 1), jnp.float32),
        compiler_params=pltpu_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="stem_value_magnitude",
    )(vr)
    return out.reshape(b, h, nb)
