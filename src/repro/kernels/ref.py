"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical specification the kernels are tested
against (tests/test_kernels_*.py sweep shapes/dtypes and assert_allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, scale: float | None = None
) -> jnp.ndarray:
    """Dense causal attention oracle (GQA-aware). q: (b,hq,n,d), k/v: (b,hk,n,d)."""
    b, hq, n, d = q.shape
    hk = k.shape[1]
    group = hq // hk
    scale = (d ** -0.5) if scale is None else scale
    qg = q.reshape(b, hk, group, n, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    qi = jnp.arange(n)[:, None]
    kj = jnp.arange(n)[None, :]
    s = jnp.where(kj <= qi, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, n, d).astype(q.dtype)


def block_sparse_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    indices: jnp.ndarray,
    slot_mask: jnp.ndarray,
    *,
    block_size: int,
    scale: float | None = None,
) -> jnp.ndarray:
    """Oracle for the Stem block-sparse kernel.

    q: (b,hq,n,d); k,v: (b,hk,n,d); indices/slot_mask: (b,hq,nq,k_max).
    Builds the dense token mask implied by the selection and runs masked
    softmax attention.
    """
    b, hq, n, d = q.shape
    hk = k.shape[1]
    group = hq // hk
    nq = n // block_size
    nk = k.shape[2] // block_size
    scale = (d ** -0.5) if scale is None else scale

    onehot = jax.nn.one_hot(indices, nk, dtype=jnp.bool_)
    block_mask = jnp.any(onehot & slot_mask[..., None], axis=-2)  # (b,hq,nq,nk)
    tok = jnp.repeat(jnp.repeat(block_mask, block_size, axis=-2), block_size, axis=-1)
    qi = jnp.arange(n)[:, None]
    kj = jnp.arange(k.shape[2])[None, :]
    tok = tok & (kj <= qi + (k.shape[2] - n))

    qg = q.reshape(b, hk, group, n, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    s = jnp.where(tok.reshape(b, hk, group, n, k.shape[2]), s, NEG_INF)
    row_live = s.max(axis=-1, keepdims=True) > NEG_INF / 2
    p = jax.nn.softmax(jnp.where(row_live, s, 0.0), axis=-1)
    p = jnp.where(row_live, p, 0.0)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, n, d).astype(q.dtype)


def antidiag_pool_ref(x: jnp.ndarray, block_size: int, stride: int) -> jnp.ndarray:
    """Oracle for the pooling kernel: (..., n, d) -> (..., nb, stride, d)."""
    *lead, n, d = x.shape
    nb = n // block_size
    xb = x.reshape(*lead, nb, block_size // stride, stride, d)
    return xb.astype(jnp.float32).mean(axis=-3)


def value_magnitude_ref(v: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Oracle for block max-pooled log ||V||_2: (..., n, d) -> (..., nb)."""
    *lead, n, d = v.shape
    nb = n // block_size
    norms = jnp.linalg.norm(v.astype(jnp.float32), axis=-1)
    return jnp.log(jnp.maximum(norms, 1e-20)).reshape(*lead, nb, block_size).max(axis=-1)
