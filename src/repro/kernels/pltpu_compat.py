"""Version shims for ``jax.experimental.pallas.tpu``.

The TPU compiler-params dataclass was renamed ``TPUCompilerParams`` ->
``CompilerParams`` across JAX releases; resolve whichever this JAX ships.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None)
if CompilerParams is None:  # pragma: no cover - depends on installed jax
    CompilerParams = pltpu.TPUCompilerParams
