"""Fused Pallas paged-attention for the serving hot path (decode + chunk).

The XLA paged executors (``runtime.paged.paged_sparse_decode`` /
``core.chunked.chunked_prefill_attention``) run score -> top-k -> gather ->
attend as separate ops, and two of those stages materialize per-step copies
that dominate the decode hot loop:

  * the summary gather ``pool.kg[:, page_table]`` — a full
    (b, hk, max_pages, stride, d) copy of every visible page's pooled keys,
    rebuilt every step just to feed one einsum;
  * the page gather ``pool.k[gp] / pool.v[gp]`` — a materialized
    (b, hk, g, k_max, bs, d) K/V copy before the attention einsum reads it
    exactly once.

This module replaces both with scalar-prefetch kernels (the PR 1
``block_sparse_attn.py`` machinery, generalized from a contiguous cache to
the page pool):

  * **scoring** — the page table rides as a scalar-prefetch operand and the
    kg BlockSpec ``index_map`` resolves ``(kv_head, page_table[b, p])``
    directly, so the DMA engine streams each page's summary tile from the
    *pool* into VMEM; routing scores are reduced in-kernel and only the tiny
    (b, hq, maxp) score matrix is ever materialized.
  * **attention** — selected pages are attended flash-style with an online
    softmax.  Scalar-prefetched revisit-filled global page ids drive the
    K/V ``index_map`` (dead slots re-point at the row's last live page ->
    zero new DMAs), logical ids rebuild token positions for length/causal
    masks, and per-row live counts bound the inner grid
    (``@pl.when(s < cnt)``) with the ragged finalize at ``cnt - 1``.

Selection itself (budgets + forced floors + top-k over the (b, h, maxp)
score matrix) stays in XLA via the *shared* ``policy.decode_select`` /
``select_chunk_blocks`` — it is O(heads * maxp) scalars, not memory-bound,
and reusing the policy code makes the fused path selection-identical to the
XLA oracle by construction (no duplicated tie-breaking to drift).

Numerics: both paths reduce in fp32; the flash-style online softmax equals
the XLA masked softmax to ~1e-6, pinned at 1e-4 by
``tests/test_paged_kernel.py``.  Zero-live rows (cache_lens == 0 trash
slots) emit exact zeros on both paths — the kernel's accumulator never runs
and finalize divides 0 by the 1e-20 floor; see
``core.decode.attend_selected`` for the contract.

Metric support: ``OutputAwareMetric`` / ``RoutingMetric`` (any pooling for
decode; "antidiag" and "mean" for chunks — the kernel computes the shared
``sum_u qp'[u] . kg[u]`` contraction after an XLA-side permutation of the
pooled queries) and ``StreamingMetric`` (content-free zeros, no kernel
needed).  Policies with custom metric classes fall back to the XLA oracle
wholesale, so registering ``executor="pallas"`` is always safe.

``interpret=True`` (the CI default on CPU) runs the identical kernel bodies
in Python; flip ``INTERPRET`` on real TPU hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import chunked as chunked_lib
from repro.core import metric as metric_lib
from repro.core import policy as policy_lib
from repro.core.selection import revisit_indices
from repro.kernels import pltpu_compat

NEG_INF = -1e30

# Flip to False on real TPU hardware (launch scripts do this via env).
INTERPRET = True

# Process-wide tally of silent XLA fallbacks, keyed by call site
# ("decode" / "chunk").  Fallbacks fire at TRACE time (once per engine
# signature, not per step); ``StemEngine`` snapshots this at init and
# surfaces the delta as ``stats["pallas_fallbacks"]``, and the first hit
# per site warns so an operator asking for "pallas" learns they are
# running the oracle.
FALLBACKS: dict = {}
_WARNED: set = set()


def _note_fallback(site: str, reason: str) -> None:
    FALLBACKS[site] = FALLBACKS.get(site, 0) + 1
    if site not in _WARNED:
        _WARNED.add(site)
        import warnings
        warnings.warn(
            f"fused_paged_{site}: falling back to the XLA gather oracle "
            f"({reason}); counted in engine.stats['pallas_fallbacks']",
            RuntimeWarning, stacklevel=3)


def _resolve_interpret(interpret):
    return INTERPRET if interpret is None else interpret


def _metric_kind(metric) -> str | None:
    """"zero" (content-free), "routing" (kernel-scorable), or None (fall
    back to the XLA oracle for the whole call)."""
    if isinstance(metric, policy_lib.StreamingMetric):
        return "zero"
    if isinstance(metric, (policy_lib.OutputAwareMetric,
                           policy_lib.RoutingMetric)):
        return "routing"
    return None


# ---------------------------------------------------------------------------
# Shared scalar-prefetch packing
# ---------------------------------------------------------------------------

def pack_selection(indices, live, page_table):
    """Selection -> the kernel's scalar-prefetch triple.

    indices/live: (b, heads..., k_max) logical page-table slots + validity
    (live slots form a prefix — the selector contract); page_table:
    (b, max_pages) global page ids.

    Returns (gp, idx, cnt) int32: revisit-filled *global* page ids (drive
    the K/V DMAs; dead slots repeat the last live page so consecutive dead
    grid steps fetch nothing new), revisit-filled *logical* ids (rebuild
    token positions for masking), and per-row live counts.
    """
    b = page_table.shape[0]
    maxp = page_table.shape[1]
    lead = indices.shape[:-1]
    pt = jnp.broadcast_to(
        page_table.reshape((b,) + (1,) * (len(lead) - 1) + (maxp,)),
        lead + (maxp,))
    gp = jnp.take_along_axis(pt, indices, axis=-1)
    return (revisit_indices(gp, live).astype(jnp.int32),
            revisit_indices(indices, live).astype(jnp.int32),
            live.sum(axis=-1, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# Summary-resident page scoring (decode: one query row per slot)
# ---------------------------------------------------------------------------

def _score_kernel(pt_ref, q_ref, kg_ref, o_ref, *, scale):
    """Routing score of one (row, page) pair straight off the pool summary.

    q tile (1, nc, s, d) holds the row's pooled queries (nc = 1 for decode),
    kg tile (1, 1, s, d) is DMA'd from ``pool.kg[kv_head, page_table[b, p]]``
    by the index map.  The (1, nc, maxp) output block is revisited across
    the page axis; each step fills its own column.
    """
    p = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)           # (nc, s, d)
    kg = kg_ref[0, 0].astype(jnp.float32)      # (s, d)
    o_ref[0, :, p] = jnp.sum(q * kg[None], axis=(1, 2)) * scale


def _score_pages(qp, kg_pool, page_table, *, group, scale, interpret,
                 name):
    """qp: (b, hq, nc, s, d) pooled/permuted queries; kg_pool:
    (hk, P, s, d) pool summaries.  Returns (b, hq, nc, maxp) fp32 routing
    scores computed without materializing ``pool.kg[:, page_table]``."""
    b, hq, nc, s, d = qp.shape
    maxp = page_table.shape[1]
    qr = qp.reshape(b * hq, nc, s, d)

    def q_map(bh, p, pt_ref):
        return (bh, 0, 0, 0)

    def kg_map(bh, p, pt_ref):
        bi = bh // hq
        hi = bh % hq
        return (hi // group, pt_ref[bi, p], 0, 0)

    def o_map(bh, p, pt_ref):
        return (bh, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hq, maxp),
        in_specs=[
            pl.BlockSpec((1, nc, s, d), q_map),
            pl.BlockSpec((1, 1, s, d), kg_map),
        ],
        out_specs=pl.BlockSpec((1, nc, maxp), o_map),
    )
    out = pl.pallas_call(
        functools.partial(_score_kernel, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hq, nc, maxp), jnp.float32),
        compiler_params=pltpu_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name=name,
    )(page_table.astype(jnp.int32), qr, kg_pool)
    return out.reshape(b, hq, nc, maxp)


def decode_page_scores(q, kg_pool, page_table, *, group,
                       interpret=None):
    """Kernel-backed ``metric_lib.decode_routing_scores`` against the pool.

    q: (b, hq, 1, d); kg_pool: (hk, P, stride, d).  Returns (b, hk, g, maxp)
    fp32 — bit-compatible (up to fp32 reduction order) with
    ``decode_routing_scores(q, swapaxes(pool.kg[:, page_table], 0, 1))``.
    """
    b, hq, _, d = q.shape
    s = kg_pool.shape[-2]
    scale = 1.0 / (s * float(d) ** 0.5)
    # One "pooled" query group per row: nc = 1, the s axis broadcasts the
    # single query against every summary group (the decode routing score
    # sums over all s groups).
    qp = jnp.broadcast_to(q[:, :, :, None, :], (b, hq, 1, s, d))
    out = _score_pages(qp, kg_pool, page_table, group=group, scale=scale,
                       interpret=_resolve_interpret(interpret),
                       name="stem_paged_decode_score")
    return out.reshape(b, hq // group, group, page_table.shape[1])


def chunk_page_scores(q, kg_pool, page_table, *, block_size, pooling,
                      group, interpret=None):
    """Kernel-backed ``metric_lib.chunk_routing_scores`` against the pool.

    The anti-diagonal pairing ``pair(u) = (s - u) % s`` is an involution, so
    permuting the *pooled queries* by it in XLA (tiny: nc * s * d per row)
    turns the paired contraction into the plain ``sum_u qp'[u] . kg[u]`` the
    shared scoring kernel computes against unpermuted in-pool summaries.
    Mean pooling reduces to the same form with the query group axis averaged
    and broadcast.  q: (b, hq, C, d) -> (b, hq, nc, maxp) fp32.
    """
    b, hq, c, d = q.shape
    s = kg_pool.shape[-2]
    qp = metric_lib.antidiag_pool(q, block_size, s)       # (b, hq, nc, s, d)
    if pooling == "antidiag":
        pair = (s - jnp.arange(s)) % s
        qp = jnp.take(qp, pair, axis=-2)
        scale = 1.0 / (s * float(d) ** 0.5)
    else:  # mean: block mean = mean of the equal-sized group means
        qp = jnp.broadcast_to(qp.mean(axis=-2, keepdims=True), qp.shape)
        scale = 1.0 / (s * float(d) ** 0.5)
    return _score_pages(qp, kg_pool, page_table, group=group, scale=scale,
                        interpret=_resolve_interpret(interpret),
                        name="stem_paged_chunk_score")


# ---------------------------------------------------------------------------
# Fused attention over selected pages (online softmax, ragged live counts)
# ---------------------------------------------------------------------------

def _attend_kernel(
    gp_ref, idx_ref, cnt_ref, pos_ref,   # scalar prefetch (SMEM)
    q_ref, k_ref, v_ref,                 # VMEM tiles
    o_ref,
    acc_ref, m_ref, l_ref,               # VMEM scratch
    *,
    scale: float,
    block_k: int,
    rows: int,
    heads: int,
    causal: bool,
):
    """Flash-style attention over one row's selected pages.

    Grid (b * hq, nc, k_max).  ``pos_ref`` is the per-slot length vector:
    for decode (causal=False, rows=1) it holds ``cache_lens`` and masks
    ``tok_pos < len``; for chunks (causal=True, rows=block) it holds
    ``chunk_start`` and masks ``tok_pos <= q_pos`` at absolute positions.
    Rows with cnt == 0 never run ``_compute``; finalize then divides the
    zero accumulator by the 1e-20 floor — the exact-zero-output contract of
    ``core.decode.attend_selected``.
    """
    bh = pl.program_id(0)
    i = pl.program_id(1)
    s = pl.program_id(2)
    bi = bh // heads
    hi = bh % heads

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cnt = cnt_ref[bi, hi, i]

    @pl.when(s < cnt)
    def _compute():
        j = idx_ref[bi, hi, i, s]
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (rows, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        sc = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                  # (rows, bk)
        tok = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_k), 1)
        if causal:
            q_pos = pos_ref[bi] + i * rows + jax.lax.broadcasted_iota(
                jnp.int32, (rows, block_k), 0)
            keep = tok <= q_pos
        else:
            keep = tok < pos_ref[bi]
        sc = jnp.where(keep, sc, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, sc.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[:, None])
        p = jnp.where(keep, p, 0.0)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(s == jnp.maximum(cnt - 1, 0))
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _attend_pages(q, k_pool, v_pool, gp, idx, cnt, pos, *, block_size,
                  causal, interpret, name):
    """q: (b, hq, nc, rows, d); k/v_pool: (hk, P, bs, d); gp/idx:
    (b, hq, nc, k_max) int32; cnt: (b, hq, nc) int32; pos: (b,) int32.
    Returns (b, hq, nc, rows, dv)."""
    b, hq, nc, rows, d = q.shape
    hk = k_pool.shape[0]
    group = hq // hk
    dv = v_pool.shape[-1]
    k_max = gp.shape[-1]
    scale = float(d) ** -0.5
    qr = q.reshape(b * hq, nc, rows, d)

    def q_map(bh, i, s, gp_ref, idx_ref, cnt_ref, pos_ref):
        return (bh, i, 0, 0)

    def kv_map(bh, i, s, gp_ref, idx_ref, cnt_ref, pos_ref):
        bi = bh // hq
        hi = bh % hq
        return (hi // group, gp_ref[bi, hi, i, s], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b * hq, nc, k_max),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d), q_map),
            pl.BlockSpec((1, 1, block_size, d), kv_map),
            pl.BlockSpec((1, 1, block_size, dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, dv), q_map),
        scratch_shapes=[
            pltpu.VMEM((rows, dv), jnp.float32),
            pltpu.VMEM((rows,), jnp.float32),
            pltpu.VMEM((rows,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _attend_kernel, scale=scale, block_k=block_size, rows=rows,
            heads=hq, causal=causal),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hq, nc, rows, dv), q.dtype),
        compiler_params=pltpu_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name=name,
    )(gp, idx, cnt, pos, qr, k_pool, v_pool)
    return out.reshape(b, hq, nc, rows, dv)


# ---------------------------------------------------------------------------
# Fused entry points (drop-in for the XLA paged executors)
# ---------------------------------------------------------------------------

def fused_paged_decode(q, pool, page_table, cache_lens, cfg,
                       budget_frac=None, *, interpret=None):
    """Kernel-backed ``runtime.paged.paged_sparse_decode``.

    Same signature and semantics; scoring and attention run as Pallas
    kernels against the pool, selection is the shared policy code.  Falls
    back to the XLA oracle for metric classes the scorer cannot serve.
    """
    from repro.core.decode import DEFAULT_BUDGET_FRAC, debug_assert_live_rows
    policy = policy_lib.as_policy(cfg)
    if budget_frac is None:
        budget_frac = DEFAULT_BUDGET_FRAC
    kind = _metric_kind(policy.metric)
    if kind is None:
        _note_fallback(
            "decode", f"unsupported metric {type(policy.metric).__name__}")
        from repro.runtime import paged as paged_lib
        return paged_lib.paged_sparse_decode(
            q, pool, page_table, cache_lens, policy, budget_frac,
            executor="xla")
    interpret = _resolve_interpret(interpret)

    b, hq, _, d = q.shape
    hk = pool.k.shape[0]
    group = hq // hk
    maxp = page_table.shape[1]
    lens = jnp.broadcast_to(jnp.asarray(cache_lens, jnp.int32), (b,))

    if kind == "zero":
        m = jnp.zeros((b, hk, group, maxp), jnp.float32)
    else:
        m = decode_page_scores(q, pool.kg, page_table, group=group,
                               interpret=interpret)
        beta = getattr(policy.metric, "beta", 0.0)
        if beta:
            vm_rows = jnp.swapaxes(pool.vm[:, page_table], 0, 1)
            m = m + beta * jnp.maximum(vm_rows, 0.0)[:, :, None, :]

    sel = policy.decode_select(m, lens, budget_frac=budget_frac)
    debug_assert_live_rows(sel, context="fused_paged_decode")
    gp, idx, cnt = pack_selection(sel.indices, sel.live, page_table)
    out = _attend_pages(
        q.reshape(b, hq, 1, 1, d),
        pool.k, pool.v,
        gp.reshape(b, hq, 1, -1), idx.reshape(b, hq, 1, -1),
        cnt.reshape(b, hq, 1), lens,
        block_size=policy.block_size, causal=False, interpret=interpret,
        name="stem_paged_decode_attend")
    return out.reshape(b, hq, 1, -1)


def fused_paged_chunk(q, pool, page_table, chunk_start, budgets, cfg,
                      k_max=0, *, interpret=None):
    """Kernel-backed ``core.chunked.chunked_prefill_attention``.

    Same signature and semantics (chunk pages already written to the pool);
    selection-identical to the XLA oracle via the shared
    ``select_chunk_blocks``.  Falls back to the oracle for metric classes or
    poolings the scorer cannot serve.
    """
    policy = policy_lib.as_policy(cfg)
    kind = _metric_kind(policy.metric)
    pooling = getattr(policy.metric, "pooling", "antidiag")
    if kind is None or (kind == "routing" and pooling not in ("antidiag",
                                                              "mean")):
        _note_fallback(
            "chunk",
            (f"unsupported metric {type(policy.metric).__name__}"
             if kind is None else f"unsupported pooling {pooling!r}"))
        return chunked_lib.chunked_prefill_attention(
            q, pool, page_table, chunk_start, budgets, policy, k_max,
            executor="xla")
    interpret = _resolve_interpret(interpret)

    b, hq, c, d = q.shape
    hk = pool.k.shape[0]
    group = hq // hk
    bs = policy.block_size
    nc = c // bs
    maxp = page_table.shape[1]
    start = jnp.asarray(chunk_start, jnp.int32)

    if kind == "zero":
        m = jnp.zeros((b, hq, nc, maxp), jnp.float32)
    else:
        m = chunk_page_scores(q, pool.kg, page_table, block_size=bs,
                              pooling=pooling, group=group,
                              interpret=interpret)
        beta = getattr(policy.metric, "beta", 0.0)
        if beta:
            vm_rows = jnp.swapaxes(pool.vm[:, page_table], 0, 1)
            mv = jnp.repeat(vm_rows, group, axis=1)        # (b, hq, maxp)
            m = m + beta * jnp.maximum(mv, 0.0)[..., None, :]
        m = metric_lib.group_reduce_metric(m, group, policy.group_reduce)

    rows = start[:, None] // bs + jnp.arange(nc)[None, :]
    sel = chunked_lib.select_chunk_blocks(m, rows, budgets, policy, k_max)
    gp, idx, cnt = pack_selection(sel.indices, sel.live, page_table)
    out = _attend_pages(
        q.reshape(b, hq, nc, bs, d),
        pool.k, pool.v,
        gp, idx, cnt, start,
        block_size=bs, causal=True, interpret=interpret,
        name="stem_paged_chunk_attend")
    return out.reshape(b, hq, c, -1)


# Both fused lanes read head counts from the pool shapes and reduce only
# within a head, so a shard-local KV-head slice is served unchanged.
policy_lib.register_paged_executor(
    "pallas", decode_fn=fused_paged_decode, chunk_fn=fused_paged_chunk,
    sharding="kv-head")
