"""Distributed training driver.

Composes the substrate: model registry + sharding rules + AdamW (fp32
master, bf16 grad compression) + seekable synthetic data + checkpoint
manager (atomic, keep-K, async) + straggler monitor + failure-injection
restart harness.

On a real fleet this is launched once per host with the same arguments;
jax.distributed.initialize() picks up the coordinator from the environment
(called only when JAX_COORDINATOR_ADDRESS is set, so single-host runs and
tests skip it).  Recommended production XLA flags (latency-hiding scheduler,
async collectives) are applied via ``--prod-flags``.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \\
      --steps 20 --batch 8 --seq 256 --checkpoint-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \\
      --steps 20 --restore --checkpoint-dir /tmp/ckpt   # resume
"""
from __future__ import annotations

import argparse
import os
import sys
import time

PROD_XLA_FLAGS = " ".join([
    # Overlap compute with collectives (latency-hiding scheduler).
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
    "--xla_enable_async_all_gather=true",
    "--xla_enable_async_collective_permute=true",
])


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale smoke training)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--stem", action="store_true",
                    help="train with Stem sparse attention in the forward")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a failure at this step (fault-tolerance demo)")
    ap.add_argument("--prod-flags", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    if args.prod_flags:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + PROD_XLA_FLAGS).strip()
    if os.environ.get("JAX_COORDINATOR_ADDRESS"):
        import jax
        jax.distributed.initialize()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import configs, optim
    from repro.checkpoint import CheckpointManager
    from repro.core.config import StemConfig
    from repro.data import SyntheticLMData, make_global_batch
    from repro.launch import mesh as mesh_lib
    from repro.launch import steps as steps_lib
    from repro.models import registry
    from repro.runtime import FailureInjector, StragglerMonitor
    from repro.sharding import rules as rules_lib

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg).replace(dtype="float32")
    bundle = registry.build(cfg)

    n_dev = len(jax.devices())
    mesh = mesh_lib.make_local_mesh() if n_dev < 256 else \
        mesh_lib.make_production_mesh(multi_pod=n_dev >= 512)

    stem_cfg = None
    if args.stem:
        stem_cfg = StemConfig(block_size=min(128, max(16, args.seq // 8)),
                              min_budget_blocks=2, sink_blocks=1, local_blocks=1,
                              stride=4)

    opt_cfg = optim.AdamWConfig(peak_lr=args.lr, warmup_steps=max(2, args.steps // 10),
                                decay_steps=max(args.steps, 10))
    abstract_values, axes_tree = bundle.abstract_params()
    param_sh = rules_lib.param_shardings(cfg, mesh, abstract_values, axes_tree)
    state_sh = steps_lib.opt_state_shardings(cfg, mesh, param_sh, abstract_values)

    train_step = steps_lib.make_train_step(
        bundle, opt_cfg, stem_cfg=stem_cfg, remat=True,
        microbatches=args.microbatches, grad_shardings=state_sh.master)

    data = SyntheticLMData(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
        kind={"vlm": "vlm", "encdec": "encdec"}.get(cfg.family, "lm"),
        d_model=cfg.d_model,
        frames=cfg.encdec.encoder_frames if cfg.encdec else 0)
    batch0 = data.batch_at(0)
    batch_sh = rules_lib.batch_sharding(
        cfg, mesh, {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch0.items()})

    mgr = CheckpointManager(args.checkpoint_dir) if args.checkpoint_dir else None
    start_step = 0
    with mesh:
        if args.restore and mgr and mgr.latest_step() is not None:
            abstract_state = steps_lib.abstract_opt_state(abstract_values, opt_cfg)
            state, meta = mgr.restore(abstract_state, shardings=state_sh)
            state = optim.OptState(*state)
            start_step = int(meta["step"])
            print(f"restored checkpoint at step {start_step}", flush=True)
        else:
            params = jax.jit(bundle.init_params, out_shardings=param_sh)(
                jax.random.PRNGKey(args.seed))
            state = jax.jit(lambda p: optim.init_state(p, opt_cfg), out_shardings=state_sh)(params)

        jit_step = jax.jit(train_step, in_shardings=(state_sh, batch_sh),
                           donate_argnums=(0,))

        injector = FailureInjector((args.fail_at,) if args.fail_at >= 0 else ())
        monitor = StragglerMonitor(on_straggler=lambda s, dt, ema: print(
            f"[straggler] step {s}: {dt:.3f}s vs ema {ema:.3f}s", flush=True))

        losses = []
        for step in range(start_step, args.steps):
            injector.maybe_fail(step)
            monitor.start()
            gbatch = make_global_batch(data.batch_at(step), mesh, batch_sh)
            state, metrics = jit_step(state, gbatch)
            loss = float(metrics["loss"])
            monitor.stop(step)
            losses.append(loss)
            if step % args.log_every == 0:
                print(f"step {step}: loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e}", flush=True)
            if mgr and (step + 1) % args.checkpoint_every == 0:
                mgr.save(step + 1, state, extra={"loss": loss}, blocking=False)
        if mgr:
            mgr.save(args.steps, state, extra={"final": True}, blocking=True)
    return {"final_loss": losses[-1] if losses else None, "losses": losses,
            "stragglers": monitor.flagged}


if __name__ == "__main__":
    main()
