"""Structural cost analysis of compiled (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts every ``while`` body
**once**, so any scan-over-layers model (all of ours) is undercounted by the
layer count — and collectives inside loop bodies (e.g. FSDP all-gathers per
layer) are likewise invisible to naive text grepping.  This module parses
the HLO module into computations, walks the ENTRY computation, recurses into
``while`` loops with their inferred trip counts, fusions, and calls, and
accumulates:

  * flops       — 2*M*N*K for dots (shapes + contracting dims from the
                  symbol table), 1/elt for elementwise fusions (dots
                  dominate every model here),
  * bytes       — operands + results at fusion boundaries (the HLO
                  "bytes accessed" convention),
  * collectives — per-op counts and ring-model bytes
                  (all-reduce 2x, all-gather/reduce-scatter/all-to-all/
                  collective-permute 1x), trip-count multiplied.

Trip-count inference: jax's scan lowers to a while whose condition compares
the counter against a ``constant(N)``; we take the max integer constant in
the condition computation, with a fallback to the leading dim of stacked
xs operands.  Validated against unrolled lowerings in tests/test_hlo_analysis.py.

This is also the dry-run "profiler" used by the §Perf iteration loop —
 per-op-class breakdowns show where flops/bytes/collective traffic live.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*(?:\(([^)]*)\))?.*\{\s*$")
_OPERAND_RE = re.compile(r"(%[\w\.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVE_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                   "all-to-all": 1.0, "collective-permute": 1.0}
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "copy", "after-all", "partition-id", "replica-id", "domain",
             "opt-barrier", "custom-call"}


def _type_info(type_str: str) -> tuple[int, int]:
    """(total elements, total bytes) across all shapes in a type string."""
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0        # every op's operands+results (no-fusion bound)
    bytes_min: float = 0.0    # fusion-ideal: dots/gathers/reduces/collectives/
                              # fusion boundaries only (TPU-like epilogue fusion)
    coll_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    flops_by_op: dict = dataclasses.field(default_factory=dict)
    bytes_by_opcode: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_min += other.bytes_min * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * mult
        for k, v in other.flops_by_op.items():
            self.flops_by_op[k] = self.flops_by_op.get(k, 0.0) + v * mult
        for k, v in other.bytes_by_opcode.items():
            self.bytes_by_opcode[k] = self.bytes_by_opcode.get(k, 0.0) + v * mult


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    result_type: str
    rest: str          # everything after the opcode's '(' on the def line


class _Computation:
    def __init__(self, name: str):
        self.name = name
        self.ops: list[_Op] = []
        self.types: dict[str, str] = {}    # symbol -> type string

    def constants(self) -> list[int]:
        out = []
        for op in self.ops:
            for m in _CONST_INT_RE.finditer(op.opcode + "(" + op.rest):
                out.append(int(m.group(1)))
        return out


def _parse_module(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("->" in line or line.startswith("ENTRY")):
            cur = _Computation(hdr.group(1))
            if line.startswith("ENTRY"):
                comps["__entry__"] = cur
            comps[hdr.group(1)] = cur
            # parameter types from the signature
            sig = hdr.group(2) or ""
            for pname, ptype in re.findall(r"([\w\.\-]+)\s*:\s*([^,()]+(?:\([^)]*\))?)", sig):
                cur.types["%" + pname] = ptype
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        d = _DEF_RE.match(line)
        if d:
            name, rtype, opcode, rest = d.groups()
            cur.ops.append(_Op(name, opcode, rtype, rest))
            cur.types[name] = rtype
            # parameters defined as ops: "%p = f32[..] parameter(0)"
    return comps


def _dot_flops(op: _Op, comp: _Computation) -> float:
    """2 * (result elements) * (contracted elements of lhs)."""
    res_elems, _ = _type_info(op.result_type)
    operands = _OPERAND_RE.findall(op.rest.split(")")[0])
    lhs_type = comp.types.get(operands[0], "") if operands else ""
    lhs_shapes = _SHAPE_RE.findall(lhs_type)
    if not lhs_shapes:
        return 2.0 * res_elems
    lhs_dims = [int(d) for d in lhs_shapes[0][1].split(",") if d]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contracted = 1
    if m and m.group(1):
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                contracted *= lhs_dims[idx]
    return 2.0 * res_elems * contracted


def _trip_count(cond: _Computation, body: _Computation,
                comp: _Computation, op: _Op) -> int:
    consts = cond.constants()
    if consts and max(consts) > 0:
        return max(consts)
    return 1


def _op_bytes(op: _Op, comp: _Computation) -> float:
    _, out_b = _type_info(op.result_type)
    in_b = 0
    arg_str = op.rest.split("), ")[0]
    for ref in _OPERAND_RE.findall(arg_str):
        t = comp.types.get(ref)
        if t:
            in_b += _type_info(t)[1]
    return float(out_b + in_b)


def _analyze_comp(comp: _Computation, comps: dict, memo: dict) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    total = Cost()
    memo[comp.name] = total   # cycles shouldn't occur; this guards anyway
    for op in comp.ops:
        oc = op.opcode
        base = oc.replace("-start", "").replace("-done", "")
        if oc.endswith("-done"):
            continue
        if base in COLLECTIVE_MULT:
            _, out_b = _type_info(op.result_type)
            moved = out_b * COLLECTIVE_MULT[base]
            total.coll_bytes += moved
            total.coll_by_op[base] = total.coll_by_op.get(base, 0.0) + moved
            total.coll_counts[base] = total.coll_counts.get(base, 0) + 1
            b = _op_bytes(op, comp)
            total.bytes += b
            total.bytes_min += b
            total.bytes_by_opcode[base] = total.bytes_by_opcode.get(base, 0.0) + b
            continue
        if oc == "while":
            body_name = re.search(r"body=(%[\w\.\-]+)", op.rest)
            cond_name = re.search(r"condition=(%[\w\.\-]+)", op.rest)
            if body_name and body_name.group(1) in comps:
                body = comps[body_name.group(1)]
                cond = comps[cond_name.group(1)] if cond_name and cond_name.group(1) in comps else _Computation("?")
                trips = _trip_count(cond, body, comp, op)
                sub = _analyze_comp(body, comps, memo)
                total.add(sub, mult=trips)
            continue
        if oc in ("fusion", "call", "conditional", "async-start"):
            sub_names = re.findall(r"(?:calls|to_apply|branch_computations)=\{?(%[\w\.\-]+)", op.rest)
            for sn in sub_names:
                if sn in comps:
                    sub = _analyze_comp(comps[sn], comps, memo)
                    # fusions are memory boundaries: take inner flops +
                    # inner collectives, but bytes only at the boundary.
                    total.flops += sub.flops
                    total.coll_bytes += sub.coll_bytes
                    for k, v in sub.coll_by_op.items():
                        total.coll_by_op[k] = total.coll_by_op.get(k, 0.0) + v
                    for k, v in sub.coll_counts.items():
                        total.coll_counts[k] = total.coll_counts.get(k, 0.0) + v
                    for k, v in sub.flops_by_op.items():
                        total.flops_by_op[k] = total.flops_by_op.get(k, 0.0) + v
                    total.bytes_min += sub.bytes_min
                    for k, v in sub.bytes_by_opcode.items():
                        total.bytes_by_opcode[k] = total.bytes_by_opcode.get(k, 0.0) + v
            b = _op_bytes(op, comp)
            total.bytes += b
            total.bytes_min += b
            total.bytes_by_opcode["fusion"] = total.bytes_by_opcode.get("fusion", 0.0) + b
            continue
        if oc in ("dot", "dot-general"):
            f = _dot_flops(op, comp)
            total.flops += f
            total.flops_by_op["dot"] = total.flops_by_op.get("dot", 0.0) + f
            b = _op_bytes(op, comp)
            total.bytes += b
            total.bytes_min += b
            total.bytes_by_opcode["dot"] = total.bytes_by_opcode.get("dot", 0.0) + b
            continue
        if oc in _SKIP_OPS:
            continue
        # generic elementwise / reduce / dynamic-slice etc.
        elems, out_b = _type_info(op.result_type)
        total.flops += elems
        total.flops_by_op["elementwise"] = total.flops_by_op.get("elementwise", 0.0) + elems
        if oc == "dynamic-slice":
            # reads only the slice: 2x the (slice-sized) result, not the
            # full buffer operand (XLA slices in place inside loops).
            b = 2.0 * out_b
        elif oc == "dynamic-update-slice":
            # in-place inside loops: traffic ~ 2x the update operand.
            ops_ = _OPERAND_RE.findall(op.rest.split("), ")[0])
            upd_t = comp.types.get(ops_[1]) if len(ops_) > 1 else None
            b = 2.0 * (_type_info(upd_t)[1] if upd_t else out_b)
        else:
            b = _op_bytes(op, comp)
        total.bytes += b
        if oc in ("reduce", "gather", "scatter", "dynamic-slice",
                  "dynamic-update-slice", "sort", "reduce-window", "transpose",
                  "convolution", "cholesky", "triangular-solve"):
            total.bytes_min += b
            total.bytes_by_opcode[oc] = total.bytes_by_opcode.get(oc, 0.0) + b
    memo[comp.name] = total
    return total


def analyze_hlo(text: str) -> Cost:
    comps = _parse_module(text)
    entry = comps.get("__entry__")
    if entry is None:
        return Cost()
    # fresh memo per module; computations reached only via entry
    return _analyze_comp(entry, comps, memo={})
