"""jit-able step functions (train / prefill / serve) + their shardings.

The same builders serve the real drivers (train.py, serve.py) and the
multi-pod dry-run (dryrun.py lowers them from ShapeDtypeStructs).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs.base import ArchConfig, RunShape
from repro.core.config import StemConfig
from repro.models import attention, mla, registry, transformer
from repro.sharding import rules as rules_lib

PAPER_STEM = StemConfig()   # paper defaults: B=128, mu=0.7, beta=0.2, floor 54

# Every ``stem_cfg`` argument in this module accepts a SparsityPolicy, a
# registered policy name, or a legacy StemConfig (core/policy.py
# ``as_policy``); ``policies`` is the per-layer override map forwarded to
# the transformer ({global layer index: policy}).


def default_stem_cfg(cfg: ArchConfig) -> Optional[StemConfig]:
    return PAPER_STEM if cfg.use_stem else None


def make_train_step(bundle: registry.ModelBundle, opt_cfg: optim.AdamWConfig,
                    *, stem_cfg=None, policies=None,
                    remat: bool = True, microbatches: int = 1,
                    grad_shardings=None):
    """(opt_state, batch) -> (opt_state, metrics).

    Forward in the arch dtype from the fp32 master, optional gradient
    accumulation over ``microbatches``, bf16 gradient compression before the
    data-parallel all-reduce, AdamW on the master.  ``grad_shardings``
    (usually the ZeRO-1 master shardings) pins gradients to the optimizer
    shard so the DP reduction lowers to a reduce-scatter instead of a full
    all-reduce + replicated accumulator.
    """
    cfg = bundle.cfg

    def pin(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads, grad_shardings)

    def loss_of(master, mb):
        params = jax.tree.map(lambda m: m.astype(cfg.jnp_dtype), master)
        kw = {"policies": policies} if policies else {}
        loss, metrics = bundle.loss_fn(params, mb, stem_cfg=stem_cfg,
                                       remat=remat, **kw)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(opt_state: optim.OptState, batch: dict):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(opt_state.master, batch)
            grads = pin(grads)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(opt_state.master, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, pin(g))
                return (pin(g_acc), l_acc + l), m

            g0 = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  opt_state.master))
            (grads, loss), ms = jax.lax.scan(acc_body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = jax.tree.map(lambda m: m[-1], ms)
        grads = optim.adamw.compress_grads(grads, opt_cfg)
        new_state, opt_metrics = optim.update(grads, opt_state, opt_cfg)
        return new_state, {**metrics, **opt_metrics, "loss": loss}

    return train_step


def make_prefill_step(bundle: registry.ModelBundle, *, max_len: int,
                      stem_cfg=None, policies=None):
    def prefill_step(params, batch):
        kw = {"policies": policies} if policies else {}
        return bundle.prefill(params, batch, max_len=max_len,
                              stem_cfg=stem_cfg, **kw)
    return prefill_step


def set_cache_positions(caches, cache_lens: jnp.ndarray):
    """Pin every attention/MLA cache's write position to per-sequence
    lengths (``(b,)`` int32).  Cache leaves are stacked ``(n_layers, ...)``,
    so the position leaf becomes ``(n_layers, b)`` and the layer scan hands
    each layer its ``(b,)`` row.  Recurrent/SSM states are position-free and
    pass through untouched."""
    lens = jnp.asarray(cache_lens, jnp.int32)

    def fix(c):
        if isinstance(c, attention.KVCache):
            return c._replace(pos=jnp.broadcast_to(lens, (c.k.shape[0],) + lens.shape))
        if isinstance(c, mla.MLACache):
            return c._replace(pos=jnp.broadcast_to(lens, (c.c_kv.shape[0],) + lens.shape))
        return c

    return jax.tree.map(
        fix, caches,
        is_leaf=lambda x: isinstance(x, (attention.KVCache, mla.MLACache)))


def make_serve_step(bundle: registry.ModelBundle, *, stem_cfg=None,
                    budget_frac: float = 1.0):
    """(params, tokens, caches[, cache_lens]) -> (logits, caches).

    ``cache_lens`` (``(b,)`` int32) overrides the caches' write positions
    per sequence — the ragged fixed-batch path: each row decodes against
    its own prompt length instead of one shared scalar.  Positions advance
    inside the caches afterwards, so pass it only on the first step.

    With ``stem_cfg`` the decode is policy-sparse over the contiguous cache
    (``attention.apply_decode`` summarizes + selects per step) — the
    fixed-batch reference arm for the paged engine's sparse decode."""
    def serve_step(params, tokens, caches, cache_lens=None):
        if cache_lens is not None:
            caches = set_cache_positions(caches, cache_lens)
        if stem_cfg is None:
            return bundle.decode_step(params, tokens, caches)
        return bundle.decode_step(params, tokens, caches,
                                  stem_cfg=stem_cfg, budget_frac=budget_frac)
    return serve_step


# ---------------------------------------------------------------------------
# Paged-engine steps (runtime/engine.py)
# ---------------------------------------------------------------------------

def make_unified_step(bundle: registry.ModelBundle, *, stem_cfg,
                      budget_frac: float = 1.0, chunk_k_max: int = 0,
                      executor=None, on_trace=None, smesh=None,
                      sampler=None):
    """The engine's single step: (params, pools, tokens (S,1),
    page_table (S,P), cache_lens (S,), chunk) ->
    (decode logits (S, vocab), chunk logits (S, vocab) | None, pools).

    One mixed batch of decode tokens + prefill chunks per call
    (``transformer.paged_mixed_step``).  With fixed S/P/C the chunked
    engine compiles this **exactly once** for arbitrary prompt lengths —
    the per-length retraces of the old monolithic ``insert_prefill`` are
    gone.  ``chunk=None`` is the decode-only view (one extra trace),
    used by the legacy monolithic arm.  ``executor`` picks the paged
    attention backend ("xla" gather oracle / fused "pallas" kernels; None
    defers to the policy).  ``on_trace`` fires as a Python
    side effect at trace time — the engine's retrace counter.

    With ``smesh`` (a ``sharding.serving.ServingMesh``) every batch
    argument gains a leading slot-group axis — tokens (dp, S, 1),
    page_table (dp, S, P), cache_lens (dp, S), chunk leaves (dp, ...) —
    and the step runs under ``shard_map``: each dp shard vmaps the
    single-device mixed step over its local slot group against its pool
    slice, and each tp shard computes its KV-head block with one
    all-gather at the attention output (``sharding/serving.py``).  Still
    exactly two traces, and bitwise identical per group to the
    single-device step.

    With ``sampler`` (``runtime/sampling.py``) the builder returns the
    SAMPLED signature instead — the async engine's step: (params, pools,
    token_buf (S,), dec_mask (S,), page_table, cache_lens, chunk) ->
    (dec_ids (S,), chunk_ids (L,) | None, token_buf', pools).  Sampling
    runs inside the trace (``transformer.paged_sampled_step``), decode
    inputs come from the device-resident ``token_buf``, and the only
    per-step transfer left is the int32 id arrays.  Under the mesh,
    ``token_buf`` / ``dec_mask`` gain the (dp,) slot-group axis like
    every other batch argument, and the tiny replicated-over-tp id
    arrays replace the per-group logits fetch — the sampled mesh step
    moves O(slots) bytes to the host instead of O(slots * vocab)."""
    cfg = bundle.cfg
    transformer.assert_paged_servable(cfg)

    def mixed_step(params, tokens, pools, page_table, cache_lens, chunk):
        return transformer.paged_mixed_step(
            params, tokens, pools, page_table, cache_lens, cfg,
            stem_cfg=stem_cfg, budget_frac=budget_frac, chunk=chunk,
            chunk_k_max=chunk_k_max, executor=executor)

    def sampled_step(params, buf, mask, pools, page_table, cache_lens,
                     chunk):
        return transformer.paged_sampled_step(
            params, buf, pools, page_table, cache_lens, mask, cfg,
            stem_cfg=stem_cfg, sampler=sampler, budget_frac=budget_frac,
            chunk=chunk, chunk_k_max=chunk_k_max, executor=executor)

    if smesh is None:
        if sampler is None:
            def unified_step(params, pools, tokens, page_table, cache_lens,
                             chunk=None):
                if on_trace is not None:
                    on_trace()
                return mixed_step(params, tokens, pools, page_table,
                                  cache_lens, chunk)
            return unified_step

        def unified_sampled(params, pools, token_buf, dec_mask, page_table,
                            cache_lens, chunk=None):
            if on_trace is not None:
                on_trace()
            return sampled_step(params, token_buf, dec_mask, pools,
                                page_table, cache_lens, chunk)
        return unified_sampled

    from jax.experimental.shard_map import shard_map

    from repro.sharding import serving as serving_lib

    POOL = serving_lib.POOL_SPEC
    GRP = serving_lib.GROUP_SPEC
    REP = serving_lib.REPLICATED

    # Two shard-mapped bodies (mixed / decode-only) mirror the two engine
    # traces — chunk=None is a pytree structure change, not a spec change.
    def _mixed_body(params, pools, tokens, page_table, cache_lens, chunk):
        def one(pools_g, tokens_g, table_g, lens_g, chunk_g):
            return mixed_step(params, tokens_g, pools_g, table_g, lens_g,
                              chunk_g)
        return jax.vmap(one)(pools, tokens, page_table, cache_lens, chunk)

    def _decode_body(params, pools, tokens, page_table, cache_lens):
        def one(pools_g, tokens_g, table_g, lens_g):
            dec, _, new_pools = mixed_step(params, tokens_g, pools_g,
                                           table_g, lens_g, None)
            return dec, new_pools
        return jax.vmap(one)(pools, tokens, page_table, cache_lens)

    # Sampled twins: same lane structure, id outputs + the fed-back token
    # buffer instead of logits.  The ids are sampled from tp-replicated
    # logits, so they are bitwise replicated over tp by construction.
    def _mixed_sampled_body(params, pools, buf, mask, page_table,
                            cache_lens, chunk):
        def one(pools_g, buf_g, mask_g, table_g, lens_g, chunk_g):
            return sampled_step(params, buf_g, mask_g, pools_g, table_g,
                                lens_g, chunk_g)
        return jax.vmap(one)(pools, buf, mask, page_table, cache_lens, chunk)

    def _decode_sampled_body(params, pools, buf, mask, page_table,
                             cache_lens):
        def one(pools_g, buf_g, mask_g, table_g, lens_g):
            ids, _, new_buf, new_pools = sampled_step(
                params, buf_g, mask_g, pools_g, table_g, lens_g, None)
            return ids, new_buf, new_pools
        return jax.vmap(one)(pools, buf, mask, page_table, cache_lens)

    # check_rep=False: outputs are bitwise replicated over tp by
    # construction (full projections + all-gather before wo), which the
    # replication checker cannot prove through the collectives.
    if sampler is None:
        smapped_mixed = shard_map(
            _mixed_body, mesh=smesh.mesh,
            in_specs=(REP, POOL, GRP, GRP, GRP, GRP),
            out_specs=(GRP, GRP, POOL), check_rep=False)
        smapped_decode = shard_map(
            _decode_body, mesh=smesh.mesh,
            in_specs=(REP, POOL, GRP, GRP, GRP),
            out_specs=(GRP, POOL), check_rep=False)

        def unified_step(params, pools, tokens, page_table, cache_lens,
                         chunk=None):
            if on_trace is not None:
                on_trace()
            # The head-sharding context is active while jit traces the
            # shard_map bodies, turning on the TP slicing inside
            # models/attention.py for exactly this trace.
            with serving_lib.head_sharding(smesh.tp):
                if chunk is None:
                    dec, new_pools = smapped_decode(params, pools, tokens,
                                                    page_table, cache_lens)
                    return dec, None, new_pools
                return smapped_mixed(params, pools, tokens, page_table,
                                     cache_lens, chunk)
        return unified_step

    smapped_mixed_s = shard_map(
        _mixed_sampled_body, mesh=smesh.mesh,
        in_specs=(REP, POOL, GRP, GRP, GRP, GRP, GRP),
        out_specs=(GRP, GRP, GRP, POOL), check_rep=False)
    smapped_decode_s = shard_map(
        _decode_sampled_body, mesh=smesh.mesh,
        in_specs=(REP, POOL, GRP, GRP, GRP, GRP),
        out_specs=(GRP, GRP, POOL), check_rep=False)

    def unified_sampled(params, pools, token_buf, dec_mask, page_table,
                        cache_lens, chunk=None):
        if on_trace is not None:
            on_trace()
        with serving_lib.head_sharding(smesh.tp):
            if chunk is None:
                ids, buf, new_pools = smapped_decode_s(
                    params, pools, token_buf, dec_mask, page_table,
                    cache_lens)
                return ids, None, buf, new_pools
            return smapped_mixed_s(params, pools, token_buf, dec_mask,
                                   page_table, cache_lens, chunk)
    return unified_sampled


def make_page_extract():
    """(pools, page_row) -> snapshot: gather one slot's pages (K/V + kg/vm
    summaries) out of every layer's pool for host offload.  ``page_row`` is
    the fixed-width ``(max_pages_per_slot,)`` trash-padded page-id row, so
    the engine jits this exactly once — preemption adds zero traces."""
    from repro.runtime import offload as offload_lib

    def extract_pages(pools, page_row):
        return offload_lib.gather_pages(pools, page_row)
    return extract_pages


def make_page_restore():
    """(pools, page_row, snapshot) -> pools: scatter an offloaded snapshot
    back into freshly allocated pages.  Bit-identical inverse of
    ``make_page_extract`` modulo page renaming (the page-table row carries
    the new mapping); jitted once, donates the pools."""
    from repro.runtime import offload as offload_lib

    def restore_pages(pools, page_row, snapshot):
        return offload_lib.scatter_pages(pools, page_row, snapshot)
    return restore_pages


def make_page_copy():
    """(pools, src, dst) -> pools: duplicate one page (K/V + kg/vm) across
    every layer's pool — the device half of copy-on-write.  ``src``/``dst``
    are traced scalar page ids, so the engine jits this exactly once."""
    from repro.runtime import paged as paged_lib

    def page_copy(pools, src, dst):
        return paged_lib.copy_pages_stacked(pools, src, dst)
    return page_copy


def make_monolithic_prefill(bundle: registry.ModelBundle, *, stem_cfg,
                            on_trace=None, sampler=None):
    """(params, tokens (1, Lp), true_len, pools, page_row) ->
    (next-token logits (vocab,), pools) — or, with ``sampler``,
    (sampled first token id (scalar int32), pools).

    The legacy one-shot admission prefill: one request, right-padded to a
    page multiple, scattered into the pools with its block summaries
    (``transformer.prefill_kv_pages``).  jit retraces one instance per
    padded-length bucket — kept as the A/B baseline for the unified
    chunked step (``benchmarks/serving.py --chunked``) and as the
    fallback for threshold selectors that chunked prefill cannot serve.
    With ``sampler`` the first token is sampled on-device too, so the
    admission fetch is one int32 instead of a vocab-sized logits row."""
    cfg = bundle.cfg
    transformer.assert_paged_servable(cfg)

    def monolithic_prefill(params, tokens, true_len, pools, page_row):
        if on_trace is not None:
            on_trace()
        logits, new_pools = transformer.prefill_kv_pages(
            params, tokens, true_len, pools, page_row, cfg, stem_cfg)
        if sampler is not None:
            return sampler(logits), new_pools
        return logits, new_pools
    return monolithic_prefill


# ---------------------------------------------------------------------------
# Shardings for the step arguments
# ---------------------------------------------------------------------------

def opt_state_shardings(cfg: ArchConfig, mesh, param_sh, abstract_values=None):
    """OptState sharded like the parameters, plus ZeRO-1 sharding over the
    `pod` axis when one exists (abstract_values supplies shapes)."""
    rep = NamedSharding(mesh, P())
    opt_sh = param_sh
    if abstract_values is not None:
        opt_sh = rules_lib.zero1_shardings(cfg, mesh, abstract_values, param_sh)
    return optim.OptState(step=rep, master=opt_sh, mu=opt_sh, nu=opt_sh)


def train_arg_shardings(cfg: ArchConfig, mesh, abstract_values, axes_tree,
                        batch_specs):
    param_sh = rules_lib.param_shardings(cfg, mesh, abstract_values, axes_tree)
    state_sh = opt_state_shardings(cfg, mesh, param_sh)
    batch_sh = rules_lib.batch_sharding(cfg, mesh, batch_specs)
    return state_sh, batch_sh


def abstract_opt_state(abstract_values, opt_cfg: Optional[optim.AdamWConfig] = None):
    mdt = jnp.bfloat16 if (opt_cfg and opt_cfg.moment_dtype == "bfloat16") else jnp.float32
    f32 = lambda v: jax.ShapeDtypeStruct(v.shape, jnp.float32)
    mom = lambda v: jax.ShapeDtypeStruct(v.shape, mdt)
    return optim.OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        master=jax.tree.map(f32, abstract_values),
        mu=jax.tree.map(mom, abstract_values),
        nu=jax.tree.map(mom, abstract_values),
    )
