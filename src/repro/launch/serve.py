"""Serving driver: continuous batching over the paged Stem KV cache.

Models the paper's deployment story end-to-end: Stem-accelerated prefill
writes each request's K/V pages + block summaries into the shared page
pool, and decode streams tokens with OAM page selection per step.  Requests
carry *mixed prompt lengths* and *staggered arrivals*; the engine
(``runtime/engine.py``) admits them into slots as capacity frees up and
recycles slots on completion — no uniform-batch assumption anywhere.

Three modes:
  * default — the continuous-batching engine with **chunked prefill**: one
    fixed-shape unified step mixes prefill chunks (``--chunk-size``) and
    decode tokens per iteration under a ``--step-token-budget``, so long
    prompts never stall in-flight decodes and the engine compiles once;
  * ``--monolithic`` — the legacy one-shot admission prefill (per-length
    traces, head-of-line blocking) kept as the A/B baseline;
  * ``--fixed-batch`` — the legacy one-shot batch, but ragged: per-request
    prompt lengths are right-padded, per-sequence ``cache_lens`` flow
    through ``make_serve_step``, and every row decodes at its own length.

The sparsity policy is declarative: ``--policy <name>`` resolves any
registered ``SparsityPolicy`` (``stem``, ``streaming``, ``uniform-sam``,
``xattention``, …; see ``core/policy.py``) and rescales it to the serving
geometry; ``--stem`` keeps the legacy flag-built stem policy.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \\
      --requests 6 --min-prompt 48 --max-prompt 200 --decode-tokens 16 \\
      --max-slots 4 --stem
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \\
      --policy streaming --requests 6 --decode-tokens 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def build_trace(rng: np.random.RandomState, n_requests: int, min_prompt: int,
                max_prompt: int, decode_tokens: int, vocab: int,
                arrival_every: int, hp_every: int = 0,
                hp_ttft_slo_s: float = None, hp_tpot_slo_s: float = None):
    """Mixed-length, staggered-arrival request trace.  With ``hp_every``,
    every hp_every-th request is priority 1 and carries the given SLOs —
    the interactive class of the overload study."""
    from repro.runtime.engine import Request
    reqs = []
    for i in range(n_requests):
        plen = int(rng.randint(min_prompt, max_prompt + 1))
        hp = bool(hp_every) and (i % hp_every == hp_every - 1)
        reqs.append(Request(
            uid=i,
            prompt=rng.randint(0, vocab, size=(plen,)).astype(np.int32),
            max_new_tokens=decode_tokens,
            arrival_step=i * arrival_every,
            priority=1 if hp else 0,
            ttft_slo_s=hp_ttft_slo_s if hp else None,
            tpot_slo_s=hp_tpot_slo_s if hp else None,
        ))
    return reqs


def _latency_stats(finished):
    """Serving-latency summary: inter-token decode gaps (p50/p95/p99 —
    these surface head-of-line stalls and swapped-out time), TTFT, and
    TPOT, reported separately.  NaN entries (shed/aborted requests never
    emitted a token; single-token requests have no TPOT) are excluded."""
    lats = np.asarray([t for f in finished for t in f.token_latencies_s])
    ttfts = np.asarray([f.ttft_s for f in finished], np.float64)
    ttfts = ttfts[~np.isnan(ttfts)] if ttfts.size else ttfts
    tpots = np.asarray([f.tpot_s for f in finished], np.float64)
    tpots = tpots[~np.isnan(tpots)] if tpots.size else tpots
    out = {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0, "ttft_ms_mean": 0.0,
           "ttft_ms_p95": 0.0, "tpot_ms_mean": 0.0}
    if lats.size:
        out["p50_ms"] = float(np.percentile(lats, 50) * 1e3)
        out["p95_ms"] = float(np.percentile(lats, 95) * 1e3)
        out["p99_ms"] = float(np.percentile(lats, 99) * 1e3)
    if ttfts.size:
        out["ttft_ms_mean"] = float(np.mean(ttfts) * 1e3)
        out["ttft_ms_p95"] = float(np.percentile(ttfts, 95) * 1e3)
    if tpots.size:
        out["tpot_ms_mean"] = float(np.mean(tpots) * 1e3)
    return out


def run_engine(args, cfg, bundle, params, stem_cfg, budget_frac):
    import jax.numpy as jnp  # noqa: F401  (keeps jax initialized up front)
    from repro.runtime.engine import EngineConfig, StemEngine

    mesh = None
    if args.mesh:
        try:
            dp, tp = (int(x) for x in args.mesh.split(","))
        except ValueError:
            raise SystemExit(f"--mesh wants 'dp,tp' (got {args.mesh!r})")
        mesh = (dp, tp)
    ecfg = EngineConfig.for_trace(
        max_slots=args.max_slots, max_prompt=args.max_prompt,
        max_new_tokens=args.decode_tokens, page_size=stem_cfg.block_size,
        budget_frac=budget_frac,
        chunk_size=args.chunk_size or None,
        step_token_budget=args.step_token_budget or None,
        monolithic_prefill=args.monolithic,
        prefix_cache=args.prefix_cache,
        prefix_evict=args.prefix_evict,
        scheduler=args.scheduler,
        max_waiting=args.max_waiting or None,
        executor=args.executor or None,
        mesh=mesh,
        admission_control=args.admission_control,
        async_depth=args.async_depth,
        sampler=args.sampler)
    chaos = None
    if args.chaos:
        from repro.runtime.chaos import ChaosConfig, ChaosInjector
        chaos = ChaosInjector(ChaosConfig(deny_alloc_steps=(2,),
                                          fail_steps=(4,),
                                          fail_restore_steps=(7,)))
    engine = StemEngine(bundle, params, stem_cfg, ecfg, chaos=chaos)
    rng = np.random.RandomState(args.seed + 1)
    trace = build_trace(rng, args.requests, args.min_prompt, args.max_prompt,
                        args.decode_tokens, cfg.vocab_size, args.arrival_every,
                        hp_every=args.hp_every,
                        hp_ttft_slo_s=args.hp_ttft_slo_ms * 1e-3,
                        hp_tpot_slo_s=args.hp_tpot_slo_ms * 1e-3)
    t0 = time.perf_counter()
    finished = engine.run(trace)
    wall = time.perf_counter() - t0
    ok = [f for f in finished if f.error is None]
    failed = [f for f in finished if f.error is not None]
    stats = _latency_stats(ok)
    total_tokens = sum(len(f.tokens) for f in finished)
    metrics = engine.metrics
    out = {
        "mode": "engine",
        "prefill": "monolithic" if args.monolithic else "chunked",
        "loop": "async" if ecfg.async_depth else "sync",
        "scheduler": ecfg.scheduler,
        "mesh": list(mesh) if mesh else None,
        "chunk_size": engine.chunk_size,
        "step_token_budget": engine.token_budget,
        "requests": len(finished),
        "failed": {f.uid: f.error for f in failed},
        "total_tokens": total_tokens,
        "wall_s": wall,
        "throughput_tok_s": total_tokens / max(wall, 1e-9),
        "engine_stats": dict(engine.stats),
        "engine_metrics": {
            "step_time_ema_s": metrics["step_time_ema_s"],
            "straggler_steps": metrics["straggler_steps"],
            "offload_peak_bytes": metrics["offload_peak_bytes"],
            "chaos": metrics["chaos"],
        },
        "tokens": {f.uid: f.tokens for f in finished},
        **stats,
    }
    print(f"engine ({out['prefill']}, {out['loop']}, {ecfg.scheduler}): "
          f"{len(finished)} "
          f"reqs ({len(failed)} failed), {total_tokens} "
          f"tokens in {wall*1e3:.0f} ms -> {out['throughput_tok_s']:.1f} "
          f"tok/s; TTFT {out['ttft_ms_mean']:.1f} ms; TPOT "
          f"{out['tpot_ms_mean']:.2f} ms; inter-token p50 "
          f"{out['p50_ms']:.2f} / p95 {out['p95_ms']:.2f} ms; "
          f"traces {engine.stats['traces']}"
          f"+{engine.stats['prefill_traces']} prefill; "
          f"slots reused {engine.stats['slots_reused']}, "
          f"max concurrency {engine.stats['max_concurrency']}", flush=True)
    s = engine.stats
    if ecfg.async_depth:
        print(f"  async: depth {ecfg.async_depth}, blocking host syncs "
              f"{s['host_syncs']} over {s['id_fetches']} id fetches, "
              f"lookahead discards {s['lookahead_discards']}", flush=True)
    if s["pallas_fallbacks"]:
        print(f"  pallas: {s['pallas_fallbacks']} call site(s) fell back to "
              f"the XLA oracle", flush=True)
    if any(s[k] for k in ("preemptions", "shed", "aborts", "step_failures",
                          "restore_failures", "straggler_steps")):
        print(f"  resilience: preemptions {s['preemptions']} "
              f"(restores {s['restores']}), shed {s['shed']}, aborts "
              f"{s['aborts']}, step failures {s['step_failures']}, restore "
              f"failures {s['restore_failures']}; offload peak "
              f"{metrics['offload_peak_bytes']} B", flush=True)
    if args.prefix_cache:
        print(f"  prefix cache: hits {s['prefix_hits']}, pages shared "
              f"{s['prefix_pages_shared']}, cows {s['prefix_cows']}; "
              f"allocator shares {engine.allocator.shares}, cached pages "
              f"{engine.allocator.cached_pages}, total alloced "
              f"{engine.allocator.total_alloced}", flush=True)
    if metrics["straggler_steps"]:
        worst = max(metrics["straggler_steps"], key=lambda f: f[1])
        print(f"  stragglers: {len(metrics['straggler_steps'])} flagged "
              f"steps (EMA {metrics['step_time_ema_s']*1e3:.2f} ms; worst "
              f"step {worst[0]} at {worst[1]*1e3:.1f} ms vs EMA "
              f"{worst[2]*1e3:.2f} ms)", flush=True)
    return out


def run_fixed_batch(args, cfg, bundle, params, stem_cfg, budget_frac=1.0):
    """Legacy one-shot batch, ragged: pad per request, per-row cache_lens.
    With ``stem_cfg`` both prefill AND decode run policy-sparse (decode
    re-summarizes the contiguous cache per step — the differential
    reference arm for the paged engine)."""
    import jax
    import jax.numpy as jnp
    from repro.core import policy as policy_lib
    from repro.launch import steps as steps_lib
    from repro.models import transformer
    from repro.runtime import sampling as sampling_lib

    # Right-padded ragged prompts are only sound for global-attention
    # mixers: per-row masking hides padding K/V, and decode overwrites it.
    # Recurrent/SSM states absorb padding tokens irreversibly, and ring
    # caches treat padding slots as valid in-window keys.
    kinds = {k for _, ks in transformer.layer_program(cfg) for k in ks}
    unsafe = kinds - {"dense", "moe", "mla_dense", "mla_moe"}
    if unsafe:
        raise NotImplementedError(
            f"--fixed-batch ragged prompts unsupported for sub-layers "
            f"{sorted(unsafe)} ({cfg.name}): padding would contaminate "
            "recurrent/ring state")

    rng = np.random.RandomState(args.seed + 1)
    lens = rng.randint(args.min_prompt, args.max_prompt + 1,
                       size=(args.requests,)).astype(np.int32)
    max_prompt = int(lens.max())
    max_len = max_prompt + args.decode_tokens
    if stem_cfg is not None:
        # Sparse decode re-summarizes the contiguous cache, which needs the
        # cache length to be a whole number of blocks.
        bs = policy_lib.as_policy(stem_cfg).block_size
        max_len = -(-max_len // bs) * bs
    toks = np.zeros((args.requests, max_prompt), np.int32)
    for i, L in enumerate(lens):
        toks[i, :L] = rng.randint(0, cfg.vocab_size, size=(int(L),))

    # Same on-device sampling op as the engine (runtime/sampling.py) —
    # the sampled ids stay on device between steps and only the int32
    # ids are pulled to host, never the (b, vocab) logits.
    sampler = sampling_lib.get_sampler(getattr(args, "sampler", "greedy"))
    prefill = jax.jit(lambda p, b, lp: bundle.prefill(
        p, b, max_len=max_len, stem_cfg=stem_cfg, last_pos=lp))
    serve = jax.jit(
        steps_lib.make_serve_step(bundle, stem_cfg=stem_cfg,
                                  budget_frac=budget_frac),
        donate_argnums=(2,), static_argnames=())
    sample = jax.jit(lambda lg: sampler(lg)[:, None])

    t0 = time.perf_counter()
    batch = {"tokens": jnp.asarray(toks)}
    logits, caches = jax.block_until_ready(
        prefill(params, batch, jnp.asarray(lens - 1)))
    ttft = time.perf_counter() - t0
    toks_step = sample(logits)
    out_tokens = [np.asarray(toks_step)]
    t1 = time.perf_counter()
    cache_lens = jnp.asarray(lens)
    for i in range(args.decode_tokens - 1):
        logits, caches = serve(params, toks_step, caches,
                               cache_lens if i == 0 else None)
        toks_step = sample(logits)
        out_tokens.append(np.asarray(toks_step))
    jax.block_until_ready(toks_step)
    dt = time.perf_counter() - t1
    per_tok = dt / max(args.decode_tokens - 1, 1)
    gen = np.concatenate(out_tokens, axis=1)
    print(f"fixed-batch (ragged lens {lens.tolist()}): TTFT {ttft*1e3:.1f} ms, "
          f"decode {per_tok*1e3:.2f} ms/token ({args.requests} seqs)", flush=True)
    return {"mode": "fixed-batch", "ttft_s": ttft, "ms_per_token": per_tok * 1e3,
            "prompt_lens": lens.tolist(),
            "tokens": {i: gen[i].tolist() for i in range(args.requests)}}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--min-prompt", type=int, default=48)
    ap.add_argument("--max-prompt", type=int, default=200)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="request i arrives at engine step i * this")
    ap.add_argument("--stem", action="store_true",
                    help="sparse decode budget (< 1.0); off = dense-equivalent")
    ap.add_argument("--policy", default=None,
                    help="named SparsityPolicy from the registry "
                         "(core/policy.py: stem, stem-sam, uniform-sam, "
                         "streaming, xattention, ...); default builds the "
                         "stem policy from StemConfig flags.  Implies the "
                         "sparse arm unless --budget-frac overrides it")
    ap.add_argument("--budget-frac", type=float, default=0.5)
    ap.add_argument("--block-size", type=int, default=0,
                    help="Stem block/page size; 0 = auto from max prompt")
    ap.add_argument("--chunk-size", type=int, default=0,
                    help="prefill chunk width in tokens (multiple of the "
                         "page size); 0 = auto (2 pages)")
    ap.add_argument("--step-token-budget", type=int, default=0,
                    help="max tokens one engine step spends (decode tokens "
                         "first, then prefill chunks); 0 = auto "
                         "(max_slots + chunk)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="hash-keyed prefix-page sharing with copy-on-write: "
                         "admission maps matched whole prompt pages "
                         "read-only and prefills only the unmatched suffix "
                         "(chunked prefill only)")
    ap.add_argument("--monolithic", action="store_true",
                    help="legacy one-shot admission prefill (per-length "
                         "traces, head-of-line blocking) — the chunked A/B "
                         "baseline")
    ap.add_argument("--scheduler", choices=("slo", "fcfs"), default="slo",
                    help="token-budget scheduling order: 'slo' = priority + "
                         "SLO headroom (preemption-capable), 'fcfs' = "
                         "admission order (the PR 5 baseline)")
    ap.add_argument("--max-waiting", type=int, default=0,
                    help="waiting-queue bound; overflow sheds the lowest-"
                         "priority pending request (0 = unbounded)")
    ap.add_argument("--hp-every", type=int, default=0,
                    help="every Nth request is priority 1 with the --hp-* "
                         "SLOs (0 = uniform priority)")
    ap.add_argument("--hp-ttft-slo-ms", type=float, default=500.0,
                    help="TTFT SLO for the high-priority class")
    ap.add_argument("--hp-tpot-slo-ms", type=float, default=50.0,
                    help="TPOT SLO for the high-priority class")
    ap.add_argument("--mesh", default="",
                    help="'dp,tp' device mesh: dp-way data-parallel slot "
                         "groups x tp-way tensor-parallel KV-head sharding "
                         "of the page pools (needs dp*tp visible devices; "
                         "empty = single-device)")
    ap.add_argument("--executor", default="",
                    help="paged executor to force ('xla' | 'pallas'); empty "
                         "= policy default")
    ap.add_argument("--prefix-evict", choices=("lru", "hit-rate"),
                    default="lru",
                    help="prefix-cache eviction: 'lru' (default) or "
                         "'hit-rate' (evict fewest-shares-first, LRU ties)")
    ap.add_argument("--admission-control", action="store_true",
                    help="reject waiting requests whose TTFT SLO is "
                         "infeasible at the measured step time (explicit "
                         "error instead of a silent SLO miss)")
    ap.add_argument("--async-depth", type=int, default=0,
                    help="0 = synchronous engine loop (the differential "
                         "oracle); 1 = async pipeline: on-device sampling, "
                         "token-id-only transfers, one-step-lookahead "
                         "dispatch (bit-identical streams)")
    ap.add_argument("--sampler", default="greedy",
                    help="registered on-device sampler "
                         "(runtime/sampling.py); greedy = argmax")
    ap.add_argument("--chaos", action="store_true",
                    help="inject a fixed fault plan (alloc denial, step "
                         "failure, restore failure) — resilience demo; the "
                         "run must still complete every request")
    ap.add_argument("--fixed-batch", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro import configs
    from repro.core.config import StemConfig
    from repro.models import registry
    import jax

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg).replace(dtype="float32")
    if cfg.family == "encdec" or cfg.vlm_stub:
        raise NotImplementedError(
            f"serve drives token-only decoder prompts; {cfg.name} needs "
            "encoder frames / patch embeddings (use launch/eval paths)")
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(args.seed))

    bs = args.block_size or max(16, min(128, args.max_prompt // 8))
    bs = -(-bs // 8) * 8
    if args.policy:
        # Resolve the named policy and rescale its geometry/stability knobs
        # to the serving shape (registered defaults carry paper geometry:
        # B=128 over 8k+ contexts).  ignore_missing: content-free policies
        # (streaming) have no stride/min_budget fields to rewrite.
        from repro.core import policy as policy_lib
        stem_cfg = policy_lib.get_policy(args.policy).with_updates(
            block_size=bs, stride=4, sink_blocks=1, local_blocks=1,
            min_budget_blocks=2, ignore_missing=True)
        sparse = True
    else:
        stem_cfg = StemConfig(block_size=bs, min_budget_blocks=2, sink_blocks=1,
                              local_blocks=1, stride=4)
        sparse = args.stem
    budget_frac = args.budget_frac if sparse else 1.0
    name = args.policy or "stem"
    print(f"serve: arch={cfg.name} page/block={bs} policy={name} "
          f"sparse={'on' if sparse else 'off'} budget_frac={budget_frac}",
          flush=True)

    if args.fixed_batch:
        return run_fixed_batch(args, cfg, bundle, params,
                               stem_cfg if sparse else None, budget_frac)
    return run_engine(args, cfg, bundle, params, stem_cfg, budget_frac)


if __name__ == "__main__":
    main()
