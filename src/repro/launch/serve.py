"""Serving driver: Stem-accelerated prefill + batched decode.

Models the paper's deployment story: the pre-filling phase (the paper's
target) runs Stem block-sparse attention; decode then streams tokens from
the populated caches.  Requests are processed as a fixed batch (continuous
batching is out of scope; the step functions are compatible with it).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \\
      --prompt-len 256 --decode-tokens 32 --batch 4 --stem
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--stem", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.core.config import StemConfig
    from repro.launch import steps as steps_lib
    from repro.models import registry

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg).replace(dtype="float32")
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(args.seed))

    stem_cfg = None
    if args.stem and cfg.use_stem:
        bs = max(16, min(128, args.prompt_len // 8))
        stem_cfg = StemConfig(block_size=bs, min_budget_blocks=2, sink_blocks=1,
                              local_blocks=1, stride=4)

    max_len = args.prompt_len + args.decode_tokens
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(args.seed + 1), (args.batch, args.prompt_len),
        0, cfg.vocab_size)}
    if cfg.vlm_stub:
        s_img = args.prompt_len // 4
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, s_img, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (args.batch, cfg.encdec.encoder_frames,
                                    cfg.d_model), jnp.float32)

    prefill = jax.jit(steps_lib.make_prefill_step(bundle, max_len=max_len,
                                                  stem_cfg=stem_cfg))
    serve = jax.jit(steps_lib.make_serve_step(bundle), donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, caches = jax.block_until_ready(prefill(params, batch))
    ttft = time.perf_counter() - t0
    print(f"prefill (TTFT proxy): {ttft*1e3:.1f} ms  stem={'on' if stem_cfg else 'off'}",
          flush=True)

    toks = jnp.argmax(logits, axis=-1)[:, None]
    out_tokens = [np.asarray(toks)]
    t1 = time.perf_counter()
    for _ in range(args.decode_tokens - 1):
        logits, caches = serve(params, toks, caches)
        toks = jnp.argmax(logits, axis=-1)[:, None]
        out_tokens.append(np.asarray(toks))
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t1
    per_tok = dt / max(args.decode_tokens - 1, 1)
    print(f"decode: {per_tok*1e3:.2f} ms/token ({args.batch} seqs)", flush=True)
    gen = np.concatenate(out_tokens, axis=1)
    print(f"generated shape: {gen.shape}", flush=True)
    return {"ttft_s": ttft, "ms_per_token": per_tok * 1e3, "tokens": gen}


if __name__ == "__main__":
    main()
