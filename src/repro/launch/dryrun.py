import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod) on
     512 placeholder host devices,
  2. lowers the right step (train_step / prefill_step / serve_step) from
     ShapeDtypeStructs — parameters, optimizer state and KV caches are all
     abstract; nothing is allocated,
  3. compiles, prints memory_analysis() (proves the cell fits) and
     cost_analysis() (FLOPs/bytes for the roofline),
  4. parses the compiled HLO for collective ops and estimates per-chip
     collective bytes (ring/all-to-all models),
  5. writes a JSON record consumed by benchmarks/roofline.py and
     EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --multi-pod
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs, optim
from repro.launch import hlo_analysis
from repro.sharding import context as sharding_context
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import registry
from repro.sharding import rules as rules_lib

# TPU v5e-class hardware constants (per chip) — the roofline denominators.
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\(?[a-z0-9\[\],\s]+\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-chip collective traffic estimate from the (SPMD, per-device) HLO.

    Ring models: all-reduce moves ~2x the tensor, all-gather/reduce-scatter
    ~1x the (large) tensor, all-to-all ~1x, collective-permute 1x.  The
    (n-1)/n factor is dropped (<7% at n >= 16).
    """
    counts: dict[str, int] = {}
    bytes_by: dict[str, float] = {}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        line = m.group(0)
        if "-done(" in line:          # async pairs: count the -start only
            continue
        b = _shape_bytes(type_str)
        mult = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}[op]
        counts[op] = counts.get(op, 0) + 1
        bytes_by[op] = bytes_by.get(op, 0.0) + b * mult
    return {"counts": counts, "bytes_by_op": bytes_by,
            "total_bytes": sum(bytes_by.values())}


def _cell_step_and_args(arch: str, shape_name: str, mesh):
    cfg = configs.get_config(arch)
    shape = {s.name: s for s in configs.ALL_SHAPES}[shape_name]
    bundle = registry.build(cfg)
    abstract_values, axes_tree = bundle.abstract_params()
    param_sh = rules_lib.param_shardings(cfg, mesh, abstract_values, axes_tree)
    in_specs = registry.input_specs(cfg, shape)
    batch_sh = rules_lib.batch_sharding(cfg, mesh, in_specs)
    stem_cfg = steps_lib.default_stem_cfg(cfg)

    if shape.kind == "train":
        opt_cfg = optim.AdamWConfig(
            moment_dtype="bfloat16" if cfg.fsdp_weights else "float32")
        state_sh = steps_lib.opt_state_shardings(cfg, mesh, param_sh, abstract_values)
        step = steps_lib.make_train_step(bundle, opt_cfg, stem_cfg=None, remat=True,
                                         microbatches=cfg.train_microbatches,
                                         grad_shardings=state_sh.master)
        state = steps_lib.abstract_opt_state(abstract_values, opt_cfg)
        return step, (state, in_specs), (state_sh, batch_sh), (0,), None
    # Serving cells must pin OUTPUT shardings too: with unspecified
    # out_shardings GSPMD may replicate the returned KV caches (observed:
    # 429 GB/device for qwen1.5 whose 20 kv heads defeat propagation).
    from jax.sharding import NamedSharding
    rules = rules_lib.logical_rules(cfg, mesh)
    logits_sh = NamedSharding(mesh, rules_lib.spec_for(
        (shape.global_batch, cfg.padded_vocab), ("batch", "vocab"), rules, mesh))
    caches = registry.abstract_caches(cfg, shape)
    cache_sh = rules_lib.cache_shardings(cfg, mesh, caches)
    if shape.kind == "prefill":
        step = steps_lib.make_prefill_step(bundle, max_len=shape.seq_len,
                                           stem_cfg=stem_cfg)
        return step, (abstract_values, in_specs), (param_sh, batch_sh), (), \
            (logits_sh, cache_sh)
    step = steps_lib.make_serve_step(bundle)
    return step, (abstract_values, in_specs["tokens"], caches), \
        (param_sh, batch_sh["tokens"], cache_sh), (2,), (logits_sh, cache_sh)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool) -> dict:
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": f"{'x'.join(str(s) for s in mesh.devices.shape)}",
                 "chips": int(n_chips), "multi_pod": multi_pod}
    t0 = time.time()
    step, args, shardings, donate, out_sh = _cell_step_and_args(arch, shape_name, mesh)
    cfg0 = configs.get_config(arch)
    with mesh, sharding_context.use(cfg0, mesh):
        # Donation mirrors the real drivers (train donates the opt state,
        # serve donates the caches) — memory_analysis reflects steady state.
        kw = {} if out_sh is None else {"out_shardings": out_sh}
        jitted = jax.jit(step, in_shardings=shardings, donate_argnums=donate, **kw)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "peak_bytes": int(getattr(mem, "temp_size_in_bytes", 0))
                + int(getattr(mem, "argument_size_in_bytes", 0)),
            }
        except Exception as e:   # CPU backend may not implement it
            rec["memory"] = {"error": str(e)}

        # XLA's own cost_analysis counts while bodies once — recorded for
        # reference; the roofline uses the loop-aware structural analyzer.
        try:
            cost = compiled.cost_analysis()
            cost = cost[0] if isinstance(cost, (list, tuple)) else cost
            rec["xla_cost_flops_loopbody_once"] = float(cost.get("flops", 0.0))
        except Exception as e:
            rec["cost_error"] = str(e)

        hlo = compiled.as_text()
        c = hlo_analysis.analyze_hlo(hlo)
        rec["flops_per_device"] = c.flops
        # bytes_min = fusion-ideal (TPU epilogue fusion) traffic; the
        # no-fusion CPU-HLO upper bound is recorded alongside.  The
        # roofline memory term uses the fusion-ideal number (documented in
        # EXPERIMENTS.md section Roofline).
        rec["bytes_per_device"] = c.bytes_min
        rec["bytes_per_device_nofusion"] = c.bytes
        rec["flops_by_op"] = c.flops_by_op
        rec["collectives"] = {"counts": c.coll_counts, "bytes_by_op": c.coll_by_op,
                              "total_bytes": c.coll_bytes}
        rec["hlo_bytes"] = len(hlo)

    # Roofline terms (per chip; cost_analysis is the per-device SPMD program).
    coll = rec["collectives"]["total_bytes"]
    rec["roofline"] = {
        "compute_s": rec["flops_per_device"] / PEAK_FLOPS,
        "memory_s": rec["bytes_per_device"] / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    terms = rec["roofline"]
    rec["roofline"]["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])

    # MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE); decode D = batch tokens.
    cfg = configs.get_config(arch)
    shape = {s.name: s for s in configs.ALL_SHAPES}[shape_name]
    total_p, active_p = registry.param_counts(cfg)
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[shape.kind]
    model_flops = mult * active_p * tokens
    rec["model_flops_total"] = model_flops
    hlo_total = rec["flops_per_device"] * n_chips
    rec["model_flops_ratio"] = model_flops / hlo_total if hlo_total else 0.0
    return rec


def cells(arch_filter: str):
    for name in sorted(configs.ASSIGNED):
        if arch_filter not in ("all", name):
            continue
        cfg = configs.get_config(name)
        for shape in configs.shapes_for(cfg):
            yield name, shape.name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells(args.arch):
        if args.shape not in ("all", shape):
            continue
        tag = f"{arch}__{shape}__{'multipod' if args.multi_pod else 'pod'}"
        out_path = os.path.join(args.out, tag + ".json")
        print(f"=== {tag} ===", flush=True)
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod)
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=1)
            r = rec["roofline"]
            print(f"  compile={rec['compile_s']}s flops/dev={rec['flops_per_device']:.3e}"
                  f" bytes/dev={rec['bytes_per_device']:.3e}"
                  f" coll={rec['collectives']['total_bytes']:.3e}B"
                  f" bottleneck={r['bottleneck']}", flush=True)
            if "peak_bytes" in rec.get("memory", {}):
                print(f"  memory: {json.dumps(rec['memory'])}", flush=True)
        except Exception as e:
            failures.append((tag, str(e)))
            with open(out_path + ".err", "w") as f:
                f.write(traceback.format_exc())
            print(f"  FAILED: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print("\nall cells compiled OK")


if __name__ == "__main__":
    main()
