"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests use small host-device meshes)."""
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist, as a (data, model) mesh with model = 1."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
