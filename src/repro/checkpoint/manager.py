"""Mesh-agnostic, atomic, keep-K checkpointing with cross-mesh restore.

Design points for the 1000+-node posture:
  * **Atomicity** — writes go to ``step_<n>.tmp/`` and are renamed into
    place; a crash mid-save never corrupts the latest checkpoint.
  * **Mesh-agnostic format** — arrays are saved as logical (unsharded)
    ``.npy`` payloads keyed by pytree path.  Restore takes the *target*
    sharding tree of the live mesh, so a job restarted on a different pod
    count / mesh shape reshards transparently (elastic scaling; exercised in
    tests/test_fault_tolerance.py).  Production would swap the payload layer
    for tensorstore/OCDBT shards; the protocol (atomic rename, keep-K,
    latest-step discovery, reshard-on-load) is the same.
  * **Async** — ``save(..., blocking=False)`` hands the host copy to a
    writer thread so the train loop overlaps checkpoint I/O with compute.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


import ml_dtypes

_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _flatten_with_paths(tree) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """Returns (arrays, dtype map).  Dtypes numpy can't serialize (bfloat16)
    are stored as same-width integer views and recorded in the map."""
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        name = str(arr.dtype)
        if name in _EXOTIC:
            dtypes[key] = name
            arr = arr.view(_EXOTIC[name][1])
        flat[key] = arr
    return flat, dtypes


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- discovery ----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "DONE")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = True) -> None:
        flat, dtypes = _flatten_with_paths(tree)  # host copy on the caller

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, "extra": extra or {},
                           "dtypes": dtypes}, f)
            with open(os.path.join(tmp, "DONE"), "w") as f:
                f.write("ok")
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        self.wait()
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def restore(self, target_tree: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``target_tree``; if ``shardings``
        (a matching tree of NamedSharding) is given, arrays are placed
        sharded — this is the cross-mesh/elastic reshard path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        payload = np.load(os.path.join(d, "arrays.npz"))
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)

        saved_dtypes = meta.get("dtypes", {})
        paths = jax.tree_util.tree_flatten_with_path(target_tree)[0]
        leaves = []
        for path, ref in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                           for p in path)
            arr = payload[key]
            if key in saved_dtypes:
                arr = arr.view(_EXOTIC[saved_dtypes[key]][0])
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {ref.shape}")
            leaves.append(arr.astype(ref.dtype))
        restored = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target_tree), leaves)
        if shardings is not None:
            restored = jax.tree.map(
                lambda a, s: jax.device_put(a, s), restored, shardings)
        else:
            restored = jax.tree.map(jax.numpy.asarray, restored)
        return restored, meta
