"""Configuration for the Stem sparse-attention module.

Defaults follow the paper (Section 3.1 Implementation Details):
block size B = 128, decay ratio mu = 0.7, metric coefficient beta = 0.2,
4 sink + 4 local blocks, minimum per-row budget of 54 blocks, and
k_start = 0.2 * N_blk for sequences of 8k-16k tokens / 0.1 * N_blk above 16k.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def default_k_start_fraction(seq_len: int) -> float:
    """Paper §3.1 length-dependent rule: 0.2 up to 16k keys, 0.1 above."""
    return 0.2 if seq_len <= 16384 else 0.1


def k_start_blocks_for(k_start_frac: Optional[float], kv_len: int,
                       block_size: int) -> int:
    """Initial TPD budget in blocks — the one canonical implementation
    shared by ``StemConfig`` and the policy schedules."""
    frac = (default_k_start_fraction(kv_len) if k_start_frac is None
            else k_start_frac)
    n_blocks = -(-kv_len // block_size)
    return max(1, int(frac * n_blocks))


def validate_sparse_segment(seg) -> None:
    """Raise ValueError unless ``seg`` is None or a (lo, hi) number pair
    with 0 <= lo < hi <= 1 (shared by StemConfig and TPDSchedule)."""
    if seg is None:
        return
    if not (isinstance(seg, tuple) and len(seg) == 2):
        raise ValueError(f"sparse_segment must be a (lo, hi) 2-tuple, got {seg!r}")
    lo, hi = seg
    try:
        lo, hi = float(lo), float(hi)
    except (TypeError, ValueError):
        raise ValueError(f"sparse_segment entries must be numbers, got {seg!r}")
    if not (0.0 <= lo < hi <= 1.0):
        raise ValueError(f"sparse_segment needs 0 <= lo < hi <= 1, got {seg!r}")


@dataclasses.dataclass(frozen=True)
class StemConfig:
    """Hyper-parameters of Stem (Token Position-Decay + Output-Aware Metric).

    This is the *frozen flag record*: a hashable bag of paper
    hyper-parameters.  The composable form — and the primary interface of
    the execution paths — is :class:`repro.core.policy.SparsityPolicy`;
    ``cfg.policy()`` converts this record into the equivalent policy
    (OAM/SAM metric x TPD schedule x top-k selector).  Every function that
    historically took a ``StemConfig`` still does, via that shim.

    Attributes:
      block_size: attention block granularity B (MXU-aligned; paper uses 128).
      k_start_frac: initial budget as a fraction of the number of key blocks.
        ``None`` selects the paper's length-dependent rule (0.2 for N <= 16k,
        0.1 above).
      mu: decay ratio in (0, 1]; k_end = mu * k_start (Eq. 3). mu = 1 is the
        uniform schedule.
      beta: weight of the value-magnitude term in the Output-Aware Metric
        (Eq. 7).
      stride: anti-diagonal sampling stride ``s`` for metric downsampling;
        the pooled representation keeps ``s`` group-mean vectors per block.
      sink_blocks: leading key blocks always retained (attention sink).
      local_blocks: trailing (diagonal-local) key blocks always retained.
      min_budget_blocks: per-query-row floor on the number of key blocks.
      pooling: "antidiag" (XAttention-style separable anti-diagonal pooling)
        or "mean" (plain block mean pooling).
      metric: "oam" (Eq. 7) or "sam" (routing-only score; ablation baseline).
      group_reduce: how to share selection across the query heads of one KV
        group for GQA models: "none" (per-query-head selection, paper
        default), "mean" or "max" (InfLLMv2-style shared selection).
      backend: "xla" (gather-based sparse execution; used under pjit),
        "pallas" (TPU kernel; interpret mode on CPU) or "dense" (O(N^2)
        masked oracle, tests only).
      slot_chunk: number of selected key blocks processed per inner step of
        the XLA flash-style executor (memory/latency trade-off).
      ragged: budget-aware ragged execution (DESIGN.md).  Rows run only the
        slot chunks their TPD budget needs (budget-sorted segment schedule)
        and GQA groups with shared selection deduplicate K/V block fetches
        to one per KV head.  False restores the padded execution where every
        row pays k_max slots — kept for A/B benchmarking (ragged_exec.py).
    """

    block_size: int = 128
    k_start_frac: Optional[float] = None
    mu: float = 0.7
    beta: float = 0.2
    stride: int = 16
    sink_blocks: int = 4
    local_blocks: int = 4
    min_budget_blocks: int = 54
    pooling: str = "antidiag"
    metric: str = "oam"
    group_reduce: str = "none"
    backend: str = "xla"
    slot_chunk: int = 8
    ragged: bool = True
    # Analysis knob (paper Fig. 3): when set to (lo, hi) fractions, only
    # query rows in [lo*N, hi*N) are sparsified; all other rows keep their
    # full causal budget.  None = sparsify everywhere (normal operation).
    sparse_segment: Optional[tuple] = None

    def __post_init__(self) -> None:
        if not (0.0 < self.mu <= 1.0):
            raise ValueError(f"mu must be in (0, 1], got {self.mu}")
        if self.beta < 0.0:
            raise ValueError(f"beta must be >= 0, got {self.beta}")
        if self.block_size <= 0 or self.block_size % 8 != 0:
            raise ValueError(f"block_size must be a positive multiple of 8, got {self.block_size}")
        if self.stride <= 0 or self.block_size % self.stride != 0:
            raise ValueError("stride must divide block_size")
        if self.pooling not in ("antidiag", "mean"):
            raise ValueError(f"unknown pooling {self.pooling!r}")
        if self.metric not in ("oam", "sam"):
            raise ValueError(f"unknown metric {self.metric!r}")
        if self.group_reduce not in ("none", "mean", "max"):
            raise ValueError(f"unknown group_reduce {self.group_reduce!r}")
        if self.backend not in ("xla", "pallas", "dense"):
            raise ValueError(f"unknown backend {self.backend!r}")
        validate_sparse_segment(self.sparse_segment)

    def policy(self):
        """The equivalent :class:`repro.core.policy.SparsityPolicy`.

        Deterministic and cached per config, so jit treats repeated
        conversions of equal configs as the same static argument."""
        from repro.core import policy as policy_lib  # deferred: avoid cycle

        return policy_lib.policy_from_config(self)

    def k_start_fraction(self, seq_len: int) -> float:
        """Paper's length-dependent initial-budget fraction (Section 3.1)."""
        if self.k_start_frac is not None:
            return self.k_start_frac
        return default_k_start_fraction(seq_len)

    def k_start_blocks(self, seq_len: int) -> int:
        return k_start_blocks_for(self.k_start_frac, seq_len, self.block_size)


# Budget-matched uniform equivalent used in the paper's ablation (Table 5):
# k_uni ~= k_start * (1 + mu) / 2.
def uniform_equivalent_budget(k_start: int, mu: float) -> int:
    return max(1, int(round(k_start * (1.0 + mu) / 2.0)))
