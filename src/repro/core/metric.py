"""Output-Aware Metric (OAM) and block-wise metric downsampling.

Implements the coarse metric of Algorithm 1 (lines 4-6 and 11-13):

  * anti-diagonal downsampling of Q and K (XAttention-style).  The strided
    anti-diagonal score sum over a B x B tile,
        sum_{(a+b) mod s == 0} q_a . k_b,
    factors into group sums:  b = -a (mod s), hence
        sum_u  < G_q[u], G_k[(s-u) mod s] >,
    where G_q[u] = sum_{a mod s == u} q_a.  We keep *group means* so the
    pooled score approximates the mean attention logit of the tile, keeping
    the beta = 0.2 scale of Eq. (7) meaningful.
  * block max-pooled value magnitude  M_V = maxpool(log ||V_j||_2).
  * metric assembly (Eq. 7):  M = QK^T/sqrt(d) + beta * max(0, M_V).

Shapes use the (batch, heads, seq, head_dim) convention.

The explicit-argument entry points (``blockwise_routing_scores``,
``oam_scores``, ``decode_routing_scores``) are what the policy metrics in
``core/policy.py`` call; the ``*(…, cfg)`` wrappers keep the historical
flag-record signatures working.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import StemConfig


def _check_divisible(seq_len: int, block_size: int) -> int:
    if seq_len % block_size != 0:
        raise ValueError(f"seq_len {seq_len} must be a multiple of block_size {block_size}")
    return seq_len // block_size


def antidiag_pool(x: jnp.ndarray, block_size: int, stride: int) -> jnp.ndarray:
    """Group-mean pooling for separable anti-diagonal scoring.

    Args:
      x: (..., seq, dim)
      block_size: tile size B.
      stride: anti-diagonal stride s (must divide B).

    Returns:
      (..., n_blocks, stride, dim) — group u holds the mean of rows whose
      within-block position is congruent to u (mod s).
    """
    *lead, seq, dim = x.shape
    n_blocks = _check_divisible(seq, block_size)
    per_group = block_size // stride
    # (..., n_blocks, per_group, stride, dim): position p = g * stride + u.
    xb = x.reshape(*lead, n_blocks, per_group, stride, dim)
    return xb.mean(axis=-3)


def mean_pool(x: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Plain block mean pooling: (..., seq, dim) -> (..., n_blocks, dim)."""
    *lead, seq, dim = x.shape
    n_blocks = _check_divisible(seq, block_size)
    return x.reshape(*lead, n_blocks, block_size, dim).mean(axis=-2)


def antidiag_routing_scores(
    q_pooled: jnp.ndarray, k_pooled: jnp.ndarray, head_dim: int
) -> jnp.ndarray:
    """Blockwise routing scores from anti-diagonal group means.

    Args:
      q_pooled: (..., nq, s, d) group means of Q.
      k_pooled: (..., nk, s, d) group means of K.
      head_dim: original head dimension (softmax scale uses sqrt(head_dim)).

    Returns:
      (..., nq, nk) approximate mean attention logits per block pair.
    """
    s = q_pooled.shape[-2]
    # Pair group u of Q with group (s - u) mod s of K.
    pair = (s - jnp.arange(s)) % s
    k_matched = jnp.take(k_pooled, pair, axis=-2)
    scores = jnp.einsum("...iud,...jud->...ij", q_pooled, k_matched)
    return scores / (s * jnp.sqrt(jnp.asarray(head_dim, dtype=scores.dtype)))


def mean_routing_scores(
    q_pooled: jnp.ndarray, k_pooled: jnp.ndarray, head_dim: int
) -> jnp.ndarray:
    """Blockwise routing from plain mean pooling: (..., nq, nk)."""
    scores = jnp.einsum("...id,...jd->...ij", q_pooled, k_pooled)
    return scores / jnp.sqrt(jnp.asarray(head_dim, dtype=scores.dtype))


def value_block_magnitude(v: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """M_V: block max-pool of log ||V_j||_2  (Algorithm 1, line 6).

    Args:
      v: (..., seq, dim)
    Returns:
      (..., n_blocks) float32.
    """
    *lead, seq, dim = v.shape
    n_blocks = _check_divisible(seq, block_size)
    norms = jnp.linalg.norm(v.astype(jnp.float32), axis=-1)  # (..., seq)
    log_norms = jnp.log(jnp.maximum(norms, 1e-20))
    return log_norms.reshape(*lead, n_blocks, block_size).max(axis=-1)


def blockwise_routing_scores(
    q: jnp.ndarray,
    k: jnp.ndarray,
    *,
    block_size: int,
    stride: int,
    pooling: str = "antidiag",
) -> jnp.ndarray:
    """Downsampled routing scores between all (query block, key block) pairs.

    Explicit-argument form consumed by the policy metrics
    (``core/policy.py``); ``routing_scores(q, k, cfg)`` is the flag-record
    wrapper.

    Args:
      q: (batch, q_heads, seq_q, d)
      k: (batch, kv_heads, seq_k, d) — kv_heads must divide q_heads.

    Returns:
      (batch, q_heads, nq, nk) approximate mean logits.
    """
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    if hq % hk != 0:
        raise ValueError(f"q_heads {hq} not a multiple of kv_heads {hk}")
    group = hq // hk
    if pooling == "antidiag":
        qp = antidiag_pool(q, block_size, stride)  # (b, hq, nq, s, d)
        kp = antidiag_pool(k, block_size, stride)  # (b, hk, nk, s, d)
        kp = jnp.repeat(kp, group, axis=1)
        return antidiag_routing_scores(qp, kp, d)
    qp = mean_pool(q, block_size)
    kp = jnp.repeat(mean_pool(k, block_size), group, axis=1)
    return mean_routing_scores(qp, kp, d)


def routing_scores(
    q: jnp.ndarray, k: jnp.ndarray, cfg: StemConfig
) -> jnp.ndarray:
    """Flag-record wrapper over :func:`blockwise_routing_scores`."""
    return blockwise_routing_scores(
        q, k, block_size=cfg.block_size, stride=cfg.stride, pooling=cfg.pooling
    )


def oam_scores(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_size: int,
    stride: int,
    pooling: str = "antidiag",
    beta: float = 0.2,
) -> jnp.ndarray:
    """Full coarse metric of Eq. (7) at block granularity (explicit args).

    ``beta = 0`` degenerates to the routing-only Score-Aware Metric.

    Args:
      q: (batch, q_heads, seq_q, d)
      k, v: (batch, kv_heads, seq_k, d)

    Returns:
      (batch, q_heads, nq, nk) metric; higher = more important.
    """
    route = blockwise_routing_scores(
        q, k, block_size=block_size, stride=stride, pooling=pooling
    )
    if beta == 0.0:
        return route
    group = q.shape[1] // k.shape[1]
    mv = value_block_magnitude(v, block_size)  # (b, hk, nk)
    mv = jnp.repeat(mv, group, axis=1)  # (b, hq, nk)
    mag = jnp.maximum(mv, 0.0).astype(route.dtype)
    return route + beta * mag[..., None, :]


def oam_metric(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: StemConfig,
) -> jnp.ndarray:
    """Flag-record wrapper over :func:`oam_scores` (``metric="sam"`` zeroes
    the value-magnitude term, matching the ablation baseline)."""
    return oam_scores(
        q, k, v,
        block_size=cfg.block_size, stride=cfg.stride, pooling=cfg.pooling,
        beta=cfg.beta if cfg.metric == "oam" else 0.0,
    )


def chunk_routing_scores(
    q: jnp.ndarray,
    k_groups: jnp.ndarray,
    *,
    block_size: int,
    pooling: str = "antidiag",
) -> jnp.ndarray:
    """Routing scores of a *chunk* of queries against pooled key summaries.

    The chunked-prefill analogue of :func:`blockwise_routing_scores`: the
    query side is pooled live from the chunk (block-aligned, so the group
    means equal the one-shot pooling of those rows), while the key side
    comes pre-pooled from the paged cache summaries (``PagePool.kg``) — the
    exact same anti-diagonal group means ``antidiag_pool`` produces, so the
    resulting scores match one-shot prefill bit-for-bit on full key blocks.

    Args:
      q: (b, hq, C, d) chunk queries with C % block_size == 0.
      k_groups: (b, hk, n, stride, d) pooled key-block group means.

    Returns:
      (b, hq, nc, n) approximate mean logits (nc = C // block_size).
    """
    b, hq, c, d = q.shape
    hk = k_groups.shape[1]
    if hq % hk != 0:
        raise ValueError(f"q_heads {hq} not a multiple of kv_heads {hk}")
    group = hq // hk
    stride = k_groups.shape[-2]
    qp = antidiag_pool(q, block_size, stride)          # (b, hq, nc, s, d)
    kp = jnp.repeat(k_groups, group, axis=1)           # (b, hq, n, s, d)
    if pooling == "antidiag":
        return antidiag_routing_scores(qp, kp, d)
    # Plain mean pooling: the block mean is the mean of the (equal-sized)
    # anti-diagonal group means, so both sides reduce over the group axis.
    return mean_routing_scores(qp.mean(axis=-2), kp.mean(axis=-2), d)


def decode_routing_scores(q: jnp.ndarray, k_groups: jnp.ndarray) -> jnp.ndarray:
    """Block routing scores for a single decode query per sequence.

    q: (b, hq, 1, d); k_groups: (b, hk, n, stride, d) anti-diag group means.
    Returns (b, hk, group, n) float32 — the mean-over-groups inner product
    approximates the block mean logit for one query row.
    """
    b, hq, _, d = q.shape
    hk = k_groups.shape[1]
    group = hq // hk
    qg = q.reshape(b, hk, group, 1, d).astype(jnp.float32)
    kg = k_groups.astype(jnp.float32)
    route = jnp.einsum("bhgqd,bhnsd->bhgqn", qg, kg) / (
        kg.shape[-2] * jnp.sqrt(jnp.asarray(d, jnp.float32)))
    return route[:, :, :, 0]                                     # (b,hk,g,n)


def group_reduce_metric(metric: jnp.ndarray, group: int, mode: str) -> jnp.ndarray:
    """Optionally share the metric across the query heads of a KV group.

    Args:
      metric: (b, hq, nq, nk)
      group: q_heads // kv_heads
      mode: "none" | "mean" | "max"

    Returns:
      (b, hq, nq, nk) — for "mean"/"max" every head in a group carries the
      group-reduced metric, so downstream top-k selects identical blocks for
      the whole group (InfLLMv2-style sharing).
    """
    if mode == "none" or group == 1:
        return metric
    b, hq, nq, nk = metric.shape
    g = metric.reshape(b, hq // group, group, nq, nk)
    red = g.mean(axis=2) if mode == "mean" else g.max(axis=2)
    return jnp.repeat(red, group, axis=1)
