"""Token Position-Decay (TPD) budget schedule and its cost model.

Implements Eq. (3) of the paper — a per-query-position Top-k budget that
decays linearly from ``k_start`` at the first position to
``k_end = mu * k_start`` at the last — together with the analytic cost
model of Eq. (2) (uniform baseline) and Eq. (4) (decay schedule).

All schedule quantities exist at two granularities:
  * token-level k(i) (the paper's formulation), and
  * block-level budgets used by the block-sparse executor (Algorithm 1,
    line 15), which is what the kernels consume.

The numpy budget builders here (``tpd_budget_blocks``,
``uniform_budget_blocks``, ``dense_budget_blocks``,
``sink_local_budget_blocks``) back the ``BudgetSchedule`` policy objects in
``core/policy.py`` — budgets are static per (policy, shape), so they
resolve at trace time and drive the ragged execution schedule.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.config import StemConfig


def tpd_budget_tokens(seq_len: int, k_start: int, mu: float) -> np.ndarray:
    """Eq. (3): k(i) = floor(k_start - (k_start (1-mu) / N) * i), i in [0, N).

    Returns an int32 numpy array of per-query-position budgets (token units),
    *before* causal clamping.
    """
    i = np.arange(seq_len, dtype=np.float64)
    k = np.floor(k_start - (k_start * (1.0 - mu) / seq_len) * i)
    return np.maximum(k, 1.0).astype(np.int32)


def tpd_budget_blocks(
    n_query_blocks: int,
    n_key_blocks: int,
    k_start_blocks: int,
    mu: float,
    *,
    min_budget_blocks: int = 0,
) -> np.ndarray:
    """Block-level TPD schedule (Algorithm 1 line 15).

    For query-block row i the raw budget interpolates linearly from
    ``k_start_blocks`` down to ``mu * k_start_blocks``; it is then floored at
    ``min_budget_blocks`` and clamped to the causally admissible count
    (row i can attend to at most i+1 key blocks when the grids align).

    Returns int32 numpy array of shape (n_query_blocks,).
    """
    if n_query_blocks <= 0:
        raise ValueError("n_query_blocks must be positive")
    i = np.arange(n_query_blocks, dtype=np.float64)
    denom = max(n_query_blocks, 1)
    raw = np.floor(k_start_blocks - (k_start_blocks * (1.0 - mu) / denom) * i)
    raw = np.maximum(raw, 1.0)
    raw = np.maximum(raw, float(min_budget_blocks))
    # Causal clamp: row i of an aligned block grid has i+1 admissible blocks
    # (diagonal included). If the key grid is longer (cross attention /
    # decode), all key blocks are admissible.
    offset = n_key_blocks - n_query_blocks
    admissible = np.minimum(i + 1 + offset, n_key_blocks)
    return np.minimum(raw, admissible).astype(np.int32)


def uniform_budget_blocks(nq: int, nk: int, k_uni: int) -> np.ndarray:
    """Constant per-row budget, causally clamped (baseline schedules)."""
    offset = nk - nq
    admissible = np.minimum(np.arange(nq, dtype=np.int64) + 1 + offset, nk)
    return np.minimum(np.full((nq,), k_uni, np.int64), admissible).astype(np.int32)


def dense_budget_blocks(nq: int, nk: int) -> np.ndarray:
    """Every causally admissible block: budgets[i] = min(i+1+offset, nk)."""
    offset = nk - nq
    return np.minimum(np.arange(nq, dtype=np.int64) + 1 + offset, nk).astype(np.int32)


def sink_local_budget_blocks(nq: int, nk: int, sink: int, local: int) -> np.ndarray:
    """StreamingLLM budget: per-row count of the forced sink + local blocks
    (within causal admissibility) — mirrors ``selection.forced_block_mask``."""
    offset = nk - nq
    i = np.arange(nq, dtype=np.int64)[:, None]
    j = np.arange(nk, dtype=np.int64)[None, :]
    diag = i + offset
    forced = ((j < sink) | ((j > diag - local) & (j <= diag))) & (j <= diag)
    return forced.sum(axis=-1).astype(np.int32)


def apply_sparse_segment(budgets: np.ndarray, nq: int, nk: int,
                         sparse_segment) -> np.ndarray:
    """Fig. 3 analysis overlay: sparsify only rows in [lo*nq, hi*nq); all
    other rows keep their full causal budgets.  ``sparse_segment=None`` is
    a no-op.  Shared by ``schedule_for`` and the TPD policy schedule."""
    if sparse_segment is None:
        return budgets
    lo, hi = sparse_segment
    full = dense_budget_blocks(nq, nk)
    sel = np.zeros(nq, bool)
    sel[int(lo * nq): int(hi * nq)] = True
    return np.where(sel, budgets, full).astype(np.int32)


def schedule_for(cfg: StemConfig, seq_len: int, kv_len: int | None = None) -> np.ndarray:
    """Convenience: block-level schedule for a config + sequence length."""
    kv_len = seq_len if kv_len is None else kv_len
    nq = -(-seq_len // cfg.block_size)
    nk = -(-kv_len // cfg.block_size)
    budgets = tpd_budget_blocks(
        nq,
        nk,
        cfg.k_start_blocks(kv_len),
        cfg.mu,
        min_budget_blocks=cfg.min_budget_blocks,
    )
    return apply_sparse_segment(budgets, nq, nk, cfg.sparse_segment)


def max_budget_blocks(cfg: StemConfig, seq_len: int, kv_len: int | None = None) -> int:
    """Static upper bound on the per-row block budget (kernel K_max)."""
    return int(schedule_for(cfg, seq_len, kv_len).max())


# ---------------------------------------------------------------------------
# Analytic cost model (Eq. 2 / Eq. 4) and measured cost.
# ---------------------------------------------------------------------------

def cost_uniform(seq_len: int, k_uni: int) -> float:
    """Eq. (2): C_uni ~= N * k_uni - k_uni^2 / 2 (token pairs computed)."""
    return seq_len * k_uni - 0.5 * k_uni * k_uni


def cost_decay(seq_len: int, k_start: int, mu: float) -> float:
    """Eq. (4): uniform baseline at k_start minus the decay savings."""
    uniform = seq_len * k_start - 0.5 * k_start * k_start
    savings = 0.5 * k_start * (1.0 - mu) * (seq_len - k_start)
    return uniform - savings


def measured_cost_tokens(seq_len: int, k_start: int, mu: float) -> int:
    """Exact computed-pair count of the token-level schedule (causally
    clamped): sum_i min(k(i), i+1). Used to validate Eq. (4)."""
    k = tpd_budget_tokens(seq_len, k_start, mu).astype(np.int64)
    avail = np.arange(1, seq_len + 1, dtype=np.int64)
    return int(np.minimum(k, avail).sum())


def measured_cost_blocks(budgets: np.ndarray, block_size: int) -> int:
    """Computed token pairs implied by a block-level schedule."""
    return int(budgets.astype(np.int64).sum()) * block_size * block_size


def average_budget(budgets: np.ndarray) -> float:
    """k_avg of Eq. (8) in block units."""
    return float(np.mean(budgets))


def budgets_as_jax(budgets: np.ndarray) -> jnp.ndarray:
    return jnp.asarray(budgets, dtype=jnp.int32)
