"""Composable sparsity policies: metric x schedule x selector (+ executor).

The paper's pitch is that Stem is *plug-and-play*, and the baselines it
compares against (uniform top-k, StreamingLLM sink+local, XAttention
threshold selection) differ from Stem along exactly three independent
axes.  This module makes those axes first-class so a policy is declared
once and runs on **all three execution paths** — prefill
(``core/sparse_attention.sparse_attention``), fixed-batch decode
(``core/decode.py``) and paged serving (``runtime/paged.py``):

  * ``BlockMetric``     — how key blocks are scored per query row
                          (``oam``, ``sam``/``xattention`` routing-only,
                          ``streaming`` content-free).
  * ``BudgetSchedule``  — how many blocks each query row may keep
                          (``tpd``, ``uniform``, ``dense``,
                          ``sink-local``).  Budgets are static numpy per
                          (policy, shape): they resolve at trace time and
                          drive the ragged execution schedule.
  * ``Selector``        — how scores + budgets become a block set
                          (``topk`` with forced sink/local floors,
                          ``cumulative-mass`` threshold).

``SparsityPolicy`` composes the three with the execution knobs
(block_size, GQA group_reduce, executor, ragged schedule).  Policies are
frozen dataclasses — hashable, so they ride through ``jax.jit`` as static
arguments exactly like ``StemConfig`` used to.

Registries map declarative names to instances so configs and CLIs can say
``--policy stem`` / ``--policy streaming``:

  * ``register_policy`` / ``get_policy`` / ``available_policies``
  * ``register_metric`` / ``register_schedule`` / ``register_selector``
  * ``register_executor`` / ``get_executor`` — execution backends
    (``xla`` / ``pallas`` / ``dense``), registered by
    ``core/sparse_attention.py``.

``as_policy`` accepts a ``SparsityPolicy``, a registered name, or a legacy
``StemConfig`` (converted via ``policy_from_config``) — every historical
call site keeps working through that shim.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Protocol, Union, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metric as metric_lib
from repro.core import schedule as schedule_lib
from repro.core import selection as selection_lib
from repro.core.config import (StemConfig, k_start_blocks_for,
                               uniform_equivalent_budget,
                               validate_sparse_segment)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Protocols (structural contracts; see DESIGN.md §Policy architecture)
# ---------------------------------------------------------------------------

@runtime_checkable
class BlockMetric(Protocol):
    """Scores key blocks per query row; higher = more important."""

    def prefill_scores(self, q, k, v, *, block_size: int) -> jnp.ndarray:
        """(b, hq, sq, d) x (b, hk, sk, d) -> (b, hq, nq, nk)."""
        ...

    def decode_scores(self, q, k_groups, v_mag) -> jnp.ndarray:
        """One decode query vs pooled cache-block summaries.

        q: (b, hq, 1, d); k_groups: (b, hk, n, stride, d); v_mag: (b, hk, n).
        Returns (b, hk, group, n) float32.
        """
        ...

    def chunk_scores(self, q, k_groups, v_mag, *, block_size: int) -> jnp.ndarray:
        """A chunk of queries vs pooled cache-block summaries (chunked
        prefill, ``core/chunked.py``).  Must reproduce ``prefill_scores`` on
        full key blocks so chunked selection matches one-shot prefill.

        q: (b, hq, C, d) with C % block_size == 0; k_groups / v_mag as in
        ``decode_scores``.  Returns (b, hq, nc, n).
        """
        ...


@runtime_checkable
class BudgetSchedule(Protocol):
    """Per-query-row block budgets (static for prefill, per-row for decode)."""

    def prefill_budgets(self, nq: int, nk: int, *, block_size: int,
                        kv_len: int) -> np.ndarray:
        """Static int32 numpy budgets of shape (nq,), causally clamped."""
        ...

    def decode_budgets(self, n_valid, n_forced, budget_frac: float):
        """(b,) int32 budgets for one decode step (n_valid/n_forced: (b,))."""
        ...

    def decode_budget_bound(self, nblk: int, forced_bound: int,
                            budget_frac: float) -> int:
        """Static top-k width: upper bound on any row's decode budget."""
        ...


@runtime_checkable
class Selector(Protocol):
    """Turns (metric, budgets) into a concrete block selection."""

    budget_driven: bool  # True: k_max = max budget; False: threshold, k_max = nk

    def select(self, metric, budgets, k_max: int, *,
               with_block_mask: bool) -> selection_lib.BlockSelection:
        ...

    def select_decode(self, metric, cache_lens, *, block_size: int,
                      schedule: BudgetSchedule,
                      budget_frac: float) -> selection_lib.DecodeSelection:
        ...


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OutputAwareMetric:
    """Eq. (7): pooled routing scores + beta * max(0, maxpool log ||V||)."""

    beta: float = 0.2
    pooling: str = "antidiag"
    stride: int = 16

    def prefill_scores(self, q, k, v, *, block_size: int) -> jnp.ndarray:
        return metric_lib.oam_scores(
            q, k, v, block_size=block_size, stride=self.stride,
            pooling=self.pooling, beta=self.beta)

    def decode_scores(self, q, k_groups, v_mag) -> jnp.ndarray:
        route = metric_lib.decode_routing_scores(q, k_groups)
        if self.beta == 0.0:
            return route
        return route + self.beta * jnp.maximum(v_mag, 0.0)[:, :, None, :]

    def chunk_scores(self, q, k_groups, v_mag, *, block_size: int) -> jnp.ndarray:
        route = metric_lib.chunk_routing_scores(
            q, k_groups, block_size=block_size, pooling=self.pooling)
        if self.beta == 0.0:
            return route
        group = q.shape[1] // k_groups.shape[1]
        mv = jnp.repeat(v_mag, group, axis=1)              # (b, hq, n)
        return route + self.beta * jnp.maximum(mv, 0.0).astype(
            route.dtype)[..., None, :]


@dataclasses.dataclass(frozen=True)
class RoutingMetric:
    """Routing-only scores (the paper's SAM ablation; also XAttention's
    anti-diagonal block scores) — no value-magnitude term."""

    pooling: str = "antidiag"
    stride: int = 16

    def prefill_scores(self, q, k, v, *, block_size: int) -> jnp.ndarray:
        return metric_lib.blockwise_routing_scores(
            q, k, block_size=block_size, stride=self.stride,
            pooling=self.pooling)

    def decode_scores(self, q, k_groups, v_mag) -> jnp.ndarray:
        return metric_lib.decode_routing_scores(q, k_groups)

    def chunk_scores(self, q, k_groups, v_mag, *, block_size: int) -> jnp.ndarray:
        return metric_lib.chunk_routing_scores(
            q, k_groups, block_size=block_size, pooling=self.pooling)


@dataclasses.dataclass(frozen=True)
class StreamingMetric:
    """Content-free zero metric: selection is driven entirely by the forced
    sink/local floors and the budget schedule (StreamingLLM)."""

    def prefill_scores(self, q, k, v, *, block_size: int) -> jnp.ndarray:
        b, hq, sq, _ = q.shape
        nq, nk = sq // block_size, k.shape[2] // block_size
        return jnp.zeros((b, hq, nq, nk), jnp.float32)

    def decode_scores(self, q, k_groups, v_mag) -> jnp.ndarray:
        b, hq = q.shape[0], q.shape[1]
        hk, n = k_groups.shape[1], k_groups.shape[2]
        return jnp.zeros((b, hk, hq // hk, n), jnp.float32)

    def chunk_scores(self, q, k_groups, v_mag, *, block_size: int) -> jnp.ndarray:
        b, hq, c, _ = q.shape
        n = k_groups.shape[2]
        return jnp.zeros((b, hq, c // block_size, n), jnp.float32)


# ---------------------------------------------------------------------------
# Budget schedules
# ---------------------------------------------------------------------------

def _validate_fractional(mu: float, min_budget_blocks: int) -> None:
    if not (0.0 < mu <= 1.0):
        raise ValueError(f"mu must be in (0, 1], got {mu}")
    if min_budget_blocks < 0:
        raise ValueError(f"min_budget_blocks must be >= 0, got {min_budget_blocks}")


def _validate_sink_local(sink_blocks: int, local_blocks: int) -> None:
    if sink_blocks < 0 or local_blocks < 0:
        raise ValueError(
            f"sink/local blocks must be >= 0, got ({sink_blocks}, {local_blocks})")


def _fractional_decode_budgets(min_budget_blocks: int, n_valid, n_forced,
                               budget_frac: float):
    """Decode budget rule shared by the budget-driven schedules: a fixed
    fraction of the valid cache blocks, floored at min_budget and at the
    forced sink/local count."""
    return jnp.maximum(
        jnp.maximum(jnp.int32(min_budget_blocks), n_forced),
        (n_valid * budget_frac).astype(jnp.int32))


def _fractional_decode_bound(min_budget_blocks: int, nblk: int,
                             forced_bound: int, budget_frac: float) -> int:
    """Static upper bound on _fractional_decode_budgets — the decode top-k
    width the executors allocate."""
    k_max = min(nblk, int(np.ceil(nblk * budget_frac))
                + min_budget_blocks + forced_bound)
    return max(k_max, 1)


@dataclasses.dataclass(frozen=True)
class TPDSchedule:
    """Token Position-Decay (Eq. 3): linear decay k_start -> mu * k_start."""

    k_start_frac: Optional[float] = None
    mu: float = 0.7
    min_budget_blocks: int = 54
    # Fig. 3 analysis mode: only rows in [lo*N, hi*N) are sparsified.
    sparse_segment: Optional[tuple] = None

    def __post_init__(self) -> None:
        _validate_fractional(self.mu, self.min_budget_blocks)
        validate_sparse_segment(self.sparse_segment)

    def prefill_budgets(self, nq: int, nk: int, *, block_size: int,
                        kv_len: int) -> np.ndarray:
        budgets = schedule_lib.tpd_budget_blocks(
            nq, nk, k_start_blocks_for(self.k_start_frac, kv_len, block_size),
            self.mu, min_budget_blocks=self.min_budget_blocks)
        return schedule_lib.apply_sparse_segment(budgets, nq, nk,
                                                 self.sparse_segment)

    def decode_budgets(self, n_valid, n_forced, budget_frac: float):
        return _fractional_decode_budgets(self.min_budget_blocks, n_valid,
                                          n_forced, budget_frac)

    def decode_budget_bound(self, nblk: int, forced_bound: int,
                            budget_frac: float) -> int:
        return _fractional_decode_bound(self.min_budget_blocks, nblk,
                                        forced_bound, budget_frac)


@dataclasses.dataclass(frozen=True)
class UniformSchedule:
    """Constant per-row budget, causally clamped.

    ``k_blocks=None`` selects the budget-matched uniform equivalent of the
    TPD schedule (paper Table 5): k_uni = k_start (1+mu)/2, floored at
    ``min(min_budget_blocks, nk)``.
    """

    k_blocks: Optional[int] = None
    k_start_frac: Optional[float] = None
    mu: float = 0.7
    min_budget_blocks: int = 54

    def __post_init__(self) -> None:
        _validate_fractional(self.mu, self.min_budget_blocks)
        if self.k_blocks is not None and self.k_blocks < 1:
            raise ValueError(f"k_blocks must be >= 1, got {self.k_blocks}")

    def _k_uni(self, nk: int, block_size: int, kv_len: int) -> int:
        if self.k_blocks is not None:
            return self.k_blocks
        k_start = k_start_blocks_for(self.k_start_frac, kv_len, block_size)
        k_uni = uniform_equivalent_budget(k_start, self.mu)
        return max(k_uni, min(self.min_budget_blocks, nk))

    def prefill_budgets(self, nq: int, nk: int, *, block_size: int,
                        kv_len: int) -> np.ndarray:
        return schedule_lib.uniform_budget_blocks(
            nq, nk, self._k_uni(nk, block_size, kv_len))

    def decode_budgets(self, n_valid, n_forced, budget_frac: float):
        return _fractional_decode_budgets(self.min_budget_blocks, n_valid,
                                          n_forced, budget_frac)

    def decode_budget_bound(self, nblk: int, forced_bound: int,
                            budget_frac: float) -> int:
        return _fractional_decode_bound(self.min_budget_blocks, nblk,
                                        forced_bound, budget_frac)


@dataclasses.dataclass(frozen=True)
class DenseSchedule:
    """Every causally admissible block — with the top-k selector this
    reproduces dense attention through the sparse executors (oracle arm);
    with the cumulative-mass selector it leaves budgeting to the threshold."""

    def prefill_budgets(self, nq: int, nk: int, *, block_size: int,
                        kv_len: int) -> np.ndarray:
        return schedule_lib.dense_budget_blocks(nq, nk)

    def decode_budgets(self, n_valid, n_forced, budget_frac: float):
        return jnp.asarray(n_valid, jnp.int32)

    def decode_budget_bound(self, nblk: int, forced_bound: int,
                            budget_frac: float) -> int:
        return max(nblk, 1)


@dataclasses.dataclass(frozen=True)
class SinkLocalSchedule:
    """StreamingLLM budget: exactly the forced sink + local blocks per row.
    Must agree with the selector's sink/local floors."""

    sink_blocks: int = 4
    local_blocks: int = 4

    def __post_init__(self) -> None:
        _validate_sink_local(self.sink_blocks, self.local_blocks)
        if self.sink_blocks + self.local_blocks < 1:
            raise ValueError("sink-local schedule needs sink + local >= 1")

    def prefill_budgets(self, nq: int, nk: int, *, block_size: int,
                        kv_len: int) -> np.ndarray:
        return schedule_lib.sink_local_budget_blocks(
            nq, nk, self.sink_blocks, self.local_blocks)

    def decode_budgets(self, n_valid, n_forced, budget_frac: float):
        return jnp.asarray(n_forced, jnp.int32)

    def decode_budget_bound(self, nblk: int, forced_bound: int,
                            budget_frac: float) -> int:
        return max(1, min(nblk, forced_bound))


# ---------------------------------------------------------------------------
# Selectors
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TopKSelector:
    """Top-k(i) over the metric with forced sink/local floors
    (``selection.select_blocks``); the decode path is the vectorized
    per-row variant shared by the contiguous and paged caches."""

    sink_blocks: int = 4
    local_blocks: int = 4
    budget_driven = True

    def __post_init__(self) -> None:
        _validate_sink_local(self.sink_blocks, self.local_blocks)

    def select(self, metric, budgets, k_max: int, *,
               with_block_mask: bool) -> selection_lib.BlockSelection:
        return selection_lib.select_blocks(
            metric, budgets, k_max,
            sink_blocks=self.sink_blocks, local_blocks=self.local_blocks,
            with_block_mask=with_block_mask)

    def select_decode(self, m, cache_lens, *, block_size: int,
                      schedule: BudgetSchedule,
                      budget_frac: float) -> selection_lib.DecodeSelection:
        """Per-row budget + validity + forced floors, static-width top-k.

        m: (b, hk, g, nblk) coarse metric; cache_lens scalar or (b,).
        """
        b, _, _, nblk = m.shape
        bs = block_size
        cache_lens = jnp.broadcast_to(jnp.asarray(cache_lens, jnp.int32), (b,))

        n_valid = (cache_lens + bs - 1) // bs                        # (b,)
        # Forced sink/local floors ride on top of the budget: the per-row
        # union of sink + local blocks is min(n_valid, sink + local) wide,
        # and every forced block stays live regardless of budget_frac.
        n_forced = jnp.minimum(
            n_valid, jnp.int32(self.sink_blocks + self.local_blocks))
        k_budget = schedule.decode_budgets(n_valid, n_forced, budget_frac)
        blk = jnp.arange(nblk)
        is_valid = blk[None, :] < n_valid[:, None]                   # (b, n)
        is_sink = blk < self.sink_blocks                             # (n,)
        is_local = (blk[None, :] >= n_valid[:, None] - self.local_blocks) & is_valid
        forced = (is_sink[None, :] | is_local)[:, None, None, :]     # (b,1,1,n)
        biased = jnp.where(forced, m + selection_lib.FORCE_BONUS, m)
        biased = jnp.where(is_valid[:, None, None, :], biased, NEG_INF)

        k_max = schedule.decode_budget_bound(
            nblk, self.sink_blocks + self.local_blocks, budget_frac)
        vals, idx = jax.lax.top_k(biased, k_max)                # (b,hk,g,kmax)
        live = (vals > NEG_INF / 2) & (
            jnp.arange(k_max)[None, None, None, :] < k_budget[:, None, None, None])
        return selection_lib.DecodeSelection(
            indices=idx.astype(jnp.int32), live=live,
            budgets=k_budget, n_valid=n_valid)


def _cumulative_mass_keep(probs: jnp.ndarray, tau: float) -> jnp.ndarray:
    """Keep mask over the last axis: a block is kept iff the cumulative
    (descending-sorted) probability mass *before* it is < tau — the
    smallest prefix reaching tau, scattered back to block ids."""
    order = jnp.argsort(-probs, axis=-1)
    sorted_p = jnp.take_along_axis(probs, order, axis=-1)
    cum = jnp.cumsum(sorted_p, axis=-1)
    keep_sorted = (cum - sorted_p) < tau
    onehot = jax.nn.one_hot(order, probs.shape[-1], dtype=jnp.bool_)
    return jnp.any(onehot & keep_sorted[..., None], axis=-2)


@dataclasses.dataclass(frozen=True)
class CumulativeMassSelector:
    """XAttention-style: per-row softmax over the (causal) metric, keep the
    smallest prefix of blocks whose cumulative mass reaches ``tau``;
    sink/local blocks are forced for stability.  Budget-free — the schedule
    only matters for rows the threshold leaves empty (never, since forced
    floors exist), so pair it with ``DenseSchedule``."""

    tau: float = 0.9
    sink_blocks: int = 4
    local_blocks: int = 4
    budget_driven = False

    def __post_init__(self) -> None:
        _validate_sink_local(self.sink_blocks, self.local_blocks)
        if not (0.0 < self.tau <= 1.0):
            raise ValueError(f"tau must be in (0, 1], got {self.tau}")

    def select(self, metric, budgets, k_max: int, *,
               with_block_mask: bool) -> selection_lib.BlockSelection:
        nq, nk = metric.shape[-2], metric.shape[-1]
        causal = selection_lib.causal_block_mask(nq, nk)
        m = jnp.where(causal, metric, NEG_INF)
        probs = jax.nn.softmax(m, axis=-1)
        block_mask = _cumulative_mass_keep(probs, self.tau) & causal
        forced = selection_lib.forced_block_mask(
            nq, nk, self.sink_blocks, self.local_blocks)
        block_mask = block_mask | (forced & causal)
        score = jnp.where(block_mask, probs + 1.0, NEG_INF)
        vals, idx = jax.lax.top_k(score, int(nk))
        slot_mask = vals > NEG_INF / 2
        indices = jnp.where(slot_mask, idx, 0).astype(jnp.int32)
        row_budgets = jnp.max(block_mask.sum(axis=-1), axis=(0, 1)).astype(jnp.int32)
        return selection_lib.BlockSelection(
            indices=indices, slot_mask=slot_mask,
            block_mask=block_mask if with_block_mask else None,
            budgets=row_budgets,
            live_counts=slot_mask.sum(axis=-1, dtype=jnp.int32))

    def select_decode(self, m, cache_lens, *, block_size: int,
                      schedule: BudgetSchedule,
                      budget_frac: float) -> selection_lib.DecodeSelection:
        """Threshold selection over cache blocks (k_max = nblk: the gather
        stays O(L) — threshold decode trades the static bound for
        budget-free selection)."""
        b, _, _, nblk = m.shape
        bs = block_size
        cache_lens = jnp.broadcast_to(jnp.asarray(cache_lens, jnp.int32), (b,))
        n_valid = (cache_lens + bs - 1) // bs
        blk = jnp.arange(nblk)
        is_valid = blk[None, :] < n_valid[:, None]
        is_sink = blk < self.sink_blocks
        is_local = (blk[None, :] >= n_valid[:, None] - self.local_blocks) & is_valid
        forced = (is_sink[None, :] | is_local)[:, None, None, :]

        mm = jnp.where(is_valid[:, None, None, :], m, NEG_INF)
        probs = jax.nn.softmax(mm, axis=-1)
        keep = _cumulative_mass_keep(probs, self.tau)
        keep = (keep | forced) & is_valid[:, None, None, :]
        score = jnp.where(keep, probs + 1.0, NEG_INF)
        vals, idx = jax.lax.top_k(score, int(nblk))
        live = vals > NEG_INF / 2
        row_budgets = keep.sum(axis=-1).max(axis=(1, 2)).astype(jnp.int32)
        return selection_lib.DecodeSelection(
            indices=idx.astype(jnp.int32), live=live,
            budgets=row_budgets, n_valid=n_valid)


# ---------------------------------------------------------------------------
# The composed policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SparsityPolicy:
    """Metric x schedule x selector + execution knobs.  Frozen/hashable —
    rides through jit as a static argument; equal policies share traces.

    One instance drives all three execution paths:
      * prefill — ``sparse_attention(q, k, v, policy)`` (core/sparse_attention);
      * fixed-batch decode — ``core.decode.sparse_decode_attention``;
      * paged serving — ``runtime.paged.paged_sparse_decode`` and the
        continuous-batching engine.
    """

    metric: Any
    schedule: Any
    selector: Any
    block_size: int = 128
    group_reduce: str = "none"     # "none" | "mean" | "max" (GQA sharing)
    executor: str = "xla"          # default execution backend (registry name)
    slot_chunk: int = 8
    ragged: bool = True
    name: str = ""

    def __post_init__(self) -> None:
        # Same construction-time invariants StemConfig enforces — a bad
        # composition must fail here with a clear message, not deep inside
        # jit tracing.  (The executor name is validated lazily at dispatch:
        # executors register after this module's built-in policies exist.)
        if self.block_size <= 0 or self.block_size % 8 != 0:
            raise ValueError(
                f"block_size must be a positive multiple of 8, got {self.block_size}")
        stride = self.stride
        if stride <= 0 or self.block_size % stride != 0:
            raise ValueError(
                f"metric stride {stride} must divide block_size {self.block_size}")
        if self.group_reduce not in ("none", "mean", "max"):
            raise ValueError(f"unknown group_reduce {self.group_reduce!r}")
        if self.slot_chunk < 1:
            raise ValueError(f"slot_chunk must be >= 1, got {self.slot_chunk}")

    # -- derived attributes the cache/pool machinery needs ------------------

    @property
    def stride(self) -> int:
        """Anti-diagonal pooling stride of the metric (1 for content-free
        metrics) — sizes the per-block K group-mean summaries."""
        return getattr(self.metric, "stride", 1)

    @property
    def sink_blocks(self) -> int:
        return getattr(self.selector, "sink_blocks", 0)

    @property
    def local_blocks(self) -> int:
        return getattr(self.selector, "local_blocks", 0)

    # -- prefill ------------------------------------------------------------

    def prefill_budgets(self, seq_len: int, kv_len: Optional[int] = None) -> np.ndarray:
        """Static numpy (nq,) budgets — resolves at trace time."""
        kv_len = seq_len if kv_len is None else kv_len
        nq = -(-seq_len // self.block_size)
        nk = -(-kv_len // self.block_size)
        return self.schedule.prefill_budgets(
            nq, nk, block_size=self.block_size, kv_len=kv_len)

    def prefill_scores(self, q, k, v) -> jnp.ndarray:
        m = self.metric.prefill_scores(q, k, v, block_size=self.block_size)
        group = q.shape[1] // k.shape[1]
        return metric_lib.group_reduce_metric(m, group, self.group_reduce)

    def prefill_select(self, q, k, v, *, with_block_mask: bool = True):
        """Phase 1 of Algorithm 1: metric + schedule + selection.

        Returns (BlockSelection, k_max).
        """
        sq, sk = q.shape[2], k.shape[2]
        m = self.prefill_scores(q, k, v)
        budgets = self.prefill_budgets(sq, sk)
        nk = sk // self.block_size
        k_max = int(budgets.max()) if self.selector.budget_driven else int(nk)
        sel = self.selector.select(
            m, schedule_lib.budgets_as_jax(budgets), k_max,
            with_block_mask=with_block_mask)
        return sel, k_max

    # -- chunked prefill (core/chunked.py) -----------------------------------

    def chunk_scores(self, q, k_groups, v_mag) -> jnp.ndarray:
        """Chunk-of-queries metric against pooled page summaries, with the
        policy's GQA group reduction applied — the chunked-prefill analogue
        of ``prefill_scores``.  Returns (b, hq, nc, n)."""
        fn = getattr(self.metric, "chunk_scores", None)
        if fn is None:
            raise NotImplementedError(
                f"metric {type(self.metric).__name__} does not implement "
                "chunk_scores(q, k_groups, v_mag, block_size=...) — required "
                "for chunked prefill (core/chunked.py)")
        m = fn(q, k_groups, v_mag, block_size=self.block_size)
        group = q.shape[1] // k_groups.shape[1]
        return metric_lib.group_reduce_metric(m, group, self.group_reduce)

    # -- decode (contiguous and paged caches share these) --------------------

    def decode_scores(self, q, k_groups, v_mag) -> jnp.ndarray:
        return self.metric.decode_scores(q, k_groups, v_mag)

    def decode_select(self, m, cache_lens, *,
                      budget_frac: float = 0.25) -> selection_lib.DecodeSelection:
        return self.selector.select_decode(
            m, cache_lens, block_size=self.block_size,
            schedule=self.schedule, budget_frac=budget_frac)

    def decode_budget_bound(self, nblk: int, budget_frac: float) -> int:
        """Static decode top-k width (the gather allocation)."""
        if not self.selector.budget_driven:
            return max(nblk, 1)
        return self.schedule.decode_budget_bound(
            nblk, self.sink_blocks + self.local_blocks, budget_frac)

    # -- ergonomics ----------------------------------------------------------

    def with_updates(self, *, ignore_missing: bool = False,
                     **kw) -> "SparsityPolicy":
        """Copy with knobs rewritten, routing each key to every component
        (policy / metric / schedule / selector) that defines a field of
        that name — e.g. ``sink_blocks`` updates both the top-k selector
        and a sink-local schedule so they stay consistent.  The final
        object is built in one step so cross-component invariants (stride
        vs block_size) are validated against the *combined* update, not an
        intermediate.  Unknown keys raise unless ``ignore_missing`` (CLIs
        rescaling heterogeneous policies pass True)."""
        top_fields = {f.name for f in dataclasses.fields(self)}
        top = {k: v for k, v in kw.items() if k in top_fields}
        known = set(top)
        final = dict(top)
        for comp_name in ("metric", "schedule", "selector"):
            comp = top.get(comp_name, getattr(self, comp_name))
            fields = {f.name for f in dataclasses.fields(comp)}
            known |= fields
            sub = {k: v for k, v in kw.items() if k in fields}
            if sub:
                final[comp_name] = dataclasses.replace(comp, **sub)
        if not ignore_missing:
            unknown = set(kw) - known
            if unknown:
                raise ValueError(
                    f"with_updates: no component defines {sorted(unknown)}")
        return dataclasses.replace(self, **final) if final else self


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------

_METRICS: dict = {}
_SCHEDULES: dict = {}
_SELECTORS: dict = {}
_POLICIES: dict = {}


def _register(table: dict, kind: str, name: str, obj, overwrite: bool):
    if not overwrite and name in table:
        raise ValueError(f"{kind} {name!r} already registered")
    table[name] = obj
    return obj


def _lookup(table: dict, kind: str, name: str):
    try:
        return table[name]
    except KeyError:
        raise KeyError(
            f"unknown {kind} {name!r}; registered: {sorted(table)}") from None


def register_metric(name: str, m, *, overwrite: bool = False):
    return _register(_METRICS, "metric", name, m, overwrite)


def get_metric(name: str):
    return _lookup(_METRICS, "metric", name)


def register_schedule(name: str, s, *, overwrite: bool = False):
    return _register(_SCHEDULES, "schedule", name, s, overwrite)


def get_schedule(name: str):
    return _lookup(_SCHEDULES, "schedule", name)


def register_selector(name: str, s, *, overwrite: bool = False):
    return _register(_SELECTORS, "selector", name, s, overwrite)


def get_selector(name: str):
    return _lookup(_SELECTORS, "selector", name)


def register_policy(name: str, policy: SparsityPolicy, *,
                    overwrite: bool = False) -> SparsityPolicy:
    if not policy.name:
        policy = dataclasses.replace(policy, name=name)
    return _register(_POLICIES, "policy", name, policy, overwrite)


def get_policy(name: str) -> SparsityPolicy:
    return _lookup(_POLICIES, "policy", name)


def available_policies() -> tuple:
    return tuple(sorted(_POLICIES))


@functools.lru_cache(maxsize=None)
def policy_from_config(cfg: StemConfig) -> SparsityPolicy:
    """Equivalent policy of a legacy flag record (the ``cfg.policy()``
    shim).  ``metric="sam"`` maps to the routing-only metric on *both*
    phases (prefill parity is exact; decode historically always added the
    value term — routing-only decode is the corrected SAM semantics)."""
    if cfg.metric == "oam":
        m: Any = OutputAwareMetric(beta=cfg.beta, pooling=cfg.pooling,
                                   stride=cfg.stride)
    else:
        m = RoutingMetric(pooling=cfg.pooling, stride=cfg.stride)
    return SparsityPolicy(
        metric=m,
        schedule=TPDSchedule(
            k_start_frac=cfg.k_start_frac, mu=cfg.mu,
            min_budget_blocks=cfg.min_budget_blocks,
            sparse_segment=cfg.sparse_segment),
        selector=TopKSelector(sink_blocks=cfg.sink_blocks,
                              local_blocks=cfg.local_blocks),
        block_size=cfg.block_size, group_reduce=cfg.group_reduce,
        executor=cfg.backend, slot_chunk=cfg.slot_chunk, ragged=cfg.ragged,
        name="stem" if cfg.metric == "oam" else "stem-sam")


PolicyLike = Union[SparsityPolicy, StemConfig, str]


def as_policy(obj: PolicyLike) -> SparsityPolicy:
    """Normalize a policy spelling: instance | registered name | StemConfig."""
    if isinstance(obj, SparsityPolicy):
        return obj
    if isinstance(obj, StemConfig):
        return policy_from_config(obj)
    if isinstance(obj, str):
        return get_policy(obj)
    raise TypeError(f"cannot interpret {type(obj).__name__} as a SparsityPolicy")


def as_policy_opt(obj: Optional[PolicyLike]) -> Optional[SparsityPolicy]:
    return None if obj is None else as_policy(obj)


# ---------------------------------------------------------------------------
# Executor registry (backends registered by core/sparse_attention.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecutorSpec:
    """One execution backend for a block selection.

    ``fn(q, k, v, sel, *, policy, scale, indices, slot_mask, live_counts,
    dedup, budgets)`` — ``indices``/``slot_mask``/``live_counts`` are the
    (possibly GQA-deduplicated) views of ``sel``; ``budgets`` is the static
    numpy schedule (None = padded execution / threshold selection)."""

    fn: Callable
    needs_block_mask: bool = False


_EXECUTORS: dict = {}


def register_executor(name: str, fn: Callable, *,
                      needs_block_mask: bool = False,
                      overwrite: bool = False) -> ExecutorSpec:
    return _register(_EXECUTORS, "executor", name,
                     ExecutorSpec(fn=fn, needs_block_mask=needs_block_mask),
                     overwrite)


def get_executor(name: str) -> ExecutorSpec:
    return _lookup(_EXECUTORS, "executor", name)


def available_executors() -> tuple:
    return tuple(sorted(_EXECUTORS))


# ---------------------------------------------------------------------------
# Paged executor registry (serving decode + chunk lanes; backends registered
# by runtime/paged.py — "xla", the gather oracle — and kernels/paged_attn.py
# — "pallas", the fused scalar-prefetch kernels)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PagedExecutorSpec:
    """One execution backend for the paged serving attention lanes.

    ``decode_fn(q, pool, page_table, cache_lens, policy, budget_frac)``
    mirrors ``runtime.paged.paged_sparse_decode``;
    ``chunk_fn(q, pool, page_table, chunk_start, budgets, policy, k_max)``
    mirrors ``core.chunked.chunked_prefill_attention``.  Both return the
    attention output and must be selection-identical to the "xla" oracle
    (the differential suite in tests/test_paged_kernel.py pins this).

    ``sharding`` declares the backend's tensor-parallel contract for
    mesh-sharded serving (``sharding/serving.py``): "kv-head" means both
    lanes are per-KV-head independent — they read head count from the pool
    shapes and never reduce across heads — so a shard-local pool slice plus
    sliced q/k/v is bitwise equivalent to the full run restricted to those
    heads.  "replicated" marks a backend that must see all heads; tp>1
    refuses it at engine construction.
    """

    decode_fn: Callable
    chunk_fn: Callable
    sharding: str = "kv-head"


_PAGED_EXECUTORS: dict = {}


def register_paged_executor(name: str, *, decode_fn: Callable,
                            chunk_fn: Callable, sharding: str = "kv-head",
                            overwrite: bool = False) -> PagedExecutorSpec:
    if sharding not in ("kv-head", "replicated"):
        raise ValueError(f"sharding must be 'kv-head' or 'replicated', "
                         f"got {sharding!r}")
    return _register(_PAGED_EXECUTORS, "paged executor", name,
                     PagedExecutorSpec(decode_fn=decode_fn, chunk_fn=chunk_fn,
                                       sharding=sharding),
                     overwrite)


def get_paged_executor(name: str) -> PagedExecutorSpec:
    """Resolve a paged backend, lazily importing the module that registers
    it.  Prefill-only executor names (a policy's ``executor`` field may name
    e.g. "dense", which only exists for the monolithic prefill registry)
    fall back to the XLA gather oracle — always correct, never fused."""
    if name not in _PAGED_EXECUTORS:
        if name == "pallas":
            from repro.kernels import paged_attn  # noqa: F401 (registers)
        else:
            from repro.runtime import paged  # noqa: F401 (registers "xla")
    if name in _PAGED_EXECUTORS:
        return _PAGED_EXECUTORS[name]
    if "xla" not in _PAGED_EXECUTORS:
        from repro.runtime import paged  # noqa: F401 (registers "xla")
    return _PAGED_EXECUTORS["xla"]


def available_paged_executors() -> tuple:
    return tuple(sorted(_PAGED_EXECUTORS))


# ---------------------------------------------------------------------------
# Built-in registrations (paper defaults: B=128, mu=0.7, beta=0.2, 4+4
# sink/local, floor 54 — rescale with .with_updates for small shapes)
# ---------------------------------------------------------------------------

register_metric("oam", OutputAwareMetric())
register_metric("sam", RoutingMetric())
register_metric("xattention", RoutingMetric())   # alias: antidiag routing
register_metric("streaming", StreamingMetric())

register_schedule("tpd", TPDSchedule())
register_schedule("uniform", UniformSchedule())
register_schedule("dense", DenseSchedule())
register_schedule("sink-local", SinkLocalSchedule())

register_selector("topk", TopKSelector())
register_selector("cumulative-mass", CumulativeMassSelector())

register_policy("stem", SparsityPolicy(
    metric=OutputAwareMetric(), schedule=TPDSchedule(),
    selector=TopKSelector()))
register_policy("stem-sam", SparsityPolicy(
    metric=RoutingMetric(), schedule=TPDSchedule(),
    selector=TopKSelector()))
register_policy("uniform-sam", SparsityPolicy(
    metric=RoutingMetric(), schedule=UniformSchedule(),
    selector=TopKSelector()))
register_policy("uniform-oam", SparsityPolicy(
    metric=OutputAwareMetric(), schedule=UniformSchedule(),
    selector=TopKSelector()))
register_policy("streaming", SparsityPolicy(
    metric=StreamingMetric(), schedule=SinkLocalSchedule(),
    selector=TopKSelector()))
register_policy("xattention", SparsityPolicy(
    metric=RoutingMetric(), schedule=DenseSchedule(),
    selector=CumulativeMassSelector()))
register_policy("dense", SparsityPolicy(
    metric=StreamingMetric(), schedule=DenseSchedule(),
    selector=TopKSelector(sink_blocks=0, local_blocks=0)))
