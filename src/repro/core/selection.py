"""Block selection: Top-k(i) over the coarse metric with stability floors.

Given the coarse metric (batch, heads, nq, nk) and the TPD block budgets
k(i), this module produces the set of key blocks each query block attends
to.  Following the paper's implementation details we always retain
``sink_blocks`` leading key blocks and ``local_blocks`` diagonal-local
blocks, and respect causal admissibility at block granularity.

Outputs come in two equivalent forms:
  * padded index lists (batch, heads, nq, K_max) + slot validity mask —
    consumed by the gather executor and the Pallas kernel (scalar prefetch);
  * a dense boolean block mask (batch, heads, nq, nk) — consumed by the
    O(N^2) oracle executor and by tests.

Ragged layout (DESIGN.md §Ragged slot layout): live slots always form a
*prefix* of the slot axis — top_k sorts values descending, the budget cut is
a prefix, and inadmissible picks sort last — so a per-row ``live_counts``
scalar fully describes validity.  ``revisit_indices`` re-points dead slots at
the row's last live block so the Pallas pipeline re-uses the already-fetched
K/V tile (zero new DMAs), and ``budget_sorted_segments`` turns the static
TPD budget vector into the segment schedule the ragged XLA executor runs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30
FORCE_BONUS = 1e30


class BlockSelection(NamedTuple):
    """Selected key blocks per query block row.

    indices: (batch, heads, nq, k_max) int32 key-block ids (invalid slots
      point at block 0 but are masked out).
    slot_mask: (batch, heads, nq, k_max) bool — True for live slots.  Live
      slots are always a contiguous prefix (see module docstring).
    block_mask: (batch, heads, nq, nk) bool dense equivalent.
    budgets: (nq,) int32 per-row block budgets actually applied.
    live_counts: (batch, heads, nq) int32 number of live slots per row —
      equals ``slot_mask.sum(-1)``.  The Pallas wrapper scalar-prefetches
      this for its ragged finalize (the XLA executor still needs the mask
      for partial-chunk masking).
    """

    indices: jnp.ndarray
    slot_mask: jnp.ndarray
    block_mask: jnp.ndarray
    budgets: jnp.ndarray
    live_counts: Optional[jnp.ndarray] = None


class DecodeSelection(NamedTuple):
    """Per-row cache-block selection for one decode step (nq = 1).

    indices: (b, hk, g, k_max) int32 *logical* block ids (slot-local order);
      dead slots are masked by ``live``.
    live: (b, hk, g, k_max) bool — slot carries a selected, in-budget,
      valid block.
    budgets: (b,) int32 per-row block budget actually applied (for
      threshold selectors: the per-row max over heads of kept blocks).
    n_valid: (b,) int32 ceil(cache_len / block_size) per row.
    """

    indices: jnp.ndarray
    live: jnp.ndarray
    budgets: jnp.ndarray
    n_valid: jnp.ndarray


class RaggedSegment(NamedTuple):
    """One segment of the budget-sorted ragged execution schedule.

    rows: original query-block row ids, budget-descending; every row in the
      segment needs the same number of slot chunks.
    n_chunks: slot chunks this segment executes (ceil(max budget / chunk)).
    """

    rows: tuple
    n_chunks: int


def causal_block_mask(nq: int, nk: int) -> jnp.ndarray:
    """Admissibility at block level: query block i may see key block j iff
    j <= i + (nk - nq) (aligned causal grids; nk >= nq for decode)."""
    offset = nk - nq
    i = jnp.arange(nq)[:, None]
    j = jnp.arange(nk)[None, :]
    return j <= i + offset


def forced_block_mask(nq: int, nk: int, sink: int, local: int) -> jnp.ndarray:
    """Blocks that are always retained (within causal admissibility):
    the first ``sink`` key blocks and the ``local`` blocks ending at the
    diagonal."""
    offset = nk - nq
    i = jnp.arange(nq)[:, None]
    j = jnp.arange(nk)[None, :]
    is_sink = j < sink
    diag = i + offset
    is_local = (j > diag - local) & (j <= diag)
    return (is_sink | is_local) & causal_block_mask(nq, nk)


def select_blocks(
    metric: jnp.ndarray,
    budgets: jnp.ndarray,
    k_max: int,
    *,
    sink_blocks: int,
    local_blocks: int,
    with_block_mask: bool = True,
) -> BlockSelection:
    """Top-k(i) selection (Algorithm 1, lines 14-17) with forced floors.

    Args:
      metric: (batch, heads, nq, nk) coarse metric (higher = keep).
      budgets: (nq,) int32 per-row budgets in blocks (already causally
        clamped and floored by the schedule).
      k_max: static max(budgets) — the padded slot count.
      with_block_mask: also materialize the dense (b, h, nq, nk) boolean
        mask.  The gather executors only need the index lists; building the
        mask costs a (b, h, nq, k_max, nk) one-hot scatter that GSPMD turns
        into enormous all-reduces at 32k scale, so the production path skips
        it (§Perf glm4 iteration 1: 773 s -> see DESIGN.md §Perf notes).

    Returns:
      BlockSelection (block_mask=None when with_block_mask=False).
    """
    b, h, nq, nk = metric.shape
    budgets = jnp.asarray(budgets, dtype=jnp.int32)

    causal = causal_block_mask(nq, nk)  # (nq, nk)
    forced = forced_block_mask(nq, nk, sink_blocks, local_blocks)

    biased = jnp.where(forced, metric + FORCE_BONUS, metric)
    biased = jnp.where(causal, biased, NEG_INF)

    k_max = int(min(k_max, nk))
    values, indices = jax.lax.top_k(biased, k_max)  # (b, h, nq, k_max)

    slot_rank = jnp.arange(k_max, dtype=jnp.int32)
    within_budget = slot_rank[None, :] < budgets[:, None]  # (nq, k_max)
    live = values > NEG_INF / 2  # excludes causally-inadmissible picks
    slot_mask = live & within_budget[None, None, :, :]

    indices = jnp.where(slot_mask, indices, 0).astype(jnp.int32)
    live_counts = slot_mask.sum(axis=-1, dtype=jnp.int32)

    block_mask = None
    if with_block_mask:
        # Dense equivalent (scatter the slots back) — tests/oracle only.
        onehot = jax.nn.one_hot(indices, nk, dtype=jnp.bool_)
        block_mask = jnp.any(onehot & slot_mask[..., None], axis=-2)

    return BlockSelection(
        indices=indices,
        slot_mask=slot_mask,
        block_mask=block_mask,
        budgets=budgets,
        live_counts=live_counts,
    )


def revisit_indices(indices: jnp.ndarray, slot_mask: jnp.ndarray) -> jnp.ndarray:
    """Re-point dead slots at the row's last live block ("revisit" trick).

    Because live slots form a prefix, every dead slot repeats the index at
    slot ``live_count - 1``; consecutive grid steps over dead slots then map
    to the same K/V block, so the Pallas pipeline skips the DMA entirely
    (splash-attention's revisit optimization).  Rows with zero live slots
    keep pointing at block 0.

    indices/slot_mask: (..., k_max) -> (..., k_max) int32.
    """
    k_max = indices.shape[-1]
    cnt = slot_mask.sum(axis=-1, dtype=jnp.int32)
    slot = jnp.minimum(
        jnp.arange(k_max, dtype=jnp.int32),
        jnp.maximum(cnt[..., None] - 1, 0),
    )
    return jnp.take_along_axis(indices, slot, axis=-1)


def budget_sorted_segments(budgets: np.ndarray, slot_chunk: int) -> tuple:
    """Static ragged execution schedule from the TPD budget vector.

    Rows are sorted by budget (descending, stable) and coalesced into
    segments whose rows all need the same number of ``slot_chunk``-wide
    chunks; the ragged executor runs one scan per segment over exactly
    ``n_chunks`` chunks, so all-dead trailing chunks of low-budget rows are
    never executed.  Pure numpy — budgets are static per (config, shape), so
    this resolves at trace time.

    Returns a tuple of RaggedSegment.
    """
    budgets = np.asarray(budgets)
    chunk = max(1, int(slot_chunk))
    order = np.argsort(-budgets, kind="stable")
    segments: list = []
    for r in order:
        c = max(1, -(-int(budgets[r]) // chunk))
        if segments and segments[-1][1] == c:
            segments[-1][0].append(int(r))
        else:
            segments.append(([int(r)], c))
    return tuple(RaggedSegment(tuple(rows), c) for rows, c in segments)


def block_mask_to_token_mask(
    block_mask: jnp.ndarray, block_q: int, block_k: int, seq_q: int, seq_k: int
) -> jnp.ndarray:
    """Expand a block mask to token granularity, re-applying exact causal
    masking inside diagonal blocks.  (batch, heads, nq, nk) ->
    (batch, heads, seq_q, seq_k).  Oracle/test path only — O(N^2) memory."""
    m = jnp.repeat(jnp.repeat(block_mask, block_q, axis=-2), block_k, axis=-1)
    m = m[..., :seq_q, :seq_k]
    offset = seq_k - seq_q
    qi = jnp.arange(seq_q)[:, None]
    kj = jnp.arange(seq_k)[None, :]
    return m & (kj <= qi + offset)


def selection_density(sel: BlockSelection, nk: int) -> jnp.ndarray:
    """Realized budget: mean fraction of admissible key blocks attended.
    Scalar in [0, 1] — comparable to the paper's BUD column.

    Computed from ``slot_mask`` (selected slots are distinct blocks, so the
    count equals the block-mask popcount) — works on the production path
    where ``with_block_mask=False`` and ``block_mask`` is None."""
    nq = sel.slot_mask.shape[-2]
    admissible = causal_block_mask(nq, nk).sum()
    kept = sel.slot_mask.sum(axis=(-1, -2)).mean()
    return kept / admissible
