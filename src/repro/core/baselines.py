"""Training-free sparse-attention baselines the paper compares against.

All baselines emit a ``BlockSelection`` so they share Stem's executors —
budget accounting and reconstruction-error comparisons are therefore
apples-to-apples:

  * ``uniform_sam``      — uniform Top-k over routing-only scores.  This is
                           the paper's ablation baseline (Table 5, row
                           "Uniform"); with k_uni = k_start (1+mu)/2 it is
                           budget-matched to TPD.
  * ``streaming``        — StreamingLLM-style static sink + local window.
  * ``xattention_like``  — anti-diagonal block scores + per-row softmax +
                           cumulative-mass threshold tau (XAttention's
                           selection rule), converted to a block mask.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import metric as metric_lib
from repro.core import schedule as schedule_lib
from repro.core import selection as selection_lib
from repro.core import sparse_attention as sa
from repro.core.config import StemConfig

NEG_INF = -1e30


def uniform_budgets(nq: int, nk: int, k_uni: int) -> jnp.ndarray:
    """Constant budget, causally clamped."""
    offset = nk - nq
    i = jnp.arange(nq)
    admissible = jnp.minimum(i + 1 + offset, nk)
    return jnp.minimum(jnp.full((nq,), k_uni, jnp.int32), admissible.astype(jnp.int32))


def uniform_sam_selection(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: StemConfig,
    k_uni: Optional[int] = None,
) -> selection_lib.BlockSelection:
    """Uniform Top-k with the Score-Aware Metric (routing only)."""
    sam_cfg = StemConfig(**{**cfg.__dict__, "metric": "sam", "mu": 1.0})
    m = metric_lib.oam_metric(q, k, v, sam_cfg)
    group = q.shape[1] // k.shape[1]
    m = metric_lib.group_reduce_metric(m, group, cfg.group_reduce)
    nq, nk = m.shape[-2], m.shape[-1]
    if k_uni is None:
        from repro.core.config import uniform_equivalent_budget

        k_uni = uniform_equivalent_budget(cfg.k_start_blocks(k.shape[2]), cfg.mu)
        k_uni = max(k_uni, min(cfg.min_budget_blocks, nk))
    budgets = uniform_budgets(nq, nk, k_uni)
    return selection_lib.select_blocks(
        m, budgets, int(min(k_uni, nk)),
        sink_blocks=cfg.sink_blocks, local_blocks=cfg.local_blocks,
    )


def streaming_selection(
    nq: int, nk: int, batch: int, heads: int, sink_blocks: int, local_blocks: int
) -> selection_lib.BlockSelection:
    """StreamingLLM: static sink + sliding window at block granularity."""
    mask2d = selection_lib.forced_block_mask(nq, nk, sink_blocks, local_blocks)
    block_mask = jnp.broadcast_to(mask2d, (batch, heads, nq, nk))
    k_max = sink_blocks + local_blocks
    # Build padded index lists from the static mask.
    score = jnp.where(mask2d, 1.0, NEG_INF)
    _, idx = jax.lax.top_k(score, min(k_max, nk))
    vals = jnp.take_along_axis(score, idx, axis=-1)
    slot2d = vals > NEG_INF / 2
    indices = jnp.broadcast_to(jnp.where(slot2d, idx, 0), (batch, heads) + idx.shape)
    slot_mask = jnp.broadcast_to(slot2d, indices.shape)
    budgets = mask2d.sum(axis=-1).astype(jnp.int32)
    return selection_lib.BlockSelection(
        indices=indices.astype(jnp.int32), slot_mask=slot_mask,
        block_mask=block_mask, budgets=budgets,
    )


def xattention_like_selection(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: StemConfig,
    tau: float = 0.9,
) -> selection_lib.BlockSelection:
    """XAttention-style: softmax the pooled anti-diagonal scores per row and
    keep the smallest prefix of blocks whose cumulative mass reaches tau."""
    sam_cfg = StemConfig(**{**cfg.__dict__, "metric": "sam"})
    m = metric_lib.oam_metric(q, k, v, sam_cfg)  # routing only
    nq, nk = m.shape[-2], m.shape[-1]
    causal = selection_lib.causal_block_mask(nq, nk)
    m = jnp.where(causal, m, NEG_INF)
    probs = jax.nn.softmax(m, axis=-1)
    order = jnp.argsort(-probs, axis=-1)
    sorted_p = jnp.take_along_axis(probs, order, axis=-1)
    cum = jnp.cumsum(sorted_p, axis=-1)
    # Keep a block if the cumulative mass *before* it is < tau.
    keep_sorted = (cum - sorted_p) < tau
    # Scatter the kept prefix back to block ids.
    onehot = jax.nn.one_hot(order, nk, dtype=jnp.bool_)
    block_mask = jnp.any(onehot & keep_sorted[..., None], axis=-2) & causal
    # Force sink + local for stability (as all block methods do).
    forced = selection_lib.forced_block_mask(nq, nk, cfg.sink_blocks, cfg.local_blocks)
    block_mask = block_mask | (forced & causal)
    k_max = int(nk)
    score = jnp.where(block_mask, probs + 1.0, NEG_INF)
    vals, idx = jax.lax.top_k(score, k_max)
    slot_mask = vals > NEG_INF / 2
    indices = jnp.where(slot_mask, idx, 0).astype(jnp.int32)
    budgets = jnp.max(block_mask.sum(axis=-1), axis=(0, 1)).astype(jnp.int32)
    return selection_lib.BlockSelection(
        indices=indices, slot_mask=slot_mask, block_mask=block_mask, budgets=budgets
    )


@functools.partial(jax.jit, static_argnames=("cfg", "method", "k_uni"))
def baseline_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: StemConfig,
    method: str = "uniform_sam",
    k_uni: Optional[int] = None,
):
    """Run a baseline selection through the shared dense-oracle executor.

    Returns (output, realized_density).
    """
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    nq, nk = sq // cfg.block_size, sk // cfg.block_size
    if method == "uniform_sam":
        sel = uniform_sam_selection(q, k, v, cfg, k_uni)
    elif method == "streaming":
        sel = streaming_selection(nq, nk, b, hq, cfg.sink_blocks, cfg.local_blocks)
    elif method == "xattention":
        sel = xattention_like_selection(q, k, v, cfg)
    else:
        raise ValueError(f"unknown baseline {method!r}")
    token_mask = selection_lib.block_mask_to_token_mask(
        sel.block_mask, cfg.block_size, cfg.block_size, sq, sk
    )
    out = sa.dense_attention(q, k, v, causal=True, scale=d ** -0.5, mask=token_mask)
    return out, selection_lib.selection_density(sel, nk)
