"""Training-free sparse-attention baselines the paper compares against.

Every baseline is now a registered ``SparsityPolicy`` (core/policy.py) —
one declarative composition of metric x schedule x selector — so all of
them share Stem's executors *and* automatically work on the decode and
paged-serving paths.  Budget accounting and reconstruction-error
comparisons are therefore apples-to-apples:

  * ``"uniform-sam"``   — uniform Top-k over routing-only scores.  This is
                          the paper's ablation baseline (Table 5, row
                          "Uniform"); with k_uni = k_start (1+mu)/2 it is
                          budget-matched to TPD.
  * ``"streaming"``     — StreamingLLM-style static sink + local window
                          (content-free metric + sink-local schedule).
  * ``"xattention"``    — anti-diagonal block scores + per-row softmax +
                          cumulative-mass threshold tau (XAttention's
                          selection rule).

The ``*_selection`` functions below are thin compatibility wrappers that
build the policy equivalent of a legacy ``StemConfig`` + keyword arguments
and return its ``BlockSelection`` — ``tests/test_policy.py`` pins them
bit-for-bit against hand-composed policies.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import policy as policy_lib
from repro.core import schedule as schedule_lib
from repro.core import selection as selection_lib
from repro.core.sparse_attention import sparse_attention
from repro.core.config import StemConfig, uniform_equivalent_budget  # noqa: F401
# (uniform_equivalent_budget is re-exported at module level — historically it
# was imported inside a function body; the budget-matched default now lives
# in policy.UniformSchedule, which uses it directly.)

NEG_INF = -1e30


def uniform_budgets(nq: int, nk: int, k_uni: int) -> jnp.ndarray:
    """Constant budget, causally clamped (jnp view of the uniform schedule)."""
    return schedule_lib.budgets_as_jax(
        schedule_lib.uniform_budget_blocks(nq, nk, k_uni))


def uniform_sam_policy(cfg: StemConfig,
                       k_uni: Optional[int] = None) -> policy_lib.SparsityPolicy:
    """The ``"uniform-sam"`` baseline scaled to a legacy config's geometry.

    ``k_uni=None`` keeps the budget-matched default (Table 5):
    k_uni = k_start (1+mu)/2, computed from the config's k_start rule.
    """
    sam = dataclasses.replace(cfg, metric="sam", mu=1.0)
    return policy_lib.as_policy(sam).with_updates(
        schedule=policy_lib.UniformSchedule(
            k_blocks=k_uni, k_start_frac=cfg.k_start_frac, mu=cfg.mu,
            min_budget_blocks=cfg.min_budget_blocks))


def uniform_sam_selection(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: StemConfig,
    k_uni: Optional[int] = None,
) -> selection_lib.BlockSelection:
    """Uniform Top-k with the Score-Aware Metric (routing only)."""
    sel, _ = uniform_sam_policy(cfg, k_uni).prefill_select(q, k, v)
    return sel


def streaming_policy(sink_blocks: int, local_blocks: int,
                     block_size: int = 128) -> policy_lib.SparsityPolicy:
    """StreamingLLM at a given window geometry (schedule and selector floors
    stay consistent by construction)."""
    return policy_lib.get_policy("streaming").with_updates(
        block_size=block_size, sink_blocks=sink_blocks,
        local_blocks=local_blocks)


def streaming_selection(
    nq: int, nk: int, batch: int, heads: int, sink_blocks: int, local_blocks: int
) -> selection_lib.BlockSelection:
    """StreamingLLM: static sink + sliding window at block granularity.

    Shape-only wrapper (the metric is content-free, so no q/k/v needed):
    runs the ``"streaming"`` policy's selector over a zero metric.
    """
    pol = streaming_policy(sink_blocks, local_blocks)
    metric = jnp.zeros((batch, heads, nq, nk), jnp.float32)
    budgets = pol.schedule.prefill_budgets(nq, nk, block_size=1, kv_len=nk)
    return pol.selector.select(
        metric, schedule_lib.budgets_as_jax(budgets),
        int(min(sink_blocks + local_blocks, nk)), with_block_mask=True)


def xattention_policy(cfg: StemConfig, tau: float = 0.9) -> policy_lib.SparsityPolicy:
    """The ``"xattention"`` baseline scaled to a legacy config's geometry.
    No group reduction (per-head thresholding, as in the original)."""
    return policy_lib.get_policy("xattention").with_updates(
        block_size=cfg.block_size, stride=cfg.stride, tau=tau,
        sink_blocks=cfg.sink_blocks, local_blocks=cfg.local_blocks,
        pooling=cfg.pooling, group_reduce="none")


def xattention_like_selection(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: StemConfig,
    tau: float = 0.9,
) -> selection_lib.BlockSelection:
    """XAttention-style: softmax the pooled anti-diagonal scores per row and
    keep the smallest prefix of blocks whose cumulative mass reaches tau."""
    sel, _ = xattention_policy(cfg, tau).prefill_select(q, k, v)
    return sel


def baseline_policy(cfg: StemConfig, method: str,
                    k_uni: Optional[int] = None) -> policy_lib.SparsityPolicy:
    """Resolve a legacy baseline name to its policy at ``cfg``'s geometry."""
    if method == "uniform_sam":
        return uniform_sam_policy(cfg, k_uni)
    if method == "streaming":
        return streaming_policy(cfg.sink_blocks, cfg.local_blocks,
                                cfg.block_size)
    if method == "xattention":
        return xattention_policy(cfg)
    raise ValueError(f"unknown baseline {method!r}")


@functools.partial(jax.jit, static_argnames=("cfg", "method", "k_uni"))
def baseline_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: StemConfig,
    method: str = "uniform_sam",
    k_uni: Optional[int] = None,
):
    """Run a baseline policy through the shared dense-oracle executor.

    Returns (output, realized_density).
    """
    out, stats = sparse_attention(
        q, k, v, baseline_policy(cfg, method, k_uni),
        executor="dense", return_stats=True)
    return out, stats.density
