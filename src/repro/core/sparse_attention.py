"""Sparse attention execution: policy orchestration (Algorithm 1 shape).

Pipeline per (batch, head), for *any* ``SparsityPolicy`` (core/policy.py):
  1. the policy's ``BlockMetric`` scores key blocks (metric.py),
  2. its ``BudgetSchedule`` fixes per-row block budgets (schedule.py),
  3. its ``Selector`` turns scores + budgets into a BlockSelection
     (selection.py),
  4. an *executor* runs exact attention over the selected blocks only.

Executors are resolved through the policy registry
(``policy.register_executor`` — DESIGN.md describes the contract in
detail):
  * "xla"    — gather-based flash-style executor in pure jnp.  This is the
               path lowered in the distributed dry-run; it is mathematically
               identical to the Pallas kernel.  With ``policy.ragged`` it
               runs a budget-sorted segment schedule so cost tracks the
               *average* budget instead of the padded k_max, and with
               GQA-shared selection it fetches each K/V block once per KV
               head.
  * "pallas" — TPU kernel (kernels/block_sparse_attn.py) driven by the same
               selection indices via scalar prefetch; dead slots revisit the
               previous K/V block (zero new DMAs) and rows finalize at their
               own live count.
  * "dense"  — O(N^2) masked oracle for tests.

``sparse_attention(q, k, v, policy)`` is the primary entry point;
``stem_attention(q, k, v, cfg)`` is the flag-record shim
(``policy = cfg.policy()``, executor from ``cfg.backend``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as policy_lib
from repro.core import selection as selection_lib
from repro.core.config import StemConfig
from repro.sharding.context import constrain

NEG_INF = -1e30


class StemStats(NamedTuple):
    density: jnp.ndarray          # realized fraction of admissible blocks
    avg_budget_blocks: jnp.ndarray
    k_max: int


def dense_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Reference dense attention with GQA support.

    q: (b, hq, sq, d); k: (b, hk, sk, d); v: (b, hk, sk, dv) — dv may differ
    from d (MLA).  O(N^2) — baseline & oracle.
    """
    b, hq, sq, d = q.shape
    hk = k.shape[1]
    dv = v.shape[-1]
    group = hq // hk
    scale = (d ** -0.5) if scale is None else scale
    qg = q.reshape(b, hk, group, sq, d)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    sk = k.shape[2]
    if causal:
        offset = sk - sq
        qi = jnp.arange(sq)[:, None]
        kj = jnp.arange(sk)[None, :]
        cmask = kj <= qi + offset
        scores = jnp.where(cmask, scores, NEG_INF)
    if mask is not None:
        # mask: (b, hq, sq, sk) boolean keep-mask.
        scores = jnp.where(mask.reshape(b, hk, group, sq, sk), scores, NEG_INF)
    # Guard fully-masked rows (can occur only in pathological configs).
    row_max = scores.max(axis=-1, keepdims=True)
    probs = jax.nn.softmax(jnp.where(row_max > NEG_INF / 2, scores, 0.0), axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "q_chunk", "kv_chunk"))
def dense_attention_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Flash-style dense attention in pure XLA: streams KV chunks with an
    online-softmax accumulator, so peak memory is O(N * chunk) instead of
    O(N^2).  This is the memory shape the Pallas flash kernel has on TPU;
    it's what train/prefill lower in the dry-run.

    Note: causal masking is applied by masking, not by skipping chunks, so
    the *compute* is 2x the causal-triangle minimum (documented in
    DESIGN.md; the Stem path avoids this entirely by gathering only
    selected blocks).
    """
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    dv = v.shape[-1]
    group = hq // hk
    scale = (d ** -0.5) if scale is None else scale
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    if sq % qc or sk % kc:
        return dense_attention(q, k, v, causal=causal, scale=scale)
    nq, nk = sq // qc, sk // kc

    qb = (q.reshape(b, hk, group, nq, qc, d).astype(jnp.float32) * scale)
    kb = k.reshape(b, hk, nk, kc, d)
    vb = v.reshape(b, hk, nk, kc, dv)
    q_pos = jnp.arange(sq).reshape(nq, qc)

    def body(carry, j):
        acc, m, l = carry
        k_j = jax.lax.dynamic_index_in_dim(kb, j, axis=2, keepdims=False)
        v_j = jax.lax.dynamic_index_in_dim(vb, j, axis=2, keepdims=False)
        s = jnp.einsum("bhgnqd,bhkd->bhgnqk", qb, k_j.astype(jnp.float32))
        if causal:
            k_pos = j * kc + jnp.arange(kc)
            keep = k_pos[None, None] <= (sk - sq) + q_pos[:, :, None]
            s = jnp.where(keep[None, None, None], s, NEG_INF)
        s_max = s.max(axis=-1)
        m_new = jnp.maximum(m, s_max)
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        if causal:
            p = jnp.where(keep[None, None, None], p, 0.0)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgnqk,bhkd->bhgnqd", p, v_j.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, hk, group, nq, qc, dv), jnp.float32)
    m0 = jnp.full((b, hk, group, nq, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, group, nq, qc), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def dense_attention_auto(q, k, v, *, causal=True, scale=None,
                         mask=None, threshold: int = 2048):
    """Dispatch: chunked flash path for long sequences (no custom mask),
    direct masked softmax otherwise."""
    if mask is None and q.shape[2] >= threshold and k.shape[2] >= threshold:
        return dense_attention_chunked(q, k, v, causal=causal, scale=scale)
    return dense_attention(q, k, v, causal=causal, scale=scale, mask=mask)


def _gather_executor(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    indices: jnp.ndarray,
    slot_mask: jnp.ndarray,
    *,
    block_size: int,
    scale: float,
    slot_chunk: int,
    budgets: Optional[np.ndarray] = None,
    group_dedup: bool = False,
) -> jnp.ndarray:
    """Flash-style sparse executor: per query-block row, stream the selected
    key/value blocks in chunks with an online-softmax accumulator.

    The executor folds (head-in-group, query-block) pairs into a single
    "row" axis per KV head, so one code path covers both layouts:

      * ``group_dedup=False`` — indices/slot_mask are per query head,
        (b, hq, nq, k_max); rows = group * nq, each with a (block_q, d)
        query tile.
      * ``group_dedup=True`` — selection is shared across the query heads
        of each KV group (``cfg.group_reduce != "none"``), so indices are
        (b, hk, nq, k_max); rows = nq with a fused (group * block_q, d)
        query tile.  Each K/V block is gathered once per *KV head*, cutting
        gather traffic by the group factor.

    ``budgets`` (static numpy, per query-block row) enables the ragged
    schedule: rows are budget-sorted and segmented (selection.
    budget_sorted_segments) and each segment scans only the slot chunks its
    rows actually use — the chunk-level early-out that makes cost track the
    average TPD budget instead of k_max.  ``budgets=None`` runs the padded
    schedule (every row pays ceil(k_max / slot_chunk) chunks).

    q: (b, hq, sq, d); k, v: (b, hk, sk, d).
    """
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    dv = v.shape[-1]
    group = hq // hk
    bs = block_size
    nq, nk = sq // bs, sk // bs
    k_max = indices.shape[-1]
    chunk = max(1, min(slot_chunk, k_max))
    # Pad slot dim to a multiple of the chunk size.
    pad = (-k_max) % chunk
    if pad:
        indices = jnp.pad(indices, ((0, 0), (0, 0), (0, 0), (0, pad)))
        slot_mask = jnp.pad(slot_mask, ((0, 0), (0, 0), (0, 0), (0, pad)))
    n_chunks = (k_max + pad) // chunk

    kb = k.reshape(b, hk, nk, bs, d)
    vb = v.reshape(b, hk, nk, bs, dv)
    # Pin K/V blocks to (batch, heads) sharding: if a seq-sharded layout
    # propagates in (e.g. from a kv_seq-sharded cache output), GSPMD cannot
    # partition the data-dependent block gather and emits a full masked
    # all-reduce of the gathered tensor (34 GB/layer at glm4-9b 32k —
    # §Perf glm4 iteration 2, DESIGN.md).
    kb = constrain(kb, ("batch", "kv_heads", None, None, None))
    vb = constrain(vb, ("batch", "kv_heads", None, None, None))

    offset = sk - sq  # 0 for self-attention prefill/train
    q_pos = offset + np.arange(sq).reshape(nq, bs)  # global query positions

    qg = q.reshape(b, hk, group, nq, bs, d)
    if group_dedup:
        # Rows = query-block rows; fused (group * bs) query tile per row.
        qrows = qg.transpose(0, 1, 3, 2, 4, 5).reshape(b, hk, nq, group * bs, d)
        idx = indices
        msk = slot_mask
        q_pos_rows = np.tile(q_pos, (1, group))            # (nq, group*bs)
        row_budgets = budgets
    else:
        # Rows = (head-in-group, query-block) pairs, plain (bs) query tile.
        qrows = qg.reshape(b, hk, group * nq, bs, d)
        idx = indices.reshape(b, hk, group * nq, -1)
        msk = slot_mask.reshape(b, hk, group * nq, -1)
        q_pos_rows = np.tile(q_pos, (group, 1))            # (group*nq, bs)
        row_budgets = None if budgets is None else np.tile(budgets, group)
    qrows = qrows.astype(jnp.float32) * scale
    q_pos_rows = jnp.asarray(q_pos_rows)

    def run_rows(q_r, pos_r, idx_r, msk_r, seg_chunks):
        """Online-softmax scan over ``seg_chunks`` slot chunks for one row
        set: q_r (b, hk, R, Bq, d); idx_r/msk_r (b, hk, R, seg_chunks*chunk).
        """
        R, Bq = q_r.shape[2], q_r.shape[3]
        idx_s = idx_r.reshape(b, hk, R, seg_chunks, chunk)
        msk_s = msk_r.reshape(b, hk, R, seg_chunks, chunk)

        def body(carry, c):
            acc, m, l = carry
            idx_c = jax.lax.dynamic_index_in_dim(idx_s, c, axis=3, keepdims=False)
            msk_c = jax.lax.dynamic_index_in_dim(msk_s, c, axis=3, keepdims=False)
            # Gather selected key/value blocks once per KV head:
            # (b, hk, R, chunk, bs, d).
            gidx = idx_c[..., None, None]
            k_c = jnp.take_along_axis(kb[:, :, None], gidx, axis=3)
            v_c = jnp.take_along_axis(vb[:, :, None], gidx, axis=3)
            # Scores: (b, hk, R, Bq, chunk, bs_k).
            s = jnp.einsum("bhrqd,bhrckd->bhrqck", q_r, k_c.astype(jnp.float32))
            # Token-level causal mask (exact on diagonal blocks) + validity.
            k_pos = idx_c[..., None] * bs + jnp.arange(bs)   # (b,hk,R,chunk,bs)
            keep = k_pos[:, :, :, None] <= pos_r[None, None, :, :, None, None]
            keep = keep & msk_c[:, :, :, None, :, None]
            s = jnp.where(keep, s, NEG_INF)
            # Online softmax update.
            s_max = s.max(axis=(-1, -2))                     # (b, hk, R, Bq)
            m_new = jnp.maximum(m, s_max)
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None, None])
            p = jnp.where(keep, p, 0.0)
            l_new = l * corr + p.sum(axis=(-1, -2))
            pv = jnp.einsum("bhrqck,bhrckd->bhrqd", p, v_c.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, hk, R, Bq, dv), jnp.float32)
        m0 = jnp.full((b, hk, R, Bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, R, Bq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(seg_chunks))
        return acc / jnp.maximum(l, 1e-20)[..., None]

    if row_budgets is None:
        out_rows = run_rows(qrows, q_pos_rows, idx, msk, n_chunks)
    else:
        # Ragged schedule: budget-sorted segments, each scanning only the
        # chunks its rows need.  All indexing below is static numpy, so each
        # segment lowers to its own (smaller) fused scan.
        segments = selection_lib.budget_sorted_segments(row_budgets, chunk)
        outs = []
        for seg in segments:
            rows = np.asarray(seg.rows)
            n_slots = min(seg.n_chunks, n_chunks) * chunk
            outs.append(run_rows(
                jnp.take(qrows, rows, axis=2),
                jnp.take(q_pos_rows, rows, axis=0),
                jnp.take(idx, rows, axis=2)[..., :n_slots],
                jnp.take(msk, rows, axis=2)[..., :n_slots],
                min(seg.n_chunks, n_chunks),
            ))
        inv = np.argsort(np.concatenate([np.asarray(s.rows) for s in segments]))
        out_rows = jnp.take(jnp.concatenate(outs, axis=2), inv, axis=2)

    if group_dedup:
        out = out_rows.reshape(b, hk, nq, group, bs, dv)
        out = out.transpose(0, 1, 3, 2, 4, 5)
    else:
        out = out_rows.reshape(b, hk, group, nq, bs, dv)
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def select_for(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg,
    *,
    with_block_mask: bool = True,
) -> tuple[selection_lib.BlockSelection, int]:
    """Phase 1: metric + schedule + selection.  ``cfg`` may be a
    ``StemConfig``, a ``SparsityPolicy`` or a registered policy name."""
    return policy_lib.as_policy(cfg).prefill_select(
        q, k, v, with_block_mask=with_block_mask)


# ---------------------------------------------------------------------------
# Executors (registered under policy.register_executor; resolved by name)
# ---------------------------------------------------------------------------

def _dense_oracle_executor(q, k, v, sel, *, policy, scale, **_):
    """O(N^2) masked softmax over the selection's dense block mask."""
    token_mask = selection_lib.block_mask_to_token_mask(
        sel.block_mask, policy.block_size, policy.block_size,
        q.shape[2], k.shape[2])
    return dense_attention(q, k, v, causal=True, scale=scale, mask=token_mask)


def _xla_gather_executor(q, k, v, sel, *, policy, scale, indices, slot_mask,
                         dedup, budgets, **_):
    return _gather_executor(
        q, k, v, indices, slot_mask,
        block_size=policy.block_size, scale=scale,
        slot_chunk=policy.slot_chunk, budgets=budgets, group_dedup=dedup)


def _pallas_executor(q, k, v, sel, *, policy, scale, indices, slot_mask,
                     live_counts, dedup, **_):
    from repro.kernels import ops as kernel_ops  # deferred: optional dep

    return kernel_ops.block_sparse_attention(
        q, k, v, indices, slot_mask,
        block_size=policy.block_size, scale=scale, group_dedup=dedup,
        live_counts=live_counts)


policy_lib.register_executor("dense", _dense_oracle_executor,
                             needs_block_mask=True)
policy_lib.register_executor("xla", _xla_gather_executor)
policy_lib.register_executor("pallas", _pallas_executor)


@functools.partial(jax.jit, static_argnames=("policy", "executor", "return_stats"))
def sparse_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    policy,
    executor: Optional[str] = None,
    return_stats: bool = False,
):
    """Block-sparse causal attention under a composable ``SparsityPolicy``.

    Args:
      q: (batch, q_heads, seq, head_dim)
      k, v: (batch, kv_heads, seq, head_dim)
      policy: SparsityPolicy | registered policy name | legacy StemConfig.
      executor: execution backend name from the executor registry
        ("xla" | "pallas" | "dense"); None uses ``policy.executor``.
      return_stats: also return StemStats.

    Returns:
      (batch, q_heads, seq, head_dim) attention output [, StemStats].
    """
    policy = policy_lib.as_policy(policy)
    spec = policy_lib.get_executor(executor or policy.executor)
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    scale = d ** -0.5
    nk = sk // policy.block_size
    # selection_density works from slot_mask, so stats never force the
    # dense block-mask scatter onto a production executor.
    sel, k_max = policy.prefill_select(
        q, k, v, with_block_mask=spec.needs_block_mask)

    # GQA block dedup: with group-shared selection every query head of a KV
    # group picks identical blocks, so the executors only need the indices
    # of one head per group (DESIGN.md §GQA dedup invariant).
    group = hq // k.shape[1]
    dedup = policy.ragged and policy.group_reduce != "none" and group > 1
    idx, msk, cnt = sel.indices, sel.slot_mask, sel.live_counts
    if dedup:
        idx, msk, cnt = idx[:, ::group], msk[:, ::group], cnt[:, ::group]

    # Budgets are static per (policy, shape) — recompute in numpy so the
    # ragged segment schedule resolves at trace time.  Threshold selectors
    # have data-dependent budgets, so they run the padded schedule.
    budgets_np = None
    if policy.ragged and policy.selector.budget_driven:
        budgets_np = policy.prefill_budgets(sq, sk)

    out = spec.fn(q, k, v, sel, policy=policy, scale=scale, indices=idx,
                  slot_mask=msk, live_counts=cnt, dedup=dedup,
                  budgets=budgets_np)

    if return_stats:
        stats = StemStats(
            density=selection_lib.selection_density(sel, nk),
            avg_budget_blocks=sel.budgets.mean(),
            k_max=k_max,
        )
        return out, stats
    return out


def stem_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: StemConfig,
    return_stats: bool = False,
):
    """Stem sparse causal attention (Algorithm 1) — flag-record shim.

    Stable entry point for existing call sites: converts the frozen
    ``StemConfig`` into its equivalent ``SparsityPolicy`` (OAM/SAM x TPD x
    top-k, executor from ``cfg.backend``) and delegates to
    :func:`sparse_attention`.  Bit-identical to the policy spelling.
    """
    return sparse_attention(q, k, v, cfg, return_stats=return_stats)
