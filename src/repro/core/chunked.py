"""Chunked sparse prefill over the paged Stem KV cache.

The serving engine used to prefill each prompt in one monolithic pass —
one jitted trace per padded prompt length, stalling every in-flight decode
slot until it finished.  This module is the core of the unified alternative:
the prompt is processed in fixed-size chunks ``[t0, t0 + C)`` that ride in
the same batched step as decode tokens, and each chunk's queries run the
policy's full coarse-to-fine pipeline against the page pool:

  1. **metric** — the chunk's queries are anti-diagonal-pooled per query
     block (block-aligned, so the group means equal one-shot pooling) and
     scored against every visible page's stored summaries
     (``PagePool.kg`` / ``PagePool.vm``) via ``policy.chunk_scores``.  The
     in-chunk blocks are scored the same way: the chunk's own K/V pages are
     written *before* attention, so "history" and "current chunk" pages are
     indistinguishable to the metric — exactly the one-shot geometry.
  2. **schedule** — per-row block budgets are evaluated at **absolute**
     query-block rows of the *full* prompt (the paper's position-decay rule
     keyed to absolute positions), not chunk-relative ones: row ``i`` of
     chunk ``c`` gets ``prefill_budgets(padded_len)[t0/B + i]``.  Budgets
     stay static numpy per request and enter the trace as data
     (``chunk_budget_rows``), so one fixed-shape trace serves every prompt
     length and every chunk size — including unaligned final chunks.
  3. **selection** — top-k with forced sink/local floors at the absolute
     diagonal, mirroring ``selection.select_blocks`` bit-for-bit on the
     shared candidates (the chunked top-k runs at width ``max_pages``; the
     extra causally-masked candidates sort last and never go live).
  4. **execution** — only the selected pages are gathered from the pool and
     attended exactly, with token-level causal masking at absolute
     positions (exact on the diagonal block).

Because every stage evaluates at absolute positions, chunked prefill is
selection-equivalent to one-shot prefill for any chunk size with
``C % block_size == 0`` (``tests/test_chunked.py`` pins logits to <=1e-4
fp32 across policies, GQA groups, and aligned/unaligned prompt lengths).

Only budget-driven selectors are supported (``validate_chunked_policy``):
threshold selectors (cumulative-mass) have data-dependent budgets that
cannot be sliced per chunk on the host.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as policy_lib
from repro.core.selection import FORCE_BONUS

NEG_INF = -1e30


class ChunkSelection(NamedTuple):
    """Per-query-block-row page selection for one prefill chunk.

    indices: (b, hq, nc, k_max) int32 *logical* block ids (page-table slot
      order); dead slots point at block 0 and are masked by ``live``.
    live: (b, hq, nc, k_max) bool — slot carries a selected, in-budget,
      causally admissible block.
    """

    indices: jnp.ndarray
    live: jnp.ndarray


def validate_chunked_policy(policy) -> None:
    """Fail fast (clear message, outside jit) for policies chunked prefill
    cannot serve: threshold selectors and metrics without ``chunk_scores``."""
    policy = policy_lib.as_policy(policy)
    if not getattr(policy.selector, "budget_driven", False):
        raise NotImplementedError(
            f"chunked prefill needs a budget-driven selector; "
            f"{type(policy.selector).__name__} is threshold-based — run the "
            "engine with monolithic_prefill=True for this policy")
    if getattr(policy.metric, "chunk_scores", None) is None:
        raise NotImplementedError(
            f"metric {type(policy.metric).__name__} lacks chunk_scores — "
            "required for chunked prefill")


# ---------------------------------------------------------------------------
# Host-side schedule slicing (static numpy, fed to the trace as data)
# ---------------------------------------------------------------------------

def chunk_budget_rows(policy, padded_len: int, chunk_start: int,
                      n_rows: int) -> np.ndarray:
    """TPD (or any schedule's) budgets for the chunk's absolute query-block
    rows: the one-shot ``prefill_budgets(padded_len)`` vector sliced at
    ``chunk_start / block_size``, zero-padded past the prompt (rows beyond
    the prompt carry budget 0 and never go live).  int32 numpy, (n_rows,).
    """
    policy = policy_lib.as_policy(policy)
    full = policy.prefill_budgets(padded_len)
    j0 = chunk_start // policy.block_size
    out = np.zeros((n_rows,), np.int32)
    rows = full[j0:j0 + n_rows]
    out[:len(rows)] = rows
    return out


# ---------------------------------------------------------------------------
# Selection at absolute query-block rows
# ---------------------------------------------------------------------------

def chunk_budget_bound(policy, max_pages: int) -> int:
    """Static upper bound on any chunk row's block budget — the top-k /
    gather width the chunked executor allocates.  Computed as the exact max
    over every admissible padded prompt length (schedules need not be
    monotone: the paper's k_start fraction steps down at 16k keys), falling
    back to ``max_pages`` when the sweep would be too costly at init."""
    policy = policy_lib.as_policy(policy)
    if max_pages > 4096:
        return max_pages
    bound = 1
    for n in range(1, max_pages + 1):
        bound = max(bound, int(policy.prefill_budgets(
            n * policy.block_size).max()))
    return max(1, min(bound, max_pages))


def select_chunk_blocks(m: jnp.ndarray, block_rows: jnp.ndarray,
                        budgets: jnp.ndarray, policy,
                        k_max: int = 0) -> ChunkSelection:
    """Top-k + forced sink/local floors + causal validity, at absolute rows.

    m: (b, hq, nc, P) chunk metric; block_rows: (b, nc) absolute query-block
    row per chunk row; budgets: (b, nc) int32 per-row block budgets;
    k_max: static selection width (0 = all P candidates — always safe;
    ``chunk_budget_bound`` gives the tight value).  Semantics mirror
    ``selection.select_blocks`` evaluated on the full (nq_total, nk_total)
    grid, restricted to the chunk's rows: the top-k cut is a prefix of the
    same descending order, so any width >= the largest live budget selects
    the identical set.
    """
    policy = policy_lib.as_policy(policy)
    b, hq, nc, maxp = m.shape
    k_max = maxp if k_max <= 0 else min(k_max, maxp)
    blk = jnp.arange(maxp)
    causal = blk[None, None, :] <= block_rows[:, :, None]          # (b, nc, P)
    is_sink = (blk < policy.sink_blocks)[None, None, :]
    is_local = blk[None, None, :] > block_rows[:, :, None] - policy.local_blocks
    forced = (is_sink | is_local) & causal                         # (b, nc, P)

    biased = jnp.where(forced[:, None], m + FORCE_BONUS, m)
    biased = jnp.where(causal[:, None], biased, NEG_INF)
    vals, idx = jax.lax.top_k(biased, k_max)              # (b, hq, nc, k_max)
    live = (vals > NEG_INF / 2) & (
        jnp.arange(k_max)[None, None, None, :] < budgets[:, None, :, None])
    return ChunkSelection(indices=jnp.where(live, idx, 0).astype(jnp.int32),
                          live=live)


# ---------------------------------------------------------------------------
# Exact attention over the gathered pages
# ---------------------------------------------------------------------------

def attend_chunk(
    q: jnp.ndarray,            # (b, hq, C, d) chunk queries
    gk: jnp.ndarray,           # (b, hk, g, nc, k_max, bs, d) gathered pages
    gv: jnp.ndarray,           # (b, hk, g, nc, k_max, bs, dv)
    sel: ChunkSelection,
    chunk_start: jnp.ndarray,  # (b,) absolute first query position
    block_size: int,
) -> jnp.ndarray:
    """Masked softmax over the selected pages only, token-causal at
    absolute positions.  Returns (b, hq, C, dv)."""
    b, hq, c, d = q.shape
    hk = gk.shape[1]
    group = hq // hk
    bs = block_size
    nc = c // bs
    k_max = gk.shape[4]
    dv = gv.shape[-1]
    qg = q.reshape(b, hk, group, nc, bs, d).astype(jnp.float32)
    s = jnp.einsum("bhgnqd,bhgnkcd->bhgnqkc", qg, gk.astype(jnp.float32))
    s = s * (d ** -0.5)                         # (b, hk, g, nc, bs_q, kmax, bs_k)
    live = sel.live.reshape(b, hk, group, nc, k_max)
    tok_pos = sel.indices.reshape(b, hk, group, nc, k_max)[..., None] * bs \
        + jnp.arange(bs)                        # (b, hk, g, nc, kmax, bs_k)
    q_pos = chunk_start[:, None, None] + (jnp.arange(nc) * bs)[None, :, None] \
        + jnp.arange(bs)[None, None, :]         # (b, nc, bs_q)
    keep = (tok_pos[:, :, :, :, None]
            <= q_pos[:, None, None, :, :, None, None])
    keep = keep & live[:, :, :, :, None, :, None]
    s = jnp.where(keep, s, NEG_INF)
    p = jax.nn.softmax(s.reshape(b, hk, group, nc, bs, -1), axis=-1)
    p = jnp.where(keep, p.reshape(s.shape), 0.0)
    o = jnp.einsum("bhgnqkc,bhgnkcd->bhgnqd", p, gv.astype(jnp.float32))
    return o.reshape(b, hq, c, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# The full phase: metric -> select -> gather -> attend
# ---------------------------------------------------------------------------

def chunked_prefill_attention(
    q: jnp.ndarray,              # (b, hq, C, d) chunk queries (rope'd)
    pool,                        # runtime.paged.PagePool (chunk already written)
    page_table: jnp.ndarray,     # (b, max_pages) global page ids
    chunk_start: jnp.ndarray,    # (b,) absolute position of the chunk start
    budgets: jnp.ndarray,        # (b, C // block) int32 absolute-row budgets
    policy,
    k_max: int = 0,              # static gather width (0 = max_pages)
    executor=None,               # paged backend name (None = policy.executor)
) -> jnp.ndarray:
    """Policy-sparse prefill attention for one chunk, straight off the page
    pool.  The chunk's own pages must already be written
    (``paged.write_chunk_pages`` runs first in ``attention.apply_chunk_paged``)
    so in-chunk blocks score and gather exactly like history blocks.
    ``executor`` picks the paged backend from the ``core/policy.py``
    registry — "xla" (the gather oracle below) or "pallas" (the fused
    kernels in ``kernels/paged_attn.py``).  Returns (b, hq, C, dv).
    """
    policy = policy_lib.as_policy(policy)
    spec = policy_lib.get_paged_executor(executor or policy.executor)
    return spec.chunk_fn(q, pool, page_table, chunk_start, budgets, policy,
                         k_max)


def _chunked_prefill_xla(
    q: jnp.ndarray,
    pool,
    page_table: jnp.ndarray,
    chunk_start: jnp.ndarray,
    budgets: jnp.ndarray,
    policy,
    k_max: int = 0,
) -> jnp.ndarray:
    """The XLA gather backend (and the fused kernel's differential oracle):
    summary gather -> chunk metric -> selection -> page gather -> masked
    attend, each a separate inspectable op."""
    policy = policy_lib.as_policy(policy)
    b, hq, c, d = q.shape
    hk = pool.k.shape[0]
    group = hq // hk
    bs = policy.block_size
    nc = c // bs
    maxp = page_table.shape[1]

    # Page summaries through the page table (cheap: pooled reps only).
    kg_rows = jnp.swapaxes(pool.kg[:, page_table], 0, 1)  # (b, hk, P, s, d)
    vm_rows = jnp.swapaxes(pool.vm[:, page_table], 0, 1)  # (b, hk, P)

    m = policy.chunk_scores(q, kg_rows, vm_rows)          # (b, hq, nc, P)
    rows = chunk_start[:, None] // bs + jnp.arange(nc)[None, :]
    sel = select_chunk_blocks(m, rows, budgets, policy, k_max)
    kk = sel.indices.shape[-1]

    # Logical slot -> global page id, then fetch only the selected pages.
    idx = sel.indices.reshape(b, hk, group, nc, kk)
    gp = jnp.take_along_axis(
        jnp.broadcast_to(page_table[:, None, None, None, :],
                         (b, hk, group, nc, maxp)),
        idx, axis=-1)                                      # (b,hk,g,nc,kmax)

    def fetch(kp, vp, gph):
        # kp, vp: (P, page, d); gph: (b, g, nc, kmax).
        return kp[gph], vp[gph]

    gk, gv = jax.vmap(fetch, in_axes=(0, 0, 1), out_axes=1)(
        pool.k, pool.v, gp)                        # (b, hk, g, nc, kmax, bs, d)
    return attend_chunk(q, gk, gv, sel, chunk_start, bs)
