"""Stem core: Token Position-Decay + Output-Aware Metric sparse attention."""
from repro.core.config import StemConfig, uniform_equivalent_budget
from repro.core.schedule import (
    average_budget,
    cost_decay,
    cost_uniform,
    max_budget_blocks,
    measured_cost_tokens,
    schedule_for,
    tpd_budget_blocks,
    tpd_budget_tokens,
)
from repro.core.metric import oam_metric, routing_scores, value_block_magnitude
from repro.core.selection import (
    BlockSelection,
    RaggedSegment,
    budget_sorted_segments,
    revisit_indices,
    select_blocks,
    selection_density,
)
from repro.core.sparse_attention import StemStats, dense_attention, stem_attention

__all__ = [
    "StemConfig",
    "uniform_equivalent_budget",
    "tpd_budget_tokens",
    "tpd_budget_blocks",
    "schedule_for",
    "max_budget_blocks",
    "cost_uniform",
    "cost_decay",
    "measured_cost_tokens",
    "average_budget",
    "oam_metric",
    "routing_scores",
    "value_block_magnitude",
    "BlockSelection",
    "RaggedSegment",
    "budget_sorted_segments",
    "revisit_indices",
    "select_blocks",
    "selection_density",
    "stem_attention",
    "dense_attention",
    "StemStats",
]
