"""Beyond-paper extension: policy-driven sparse *decode* attention.

The paper scopes Stem to the pre-filling phase.  The same coarse-to-fine
shape extends to decoding against a long KV cache (cf. Quest), and fits
our serving stack naturally because prefill already computes the
block-pooled representations:

  * keep the anti-diagonal-pooled K-block group means and the block
    max-pooled log||V|| alongside the KV cache (tiny: stride x d + 1 floats
    per 128-token block),
  * each decode step scores cache *blocks* against the single query with
    the policy's ``BlockMetric`` (``decode_scores``), applies the policy's
    budget + selection rule to the cache (for the top-k selector: a fixed
    fraction of cache blocks, floored, with forced sink + local blocks),
    and attends exactly over the selected blocks only.

This turns decode attention from O(L) per token to O(k_avg * B) — the same
coarse-to-fine shape as Algorithm 1 with nq = 1.

Everything is vectorized over *per-sequence* cache lengths: ``cache_lens``
may be a scalar (uniform batch, the seed behaviour) or a ``(b,)`` vector
(continuous batching — every row carries its own valid prefix, lengths need
not be multiples of ``block_size``).  The pipeline is factored into three
stages shared with the paged-cache executor (``runtime/paged.py``); all of
them accept a ``SparsityPolicy``, a registered policy name, or a legacy
``StemConfig`` (converted via ``cfg.policy()``):

  ``decode_block_metric``  — policy metric of the query vs every cache block;
  ``select_decode_blocks`` — policy budget + validity + forced floors
                             (``Selector.select_decode``);
  ``attend_selected``      — exact masked attention over gathered blocks.
"""
from __future__ import annotations

import os
from typing import NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metric as metric_lib
from repro.core import policy as policy_lib
from repro.core.selection import DecodeSelection  # noqa: F401  (re-export)

NEG_INF = -1e30
# The one shared decode sparsity default.  Every decode entry point
# (``sparse_decode_attention``, ``select_decode_blocks``,
# ``runtime.paged.paged_sparse_decode``, ``models.attention.apply_decode*``)
# reads this constant, so a caller omitting ``budget_frac`` gets the same
# behaviour on every path: **dense** (1.0) — the safe spelling, since
# forgetting the knob can cost throughput but never quality.  Sparse serving
# passes its fraction explicitly (the engine threads
# ``EngineConfig.budget_frac``).
DEFAULT_BUDGET_FRAC = 1.0
# summarize_cache() of an all-zero block yields this v_mag (log of the norm
# floor); fresh/partial pages are initialized to it so incremental appends
# reproduce the batch summary exactly.
V_MAG_FLOOR = float(np.log(1e-20))


class BlockSummary(NamedTuple):
    """Pooled per-block cache summaries (built at prefill, O(L) memory/B)."""
    k_groups: jnp.ndarray   # (b, hk, nblocks, stride, d) anti-diag group means
    v_mag: jnp.ndarray      # (b, hk, nblocks) max-pooled log ||V||


def summarize_cache(k: jnp.ndarray, v: jnp.ndarray, cfg) -> BlockSummary:
    """k, v: (b, hk, L, d) with L % block_size == 0.  ``cfg``: StemConfig,
    SparsityPolicy or policy name (block_size/stride are read off it)."""
    p = policy_lib.as_policy(cfg)
    return BlockSummary(
        k_groups=metric_lib.antidiag_pool(k, p.block_size, p.stride),
        v_mag=metric_lib.value_block_magnitude(v, p.block_size),
    )


# ---------------------------------------------------------------------------
# Stage 1: coarse metric — single query row vs all cache blocks
# ---------------------------------------------------------------------------

def decode_block_metric(q: jnp.ndarray, k_groups: jnp.ndarray,
                        v_mag: jnp.ndarray, cfg) -> jnp.ndarray:
    """Policy metric at block granularity for one decode query per sequence.

    q: (b, hq, 1, d); k_groups: (b, hk, n, stride, d); v_mag: (b, hk, n).
    Returns (b, hk, group, n) float32 — higher = more important.
    """
    return policy_lib.as_policy(cfg).decode_scores(q, k_groups, v_mag)


# ---------------------------------------------------------------------------
# Stage 2: per-row budget + static-width top-k selection
# ---------------------------------------------------------------------------

def decode_budget_bound(nblk: int, cfg, budget_frac: float) -> int:
    """Static top-k width of the policy's decode selection — the gather
    width the executors allocate (O(k_avg * B), not O(L), for budget-driven
    selectors)."""
    return policy_lib.as_policy(cfg).decode_budget_bound(nblk, budget_frac)


def select_decode_blocks(
    m: jnp.ndarray,                       # (b, hk, g, nblk) coarse metric
    cache_lens: jnp.ndarray,              # scalar or (b,) valid prefix
    cfg,
    budget_frac: float = DEFAULT_BUDGET_FRAC,
) -> DecodeSelection:
    """Policy budget + forced floors + validity, vectorized per row."""
    return policy_lib.as_policy(cfg).decode_select(
        m, cache_lens, budget_frac=budget_frac)


def debug_assert_live_rows(sel: DecodeSelection,
                           context: str = "decode selection") -> None:
    """Opt-in invariant check (``REPRO_DEBUG_DECODE=1``): every row with a
    non-empty cache must keep at least one live selected block per head —
    otherwise its attention output is a *silent zero vector* (see
    ``attend_selected``).  Normal policies cannot trip this (selectors force
    sink/local floors and budgets are floored at the forced count), so a
    failure means a broken schedule/selector composition; checking costs a
    host callback and is therefore gated behind the env var."""
    if not os.environ.get("REPRO_DEBUG_DECODE"):
        return

    has_live = sel.live.any(axis=-1)                     # (b, hk, g)
    nonempty = sel.n_valid > 0                           # (b,)

    def _check(has_live, nonempty, context=context):
        bad = np.asarray(nonempty)[:, None, None] & ~np.asarray(has_live)
        if bad.any():
            raise AssertionError(
                f"{context}: rows with a non-empty cache selected zero live "
                f"blocks at (row, kv_head, group) = "
                f"{np.argwhere(bad).tolist()}; their attention output will "
                "be a silent zero vector (schedule/selector produced a zero "
                "budget with no forced sink/local floor)")

    jax.debug.callback(_check, has_live, nonempty)


# ---------------------------------------------------------------------------
# Stage 3: exact attention over gathered blocks
# ---------------------------------------------------------------------------

def attend_selected(
    q: jnp.ndarray,            # (b, hq, 1, d)
    gk: jnp.ndarray,           # (b, hk, g, k_max, bs, d) gathered key blocks
    gv: jnp.ndarray,           # (b, hk, g, k_max, bs, dv)
    sel: DecodeSelection,
    cache_lens: jnp.ndarray,   # scalar or (b,)
    block_size: int,
) -> jnp.ndarray:
    """Masked softmax over the selected blocks only.  Returns (b, hq, 1, dv).

    **Zero-live-row contract:** a row whose selection carries no live slot
    (``sel.live`` all False — e.g. ``cache_lens == 0`` trash slots riding in
    a paged serving batch) softmaxes over an all-``NEG_INF`` score row; the
    uniform probabilities that produces are then zeroed by the ``keep``
    mask, so the row returns an **exact zero output vector** — not NaN, not
    garbage.  The fused Pallas path (``kernels/paged_attn.py``) honors the
    same contract: its accumulator never runs and the finalize step divides
    zero by the 1e-20 normalizer floor.  Rows with a *non-empty* cache must
    always have at least one live slot (selectors force sink/local floors);
    ``REPRO_DEBUG_DECODE=1`` asserts that invariant via
    ``debug_assert_live_rows``.  Pinned by
    tests/test_paged_kernel.py::TestZeroLiveRows on both paths.
    """
    debug_assert_live_rows(sel, context="attend_selected")
    b, hq, _, d = q.shape
    hk = gk.shape[1]
    group = hq // hk
    bs = block_size
    cache_lens = jnp.broadcast_to(jnp.asarray(cache_lens, jnp.int32), (b,))
    qg = q.reshape(b, hk, group, 1, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhgnkd->bhgqnk", qg, gk.astype(jnp.float32))
    s = s * (d ** -0.5)                                    # (b,hk,g,1,kmax,bs)
    tok_pos = sel.indices[..., None] * bs + jnp.arange(bs)  # (b,hk,g,kmax,bs)
    keep = (tok_pos < cache_lens[:, None, None, None, None]) & sel.live[..., None]
    s = jnp.where(keep[:, :, :, None], s, NEG_INF)
    p = jax.nn.softmax(s.reshape(b, hk, group, 1, -1), axis=-1).reshape(s.shape)
    p = jnp.where(keep[:, :, :, None], p, 0.0)
    o = jnp.einsum("bhgqnk,bhgnkd->bhgqd", p, gv.astype(jnp.float32))
    return o.reshape(b, hq, 1, gv.shape[-1]).astype(q.dtype)


def sparse_decode_attention(
    q: jnp.ndarray,           # (b, hq, 1, d) — one new query token
    cache_k: jnp.ndarray,     # (b, hk, L, d)
    cache_v: jnp.ndarray,
    summary: BlockSummary,
    cache_lens: Union[jnp.ndarray, int],   # scalar or (b,) valid prefixes
    cfg,
    budget_frac: float = DEFAULT_BUDGET_FRAC,
) -> jnp.ndarray:
    """Policy block selection + exact attention over selected cache blocks.

    ``cache_lens`` is per-sequence: a scalar applies one length to every
    row; a ``(b,)`` vector gives each row its own valid prefix (lengths not
    multiples of ``block_size`` are handled by token-level masking of the
    partial block).  At ``budget_frac=1.0`` every valid block is selected,
    so the result equals dense decode over each row's prefix exactly.
    """
    policy = policy_lib.as_policy(cfg)
    b, hq, _, d = q.shape
    hk = cache_k.shape[1]
    bs = policy.block_size
    nblk = cache_k.shape[2] // bs

    m = policy.decode_scores(q, summary.k_groups, summary.v_mag)
    sel = policy.decode_select(m, cache_lens, budget_frac=budget_frac)

    dv = cache_v.shape[-1]
    kb = cache_k.reshape(b, hk, nblk, bs, d)
    vb = cache_v.reshape(b, hk, nblk, bs, dv)
    # gather along the block axis (3 after the g broadcast dim is inserted)
    gk = jnp.take_along_axis(kb[:, :, None], sel.indices[..., None, None], axis=3)
    gv = jnp.take_along_axis(vb[:, :, None], sel.indices[..., None, None], axis=3)
    return attend_selected(q, gk, gv, sel, cache_lens, bs)
