"""Beyond-paper extension: Stem-sparse *decode* attention.

The paper scopes Stem to the pre-filling phase.  The same two ideas extend
to decoding against a long KV cache (cf. Quest), and fit our serving stack
naturally because prefill already computes the block-pooled representations:

  * keep the anti-diagonal-pooled K-block group means and the block
    max-pooled log||V|| alongside the KV cache (tiny: stride x d + 1 floats
    per 128-token block),
  * each decode step scores cache *blocks* with the Output-Aware Metric
    against the single query (routing + beta * magnitude), applies a
    TPD-like budget to the cache (here: a fixed fraction of cache blocks,
    floored), forces sink + local blocks, and attends exactly over the
    selected blocks only.

This turns decode attention from O(L) per token to O(k_avg * B) — the same
coarse-to-fine shape as Algorithm 1 with nq = 1.  Exposed as
``sparse_decode_attention`` and benchmarked in tests against full-cache
decode for selection quality.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metric as metric_lib
from repro.core import selection as selection_lib
from repro.core.config import StemConfig

NEG_INF = -1e30


class BlockSummary(NamedTuple):
    """Pooled per-block cache summaries (built at prefill, O(L) memory/B)."""
    k_groups: jnp.ndarray   # (b, hk, nblocks, stride, d) anti-diag group means
    v_mag: jnp.ndarray      # (b, hk, nblocks) max-pooled log ||V||


def summarize_cache(k: jnp.ndarray, v: jnp.ndarray, cfg: StemConfig) -> BlockSummary:
    """k, v: (b, hk, L, d) with L % block_size == 0."""
    return BlockSummary(
        k_groups=metric_lib.antidiag_pool(k, cfg.block_size, cfg.stride),
        v_mag=metric_lib.value_block_magnitude(v, cfg.block_size),
    )


def sparse_decode_attention(
    q: jnp.ndarray,           # (b, hq, 1, d) — one new query token
    cache_k: jnp.ndarray,     # (b, hk, L, d)
    cache_v: jnp.ndarray,
    summary: BlockSummary,
    cache_len: jnp.ndarray,   # scalar int32 — valid prefix of the cache
    cfg: StemConfig,
    budget_frac: float = 0.25,
) -> jnp.ndarray:
    """OAM block selection + exact attention over selected cache blocks.

    The top-k width is capped at a *static* bound derived from
    ``budget_frac`` + the stability floors, so the block gather moves
    O(k_avg * B) cache tokens per step instead of the whole cache.
    """
    b, hq, _, d = q.shape
    hk = cache_k.shape[1]
    group = hq // hk
    bs = cfg.block_size
    nblk = cache_k.shape[2] // bs

    # --- coarse metric: single query row vs all cache blocks -------------
    # Pool the query alone (stride groups of one position = the query).
    qg = q.reshape(b, hk, group, 1, d).astype(jnp.float32)
    kg = summary.k_groups.astype(jnp.float32)                    # (b,hk,n,s,d)
    # mean over groups == block mean-logit approximation for one query
    route = jnp.einsum("bhgqd,bhnsd->bhgqn", qg, kg) / (
        kg.shape[-2] * jnp.sqrt(jnp.asarray(d, jnp.float32)))
    route = route[:, :, :, 0]                                    # (b,hk,g,n)
    m = route + cfg.beta * jnp.maximum(summary.v_mag, 0.0)[:, :, None, :]

    # --- budget + validity ------------------------------------------------
    n_valid = (cache_len + bs - 1) // bs
    k_budget = jnp.maximum(
        jnp.int32(cfg.min_budget_blocks),
        (n_valid * budget_frac).astype(jnp.int32))
    blk = jnp.arange(nblk)
    is_valid = blk < n_valid
    is_sink = blk < cfg.sink_blocks
    is_local = (blk >= n_valid - cfg.local_blocks) & is_valid
    biased = jnp.where(is_sink | is_local, m + selection_lib.FORCE_BONUS, m)
    biased = jnp.where(is_valid, biased, NEG_INF)

    # Static budget bound so the gather below is O(k_avg * B), not O(L):
    # the dynamic k_budget never exceeds ceil(nblk * budget_frac) +
    # min_budget_blocks, and the forced sink/local floors ride on top (they
    # carry FORCE_BONUS, so they occupy the leading top-k slots).
    k_max = min(
        nblk,
        int(np.ceil(nblk * budget_frac)) + cfg.min_budget_blocks
        + cfg.sink_blocks + cfg.local_blocks,
    )
    k_max = max(k_max, 1)
    vals, idx = jax.lax.top_k(biased, k_max)                     # (b,hk,g,n)
    live = (vals > NEG_INF / 2) & (jnp.arange(k_max) < k_budget)

    # --- exact attention over selected blocks -----------------------------
    dv = cache_v.shape[-1]
    kb = cache_k.reshape(b, hk, nblk, bs, d)
    vb = cache_v.reshape(b, hk, nblk, bs, dv)
    # gather along the block axis (3 after the g broadcast dim is inserted)
    gk = jnp.take_along_axis(kb[:, :, None], idx[..., None, None], axis=3)
    gv = jnp.take_along_axis(vb[:, :, None], idx[..., None, None], axis=3)
    s = jnp.einsum("bhgqd,bhgnkd->bhgqnk", qg, gk.astype(jnp.float32))
    s = s * (d ** -0.5)                                          # (b,hk,g,1,n,bs)
    tok_pos = idx[..., None] * bs + jnp.arange(bs)               # (b,hk,g,n,bs)
    keep = (tok_pos < cache_len) & live[..., None]
    s = jnp.where(keep[:, :, :, None], s, NEG_INF)
    p = jax.nn.softmax(s.reshape(b, hk, group, 1, -1), axis=-1).reshape(s.shape)
    p = jnp.where(keep[:, :, :, None], p, 0.0)
    o = jnp.einsum("bhgqnk,bhgnkd->bhgqd", p, gv.astype(jnp.float32))
    return o.reshape(b, hq, 1, dv).astype(q.dtype)
