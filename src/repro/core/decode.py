"""Beyond-paper extension: Stem-sparse *decode* attention.

The paper scopes Stem to the pre-filling phase.  The same two ideas extend
to decoding against a long KV cache (cf. Quest), and fit our serving stack
naturally because prefill already computes the block-pooled representations:

  * keep the anti-diagonal-pooled K-block group means and the block
    max-pooled log||V|| alongside the KV cache (tiny: stride x d + 1 floats
    per 128-token block),
  * each decode step scores cache *blocks* with the Output-Aware Metric
    against the single query (routing + beta * magnitude), applies a
    TPD-like budget to the cache (here: a fixed fraction of cache blocks,
    floored), forces sink + local blocks, and attends exactly over the
    selected blocks only.

This turns decode attention from O(L) per token to O(k_avg * B) — the same
coarse-to-fine shape as Algorithm 1 with nq = 1.

Everything is vectorized over *per-sequence* cache lengths: ``cache_lens``
may be a scalar (uniform batch, the seed behaviour) or a ``(b,)`` vector
(continuous batching — every row carries its own valid prefix, lengths need
not be multiples of ``block_size``).  The pipeline is factored into three
stages shared with the paged-cache executor (``runtime/paged.py``):

  ``decode_block_metric``  — OAM score of the query vs every cache block;
  ``select_decode_blocks`` — per-row budget + validity + forced floors,
                             static-width top-k;
  ``attend_selected``      — exact masked attention over gathered blocks.
"""
from __future__ import annotations

from typing import NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metric as metric_lib
from repro.core import selection as selection_lib
from repro.core.config import StemConfig

NEG_INF = -1e30
# summarize_cache() of an all-zero block yields this v_mag (log of the norm
# floor); fresh/partial pages are initialized to it so incremental appends
# reproduce the batch summary exactly.
V_MAG_FLOOR = float(np.log(1e-20))


class BlockSummary(NamedTuple):
    """Pooled per-block cache summaries (built at prefill, O(L) memory/B)."""
    k_groups: jnp.ndarray   # (b, hk, nblocks, stride, d) anti-diag group means
    v_mag: jnp.ndarray      # (b, hk, nblocks) max-pooled log ||V||


def summarize_cache(k: jnp.ndarray, v: jnp.ndarray, cfg: StemConfig) -> BlockSummary:
    """k, v: (b, hk, L, d) with L % block_size == 0."""
    return BlockSummary(
        k_groups=metric_lib.antidiag_pool(k, cfg.block_size, cfg.stride),
        v_mag=metric_lib.value_block_magnitude(v, cfg.block_size),
    )


# ---------------------------------------------------------------------------
# Stage 1: coarse metric — single query row vs all cache blocks
# ---------------------------------------------------------------------------

def decode_block_metric(q: jnp.ndarray, k_groups: jnp.ndarray,
                        v_mag: jnp.ndarray, cfg: StemConfig) -> jnp.ndarray:
    """OAM at block granularity for one decode query per sequence.

    q: (b, hq, 1, d); k_groups: (b, hk, n, stride, d); v_mag: (b, hk, n).
    Returns (b, hk, group, n) float32 — higher = more important.
    """
    b, hq, _, d = q.shape
    hk = k_groups.shape[1]
    group = hq // hk
    qg = q.reshape(b, hk, group, 1, d).astype(jnp.float32)
    kg = k_groups.astype(jnp.float32)
    # mean over groups == block mean-logit approximation for one query
    route = jnp.einsum("bhgqd,bhnsd->bhgqn", qg, kg) / (
        kg.shape[-2] * jnp.sqrt(jnp.asarray(d, jnp.float32)))
    route = route[:, :, :, 0]                                    # (b,hk,g,n)
    return route + cfg.beta * jnp.maximum(v_mag, 0.0)[:, :, None, :]


# ---------------------------------------------------------------------------
# Stage 2: per-row budget + static-width top-k selection
# ---------------------------------------------------------------------------

class DecodeSelection(NamedTuple):
    """Per-row cache-block selection for one decode step.

    indices: (b, hk, g, k_max) int32 *logical* block ids (slot-local order);
      dead slots are masked by ``live``.
    live: (b, hk, g, k_max) bool — slot carries a selected, in-budget,
      valid block.
    budgets: (b,) int32 per-row block budget actually applied.
    n_valid: (b,) int32 ceil(cache_len / block_size) per row.
    """

    indices: jnp.ndarray
    live: jnp.ndarray
    budgets: jnp.ndarray
    n_valid: jnp.ndarray


def decode_budget_bound(nblk: int, cfg: StemConfig, budget_frac: float) -> int:
    """Static top-k width: the dynamic per-row budget never exceeds
    ceil(nblk * budget_frac) + min_budget_blocks, and the forced sink/local
    floors ride on top (they carry FORCE_BONUS, so they occupy the leading
    top-k slots).  Keeps the block gather O(k_avg * B), not O(L)."""
    k_max = min(
        nblk,
        int(np.ceil(nblk * budget_frac)) + cfg.min_budget_blocks
        + cfg.sink_blocks + cfg.local_blocks,
    )
    return max(k_max, 1)


def select_decode_blocks(
    m: jnp.ndarray,                       # (b, hk, g, nblk) coarse metric
    cache_lens: jnp.ndarray,              # scalar or (b,) valid prefix
    cfg: StemConfig,
    budget_frac: float = 0.25,
) -> DecodeSelection:
    """TPD-style budget + forced sink/local floors, vectorized per row."""
    b, _, _, nblk = m.shape
    bs = cfg.block_size
    cache_lens = jnp.broadcast_to(jnp.asarray(cache_lens, jnp.int32), (b,))

    n_valid = (cache_lens + bs - 1) // bs                        # (b,)
    # forced sink/local floors ride on top of the budget: the per-row union
    # of sink + local blocks is min(n_valid, sink + local) wide, and every
    # forced block must stay live regardless of budget_frac.
    n_forced = jnp.minimum(
        n_valid, jnp.int32(cfg.sink_blocks + cfg.local_blocks))
    k_budget = jnp.maximum(
        jnp.maximum(jnp.int32(cfg.min_budget_blocks), n_forced),
        (n_valid * budget_frac).astype(jnp.int32))               # (b,)
    blk = jnp.arange(nblk)
    is_valid = blk[None, :] < n_valid[:, None]                   # (b, n)
    is_sink = blk < cfg.sink_blocks                              # (n,)
    is_local = (blk[None, :] >= n_valid[:, None] - cfg.local_blocks) & is_valid
    forced = (is_sink[None, :] | is_local)[:, None, None, :]     # (b,1,1,n)
    biased = jnp.where(forced, m + selection_lib.FORCE_BONUS, m)
    biased = jnp.where(is_valid[:, None, None, :], biased, NEG_INF)

    k_max = decode_budget_bound(nblk, cfg, budget_frac)
    vals, idx = jax.lax.top_k(biased, k_max)                     # (b,hk,g,kmax)
    live = (vals > NEG_INF / 2) & (
        jnp.arange(k_max)[None, None, None, :] < k_budget[:, None, None, None])
    return DecodeSelection(indices=idx.astype(jnp.int32), live=live,
                           budgets=k_budget, n_valid=n_valid)


# ---------------------------------------------------------------------------
# Stage 3: exact attention over gathered blocks
# ---------------------------------------------------------------------------

def attend_selected(
    q: jnp.ndarray,            # (b, hq, 1, d)
    gk: jnp.ndarray,           # (b, hk, g, k_max, bs, d) gathered key blocks
    gv: jnp.ndarray,           # (b, hk, g, k_max, bs, dv)
    sel: DecodeSelection,
    cache_lens: jnp.ndarray,   # scalar or (b,)
    block_size: int,
) -> jnp.ndarray:
    """Masked softmax over the selected blocks only.  Returns (b, hq, 1, dv)."""
    b, hq, _, d = q.shape
    hk = gk.shape[1]
    group = hq // hk
    bs = block_size
    cache_lens = jnp.broadcast_to(jnp.asarray(cache_lens, jnp.int32), (b,))
    qg = q.reshape(b, hk, group, 1, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhgnkd->bhgqnk", qg, gk.astype(jnp.float32))
    s = s * (d ** -0.5)                                    # (b,hk,g,1,kmax,bs)
    tok_pos = sel.indices[..., None] * bs + jnp.arange(bs)  # (b,hk,g,kmax,bs)
    keep = (tok_pos < cache_lens[:, None, None, None, None]) & sel.live[..., None]
    s = jnp.where(keep[:, :, :, None], s, NEG_INF)
    p = jax.nn.softmax(s.reshape(b, hk, group, 1, -1), axis=-1).reshape(s.shape)
    p = jnp.where(keep[:, :, :, None], p, 0.0)
    o = jnp.einsum("bhgqnk,bhgnkd->bhgqd", p, gv.astype(jnp.float32))
    return o.reshape(b, hq, 1, gv.shape[-1]).astype(q.dtype)


def sparse_decode_attention(
    q: jnp.ndarray,           # (b, hq, 1, d) — one new query token
    cache_k: jnp.ndarray,     # (b, hk, L, d)
    cache_v: jnp.ndarray,
    summary: BlockSummary,
    cache_lens: Union[jnp.ndarray, int],   # scalar or (b,) valid prefixes
    cfg: StemConfig,
    budget_frac: float = 0.25,
) -> jnp.ndarray:
    """OAM block selection + exact attention over selected cache blocks.

    ``cache_lens`` is per-sequence: a scalar applies one length to every
    row; a ``(b,)`` vector gives each row its own valid prefix (lengths not
    multiples of ``block_size`` are handled by token-level masking of the
    partial block).  At ``budget_frac=1.0`` every valid block is selected,
    so the result equals dense decode over each row's prefix exactly.
    """
    b, hq, _, d = q.shape
    hk = cache_k.shape[1]
    bs = cfg.block_size
    nblk = cache_k.shape[2] // bs

    m = decode_block_metric(q, summary.k_groups, summary.v_mag, cfg)
    sel = select_decode_blocks(m, cache_lens, cfg, budget_frac)

    dv = cache_v.shape[-1]
    kb = cache_k.reshape(b, hk, nblk, bs, d)
    vb = cache_v.reshape(b, hk, nblk, bs, dv)
    # gather along the block axis (3 after the g broadcast dim is inserted)
    gk = jnp.take_along_axis(kb[:, :, None], sel.indices[..., None, None], axis=3)
    gv = jnp.take_along_axis(vb[:, :, None], sel.indices[..., None, None], axis=3)
    return attend_selected(q, gk, gv, sel, cache_lens, bs)
