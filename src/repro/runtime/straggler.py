"""Straggler detection: per-step wall-time EMA with outlier flagging.

On a real fleet the monitor's callback would feed the control plane
(demote/replace the slow host, or trigger an elastic reshard via
runtime/elastic.py).  Here the detection logic itself is what we ship and
test — the policy hook is injectable.
"""
from __future__ import annotations

import time
from typing import Callable, Optional


class StragglerMonitor:
    def __init__(self, threshold: float = 2.5, ema_decay: float = 0.9,
                 warmup_steps: int = 3,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.threshold = threshold
        self.ema_decay = ema_decay
        self.warmup_steps = warmup_steps
        self.on_straggler = on_straggler
        self.ema: Optional[float] = None
        self.count = 0
        self.flagged: list[tuple[int, float, float]] = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def cancel(self) -> None:
        """Discard an in-flight timing (the timed step failed or did no
        work) without polluting the EMA baseline."""
        self._t0 = None

    def stop(self, step: int) -> float:
        assert self._t0 is not None, "start() not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        self.observe(step, dt)
        return dt

    def observe(self, step: int, dt: float) -> bool:
        """Feed one step time; returns True if flagged as a straggler."""
        self.count += 1
        is_straggler = False
        if self.ema is not None and self.count > self.warmup_steps:
            if dt > self.threshold * self.ema:
                is_straggler = True
                self.flagged.append((step, dt, self.ema))
                if self.on_straggler:
                    self.on_straggler(step, dt, self.ema)
        # Outliers don't poison the baseline.
        if self.ema is None:
            self.ema = dt
        elif not is_straggler:
            self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * dt
        return is_straggler
