from repro.runtime.straggler import StragglerMonitor
from repro.runtime.fault_tolerance import (FailureInjector, InjectedFailure,
                                           run_with_restarts)
from repro.runtime.chaos import ChaosConfig, ChaosInjector

__all__ = ["StragglerMonitor", "FailureInjector", "InjectedFailure",
           "run_with_restarts", "ChaosConfig", "ChaosInjector",
           "Request", "FinishedRequest", "EngineConfig", "StemEngine",
           "EngineStalledError", "PageAllocator", "PagePool",
           "HostPageStore"]


def __getattr__(name):
    # Lazy: engine/offload pull in jax/models; keep the lightweight runtime
    # imports (straggler/fault-tolerance/chaos) usable without tracing
    # machinery.
    if name in ("Request", "FinishedRequest", "EngineConfig", "StemEngine",
                "EngineStalledError"):
        from repro.runtime import engine as _engine
        return getattr(_engine, name)
    if name in ("PageAllocator", "PagePool"):
        from repro.runtime import paged as _paged
        return getattr(_paged, name)
    if name == "HostPageStore":
        from repro.runtime import offload as _offload
        return getattr(_offload, name)
    raise AttributeError(name)
