from repro.runtime.straggler import StragglerMonitor
from repro.runtime.fault_tolerance import (FailureInjector, InjectedFailure,
                                           run_with_restarts)

__all__ = ["StragglerMonitor", "FailureInjector", "InjectedFailure",
           "run_with_restarts"]
