from repro.runtime.straggler import StragglerMonitor
from repro.runtime.fault_tolerance import (FailureInjector, InjectedFailure,
                                           run_with_restarts)

__all__ = ["StragglerMonitor", "FailureInjector", "InjectedFailure",
           "run_with_restarts", "Request", "FinishedRequest", "EngineConfig",
           "StemEngine", "PageAllocator", "PagePool"]


def __getattr__(name):
    # Lazy: engine pulls in jax/models; keep the lightweight runtime imports
    # (straggler/fault-tolerance) usable without tracing machinery.
    if name in ("Request", "FinishedRequest", "EngineConfig", "StemEngine"):
        from repro.runtime import engine as _engine
        return getattr(_engine, name)
    if name in ("PageAllocator", "PagePool"):
        from repro.runtime import paged as _paged
        return getattr(_paged, name)
    raise AttributeError(name)
