"""Host page offload: swap a request's pages to host memory and back.

Preemption support for the serving engine (``runtime/engine.py``).  A
preempted request's device pages — raw K/V *and* the per-page Stem
selection summaries (``kg``/``vm``) and, implicitly, its cursor state held
by the engine — are gathered into a snapshot, copied to host numpy
buffers, and the device pages are returned to the ``PageAllocator``
free list (``allocator.evict``).  Re-admission allocates a fresh set of
physical pages (``allocator.restore``) and scatters the snapshot back
bit-identically; because a page carries its own OAM/SAM summaries, the
restored request resumes decode (or mid-prefill chunking) with **zero
recompute** — no prefill replay, no summary rebuild, no extra traces.

Both ``gather_pages`` and ``scatter_pages`` operate on the engine's
per-layer pool tree (``PagePool`` leaves stacked ``(n_layers, hk, P, ...)``)
with a fixed-width ``(max_pages_per_slot,)`` page-id row padded with the
trash page, so the engine jits each exactly once
(``launch/steps.make_page_extract`` / ``make_page_restore``).  Padding
slots gather/scatter the trash page, which holds garbage by design.

Prefix caching changes WHAT is snapshotted, not how: a preempted request's
prefix-SHARED pages are never gathered or scattered — their contents stay
on the device (co-tenants may be reading them) and the engine keeps one
pinned allocator reference per shared page for the duration of the
offload.  Only the privately-held suffix/decode pages round-trip through
this store; ``put(..., pinned=...)`` records the pinned ids per request so
the residency accounting stays honest.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.runtime import paged as paged_lib


def _is_pool(x) -> bool:
    return isinstance(x, paged_lib.PagePool)


def gather_pages(pools, page_row):
    """Extract the pages named by ``page_row`` from every layer's pool.

    pools: the engine pool tree, PagePool leaves stacked (n, hk, P, ...).
    page_row: (max_pages_per_slot,) int32 global page ids, trash-padded.
    Returns the same tree shape with the page axis narrowed to the row
    width — the device-side snapshot (copy to host with ``to_host``).
    """
    def one(pool: paged_lib.PagePool) -> paged_lib.PagePool:
        return paged_lib.PagePool(
            k=pool.k[:, :, page_row],
            v=pool.v[:, :, page_row],
            kg=pool.kg[:, :, page_row],
            vm=pool.vm[:, :, page_row],
        )

    return jax.tree.map(one, pools, is_leaf=_is_pool)


def scatter_pages(pools, page_row, snapshot):
    """Write a snapshot back into the pages named by ``page_row``.

    Exact inverse of ``gather_pages`` modulo page renaming: the snapshot's
    i-th page lands in ``page_row[i]``, which need not be the page it was
    gathered from — the engine's page-table row carries the new mapping.
    Trash-padding slots rewrite page 0 (garbage by design, harmless).
    """
    def one(pool: paged_lib.PagePool,
            snap: paged_lib.PagePool) -> paged_lib.PagePool:
        return paged_lib.PagePool(
            k=pool.k.at[:, :, page_row].set(snap.k),
            v=pool.v.at[:, :, page_row].set(snap.v),
            kg=pool.kg.at[:, :, page_row].set(snap.kg),
            vm=pool.vm.at[:, :, page_row].set(snap.vm),
        )

    return jax.tree.map(one, pools, snapshot, is_leaf=_is_pool)


def snapshot_nbytes(snapshot) -> int:
    total = 0
    for leaf in jax.tree.leaves(snapshot):
        if isinstance(leaf, HostShards):
            total += leaf.nbytes
        else:
            total += int(np.asarray(leaf).nbytes)
    return total


# ---------------------------------------------------------------------------
# Mesh-sharded snapshots (sharding/serving.py engines)
# ---------------------------------------------------------------------------

class HostShards:
    """One sharded device array as per-shard host buffers keyed by mesh
    coordinate ``(dp, tp)``.  Under tp>1 a snapshot leaf's KV-head axis is
    split across devices; copying each shard's bytes verbatim and putting
    them back on the device at the *same* mesh coordinate makes the
    preempt/restore round-trip bit-identical with no gather/reshard on
    either side.  Only the preempted slot group's shards are stored —
    other groups' rows in the fixed-width extract are trash-page garbage.
    """

    __slots__ = ("shards", "shape", "dtype")

    def __init__(self, shards: dict, shape, dtype):
        self.shards = shards            # (dp, tp) coord -> np.ndarray
        self.shape = tuple(shape)       # global (all-groups) shape
        self.dtype = dtype

    @property
    def nbytes(self) -> int:
        return sum(int(s.nbytes) for s in self.shards.values())


def _mesh_coords(mesh) -> dict:
    """Device id -> (dp, tp) mesh coordinate."""
    return {dev.id: tuple(int(i) for i in idx)
            for idx, dev in np.ndenumerate(mesh.devices)}


def shard_snapshot_to_host(snapshot, smesh, group: int):
    """Copy a mesh-sharded snapshot to host, keeping only slot group
    ``group``'s shards.  Snapshot leaves are ``(dp, n, hk, W, ...)`` placed
    with the pool sharding (dp over groups, tp over KV heads); each leaf
    becomes a :class:`HostShards` holding the dp==group blocks per tp
    coordinate."""
    coords = _mesh_coords(smesh.mesh)

    def one(x):
        shards = {}
        for sh in x.addressable_shards:
            c = coords[sh.device.id]
            if c[0] != group:
                continue
            shards[c] = np.asarray(sh.data)
        return HostShards(shards, x.shape, x.dtype)

    return jax.tree.map(one, snapshot)


def assemble_sharded_snapshot(host, smesh, group: int):
    """Inverse of ``shard_snapshot_to_host``: rebuild device-sharded
    snapshot leaves with group ``group``'s bytes at their original tp
    coordinates and zeros elsewhere (other groups' rows scatter into their
    trash page — garbage by design)."""
    from repro.sharding import serving as serving_lib

    mesh = smesh.mesh
    sharding = jax.sharding.NamedSharding(mesh, serving_lib.POOL_SPEC)

    def one(hs: HostShards):
        sample = next(iter(hs.shards.values()))
        arrs = []
        for idx, dev in np.ndenumerate(mesh.devices):
            c = tuple(int(i) for i in idx)
            buf = hs.shards.get(c)
            if buf is None:
                buf = np.zeros(sample.shape, hs.dtype)
            arrs.append(jax.device_put(buf, dev))
        return jax.make_array_from_single_device_arrays(
            hs.shape, sharding, arrs)

    return jax.tree.map(
        one, host, is_leaf=lambda x: isinstance(x, HostShards))


class HostPageStore:
    """Host-side store of offloaded page snapshots, keyed by request uid.

    ``put`` forces the device snapshot onto the host (numpy) so the device
    pages can be reused immediately; ``pop`` hands the numpy tree back for
    the jitted scatter (shapes/dtypes are fixed, so restore never retraces).
    Tracks resident and peak bytes for the engine's metrics.
    """

    def __init__(self):
        self._store: dict = {}
        self._pinned: dict = {}      # uid -> device pages pinned, not copied
        self.nbytes = 0
        self.peak_nbytes = 0
        self.total_offloads = 0

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, uid) -> bool:
        return uid in self._store

    def put(self, uid, snapshot, pinned=()) -> None:
        """Store a request's private-page snapshot.  ``pinned`` lists the
        prefix-shared device pages that stay resident on the device (the
        engine holds one allocator reference each) — recorded for
        observability, no bytes copied."""
        if uid in self._store:
            raise ValueError(f"request {uid} already offloaded")

        def to_host(x):
            if isinstance(x, HostShards):    # already host-resident shards
                return x
            return np.asarray(jax.device_get(x))

        host = jax.tree.map(to_host, snapshot,
                            is_leaf=lambda x: isinstance(x, HostShards))
        self._store[uid] = host
        self._pinned[uid] = list(pinned)
        self.nbytes += snapshot_nbytes(host)
        self.peak_nbytes = max(self.peak_nbytes, self.nbytes)
        self.total_offloads += 1

    def get(self, uid):
        return self._store[uid]

    def pinned(self, uid) -> list:
        """Device pages this offloaded request keeps pinned (shared prefix)."""
        return list(self._pinned.get(uid, ()))

    def pop(self, uid):
        snap = self._store.pop(uid)
        self._pinned.pop(uid, None)
        self.nbytes -= snapshot_nbytes(snap)
        return snap

    def drop(self, uid) -> None:
        """Discard a snapshot without restoring (aborted request)."""
        if uid in self._store:
            self.pop(uid)
