"""Failure injection + restart-from-checkpoint orchestration.

``run_with_restarts`` wraps a training function that (a) restores from the
latest checkpoint on entry and (b) may die at any step.  The harness
restarts it up to ``max_restarts`` times — the single-process analogue of a
cluster controller rescheduling a failed job, with the checkpoint manager +
seekable data pipeline guaranteeing bit-identical continuation (tested).
"""
from __future__ import annotations

from typing import Callable, Optional


class InjectedFailure(RuntimeError):
    pass


class FailureInjector:
    """Raises at configured steps — ``repeats`` times per step (default
    once: the restarted/retried pass sails through cleanly, like a real
    transient node failure; ``repeats > 1`` models a persistent fault that
    outlives bounded retry).  The serving-side chaos harness
    (``runtime/chaos.py``) composes several of these, one per injection
    channel (allocator, step, restore)."""

    def __init__(self, fail_at_steps: tuple[int, ...] = (), repeats: int = 1):
        self.remaining = {s: repeats for s in fail_at_steps}
        self.fired = 0

    def should_fail(self, step: int) -> bool:
        """Consume one configured failure at ``step`` if any remain."""
        if self.remaining.get(step, 0) > 0:
            self.remaining[step] -= 1
            self.fired += 1
            return True
        return False

    def maybe_fail(self, step: int) -> None:
        if self.should_fail(step):
            raise InjectedFailure(f"injected node failure at step {step}")


def run_with_restarts(train_fn: Callable[[], object], max_restarts: int = 3,
                      on_restart: Optional[Callable[[int, Exception], None]] = None):
    """Run ``train_fn`` to completion, restarting on failure.

    train_fn must be restart-safe: it restores state from its checkpoint
    manager at entry.  Returns train_fn's result.
    """
    attempt = 0
    while True:
        try:
            return train_fn()
        except InjectedFailure as e:   # noqa: PERF203
            attempt += 1
            if attempt > max_restarts:
                raise
            if on_restart:
                on_restart(attempt, e)
