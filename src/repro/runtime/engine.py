"""Continuous-batching serving engine over the paged Stem KV cache.

Requests with arbitrary prompt lengths arrive over time, are admitted into
a fixed set of *slots* as capacity frees up, and make progress together in
**one jitted mixed-batch step per iteration** — vLLM-shaped continuous
batching with Stem's coarse-to-fine selection running natively on the page
pool (a page *is* a Stem block; see ``runtime/paged.py``).

Unified step (the default): prefill is **chunked**.  A slot admitted with a
long prompt does not stall its co-tenants behind a monolithic prefill;
instead it advances ``chunk_size`` tokens per engine step through the
chunked-prefill lane of the single jitted ``unified_step``
(``launch/steps.make_unified_step`` -> ``transformer.paged_mixed_step``),
riding in the same trace as every decode token.  The step's shapes are
fixed — a (slots, 1) decode lane plus a narrow (chunk_lanes, chunk_size)
prefill lane (lanes = the most whole chunks the token budget admits,
typically 1) — so the engine compiles each of its two signatures (mixed,
and decode-only for chunk-free steps) **exactly once**, independent of
prompt lengths (``stats["traces"]``; pinned by ``tests/test_engine.py``).
The old monolithic path retraced per padded prompt-length bucket.

Engine loop (one ``step()``):

  1. **Admission** — FCFS from the waiting queue, gated on arrival step, a
     free slot, and an all-or-nothing page reservation for the request's
     whole lifetime.  Chunked mode resets the reserved pages to pristine
     and parks the slot in the ``prefill`` phase with a ``prefill_pos``
     cursor; monolithic mode (``EngineConfig.monolithic_prefill``, the A/B
     baseline) runs the legacy per-length-bucket prefill inline.
  2. **Token-budget scheduling** — each step spends at most
     ``step_token_budget`` tokens: every decode-phase slot's token first,
     then prefill chunks FCFS while whole chunks fit (at least one chunk is
     granted when prefill work exists and nothing else would run, so the
     engine never stalls).  This bounds per-step latency: long prompts cost
     many small steps instead of one huge one.
  3. **Mixed step** — one jitted call advances every granted lane.  Decode
     slots append + sample greedily; prefill slots advance their cursor,
     and the chunk that completes a prompt yields the request's first
     token (TTFT) from the chunk-lane logits.
  4. **Recycling** — slots hitting EOS / max-new-tokens free their pages
     and return to the free list; the next ``step()`` re-admits.

Latency accounting: ``token_latencies_s`` records **inter-token gaps** as
experienced by the request (time between consecutive emissions — this is
what surfaces head-of-line blocking stalls), ``ttft_s`` the admission ->
first-token wall, and ``tpot_s`` the mean per-output-token time after the
first.  ``benchmarks/serving.py`` reports them separately.

Determinism / batch-invariance: every per-slot computation in both lanes
is row-parallel (selection, gather, softmax), and chunk boundaries depend
only on ``chunk_size`` — so a request's token stream is bitwise independent
of which slot it occupies, who its co-tenants are, and how the token budget
interleaves its chunks.  ``tests/test_engine.py`` pins this differentially.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunked as chunked_lib
from repro.launch import steps as steps_lib
from repro.models import transformer
from repro.runtime import paged as paged_lib


@dataclasses.dataclass
class Request:
    """One generation request."""
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival_step: int = 0         # engine step at which the request exists


@dataclasses.dataclass
class FinishedRequest:
    uid: int
    prompt_len: int
    tokens: list                  # generated token ids (greedy)
    slot: int
    admitted_step: int
    finished_step: int
    ttft_s: float                 # admission -> first token (all chunks)
    tpot_s: float                 # mean per-output-token time after the
                                  # first (NaN when only one token: undefined)
    token_latencies_s: list       # inter-token gaps (includes HOL stalls)


def pages_needed(prompt_len: int, max_new: int, page_size: int) -> int:
    """Pages a request holds for its whole lifetime.  Tokens ever cached:
    the prompt plus every generated token that is fed back (the final one
    is not)."""
    cached = prompt_len + max(max_new - 1, 0)
    return -(-cached // page_size)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Sizing + policy knobs of the serving engine.

    ``num_pages`` includes the reserved trash page 0.  A request needs
    ``pages_needed(prompt_len, max_new_tokens, page_size)`` pages for its
    whole lifetime (conservative up-front reservation — no mid-flight OOM),
    and at most ``max_pages_per_slot`` (the static page-table width).

    ``chunk_size`` (tokens, a multiple of the page size; None = 2 pages)
    fixes the prefill-lane width of the unified step;
    ``step_token_budget`` (None = max_slots + chunk_size) caps the tokens
    one step may spend — decode tokens first, then whole prefill chunks.
    ``monolithic_prefill`` switches to the legacy per-length-trace
    admission prefill (the chunked-vs-monolithic A/B baseline, and the
    fallback for threshold selectors chunked prefill cannot serve)."""
    max_slots: int = 4
    num_pages: int = 64
    max_pages_per_slot: int = 16
    budget_frac: float = 1.0      # 1.0 = dense-equivalent oracle arm
    eos_id: Optional[int] = None
    chunk_size: Optional[int] = None
    step_token_budget: Optional[int] = None
    monolithic_prefill: bool = False

    @classmethod
    def for_trace(cls, *, max_slots: int, max_prompt: int,
                  max_new_tokens: int, page_size: int,
                  budget_frac: float = 1.0,
                  eos_id: Optional[int] = None,
                  chunk_size: Optional[int] = None,
                  step_token_budget: Optional[int] = None,
                  monolithic_prefill: bool = False) -> "EngineConfig":
        """Size the pool so every slot can hold the largest trace request —
        the one place the reservation rule is encoded for drivers."""
        per_slot = pages_needed(max_prompt, max_new_tokens, page_size)
        return cls(max_slots=max_slots, num_pages=1 + max_slots * per_slot,
                   max_pages_per_slot=per_slot, budget_frac=budget_frac,
                   eos_id=eos_id, chunk_size=chunk_size,
                   step_token_budget=step_token_budget,
                   monolithic_prefill=monolithic_prefill)


@dataclasses.dataclass
class _SlotState:
    req: Request
    tokens: list
    admitted_step: int
    admit_t: float
    phase: str                    # "prefill" | "decode"
    prefill_pos: int              # next absolute prompt position to process
    padded: np.ndarray            # (Lp,) prompt right-padded to a page multiple
    true_len: int
    ttft_s: float = 0.0
    first_token_t: float = 0.0
    last_token_t: float = 0.0
    token_latencies_s: list = dataclasses.field(default_factory=list)


class StemEngine:
    """Continuous-batching engine; host-side scheduler + one jitted step.

    ``stem_cfg`` names the engine's sparsity policy: a ``SparsityPolicy``,
    a registered policy name (``"stem"``, ``"streaming"``, …) or a legacy
    ``StemConfig``.  One policy drives chunked prefill page summaries,
    chunk selection, and decode page selection alike."""

    def __init__(self, bundle, params, stem_cfg,
                 ecfg: EngineConfig = EngineConfig()):
        from repro.core import policy as policy_lib

        transformer.assert_paged_servable(bundle.cfg)
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.params = params
        self.policy = policy_lib.as_policy(stem_cfg)
        self.stem_cfg = self.policy          # legacy attribute name
        self.ecfg = ecfg
        self.page_size = self.policy.block_size
        self.chunk_size = ecfg.chunk_size or 2 * self.page_size
        if self.chunk_size % self.page_size:
            raise ValueError(
                f"chunk_size {self.chunk_size} must be a multiple of the "
                f"page size {self.page_size}")
        self.token_budget = (ecfg.step_token_budget
                             or ecfg.max_slots + self.chunk_size)
        # Static width of the chunked-prefill lane: the most whole chunks
        # the token budget could ever admit in one step.
        self.chunk_lanes = min(ecfg.max_slots,
                               max(1, self.token_budget // self.chunk_size))
        if not ecfg.monolithic_prefill:
            chunked_lib.validate_chunked_policy(self.policy)

        S, P = ecfg.max_slots, ecfg.max_pages_per_slot
        self.pools = transformer.init_page_pools(
            bundle.cfg, ecfg.num_pages, self.policy)
        self.allocator = paged_lib.PageAllocator(ecfg.num_pages)
        self.page_table = np.zeros((S, P), np.int32)
        self.cache_lens = np.zeros((S,), np.int32)
        self.slot_pages: list = [None] * S     # page ids held by each slot
        self.slots: list = [None] * S          # _SlotState | None
        self.waiting: collections.deque = collections.deque()
        self.finished: list = []
        self.step_count = 0
        self.stats = {"prefills": 0, "chunks": 0, "decode_steps": 0,
                      "step_calls": 0, "tokens_generated": 0,
                      "slots_reused": 0, "max_concurrency": 0,
                      "traces": 0, "prefill_traces": 0}
        self._slot_ever_used = [False] * S

        def _count(key):
            def bump():
                self.stats[key] += 1
            return bump

        # THE step: decode lane + chunked-prefill lane, fixed shapes.
        # ``chunk_k_max`` is the static chunk-selection/gather width: the
        # largest block budget any admissible prompt can reach, so chunk
        # cost tracks the policy's budget, not the page-table width.
        # ``stats["traces"]`` counts (re)compiles via a trace-time side
        # effect — the regression test pins it to the two lane signatures
        # (mixed / decode-only) across heterogeneous prompt lengths.
        k_bound = (0 if ecfg.monolithic_prefill else
                   chunked_lib.chunk_budget_bound(self.policy, P))
        self._unified = jax.jit(steps_lib.make_unified_step(
            bundle, stem_cfg=self.policy, budget_frac=ecfg.budget_frac,
            chunk_k_max=k_bound, on_trace=_count("traces")),
            donate_argnums=(1,))
        self._reset = jax.jit(paged_lib.reset_pools_stacked,
                              donate_argnums=(0,))
        self._prefill = None
        if ecfg.monolithic_prefill:
            # Legacy A/B arm: one trace per padded prompt-length bucket.
            self._prefill = jax.jit(steps_lib.make_monolithic_prefill(
                bundle, stem_cfg=self.policy,
                on_trace=_count("prefill_traces")), donate_argnums=(3,))

    # -- scheduling ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        npages = self._pages_needed(len(req.prompt), req.max_new_tokens)
        if npages > self.ecfg.max_pages_per_slot:
            raise ValueError(
                f"request {req.uid} needs {npages} pages > max_pages_per_slot "
                f"{self.ecfg.max_pages_per_slot}")
        self.waiting.append(req)

    def _pages_needed(self, prompt_len: int, max_new: int) -> int:
        return pages_needed(prompt_len, max_new, self.page_size)

    def reset_metrics(self) -> None:
        """Zero the workload observability state (finished list, counters,
        slot-reuse tracking) without touching pools, slots, or the
        allocator — e.g. after a benchmark warmup pass.  Trace counters are
        *kept*: they record compiles over the engine's lifetime (a warmed
        engine adds zero), and benchmarks report them as evidence of the
        no-retrace property."""
        self.finished.clear()
        keep = ("traces", "prefill_traces")
        self.stats.update({k: 0 for k in self.stats if k not in keep})
        self._slot_ever_used = [False] * self.ecfg.max_slots

    def _free_slot(self) -> Optional[int]:
        for s, st in enumerate(self.slots):
            if st is None:
                return s
        return None

    # -- engine iteration ---------------------------------------------------

    def _admit(self) -> None:
        while self.waiting:
            req = self.waiting[0]
            if req.arrival_step > self.step_count:
                break                           # not arrived yet (FCFS gate)
            slot = self._free_slot()
            if slot is None:
                break
            npages = self._pages_needed(len(req.prompt), req.max_new_tokens)
            pages = self.allocator.alloc(npages)
            if pages is None:
                break                           # no memory — head-of-line waits
            self.waiting.popleft()

            plen = len(req.prompt)
            npages_prompt = -(-plen // self.page_size)
            padded_len = npages_prompt * self.page_size
            # Full reservation, trash-padded.
            row = np.zeros((self.ecfg.max_pages_per_slot,), np.int32)
            row[:npages] = pages
            if self._slot_ever_used[slot]:
                self.stats["slots_reused"] += 1
            self._slot_ever_used[slot] = True
            self.page_table[slot] = row
            self.slot_pages[slot] = pages
            now = time.perf_counter()

            if self.ecfg.monolithic_prefill:
                # Legacy: prefill the whole prompt at admission (resets the
                # reserved pages inside prefill_kv_pages), per-length trace.
                toks = np.zeros((1, padded_len), np.int32)
                toks[0, :plen] = req.prompt
                logits, self.pools = self._prefill(
                    self.params, jnp.asarray(toks),
                    jnp.asarray(plen, jnp.int32), self.pools,
                    jnp.asarray(row))
                first = int(np.argmax(np.asarray(logits)))
                done = time.perf_counter()
                self.stats["prefills"] += 1
                self.stats["tokens_generated"] += 1
                self.cache_lens[slot] = plen
                st = _SlotState(
                    req=req, tokens=[first], admitted_step=self.step_count,
                    admit_t=now, phase="decode", prefill_pos=padded_len,
                    padded=np.zeros((0,), np.int32), true_len=plen,
                    ttft_s=done - now, first_token_t=done, last_token_t=done)
                self.slots[slot] = st
                if self._is_finished(st):
                    self._recycle(slot)
                continue

            # Chunked: reset the reservation to pristine (recycled pages are
            # dirty; chunk writes + decode increments assume fresh pages),
            # park the slot mid-prefill with a prefill_pos cursor.
            self.pools = self._reset(self.pools, jnp.asarray(row))
            ptoks = np.zeros((padded_len,), np.int32)
            ptoks[:plen] = req.prompt
            self.cache_lens[slot] = 0
            self.slots[slot] = _SlotState(
                req=req, tokens=[], admitted_step=self.step_count,
                admit_t=now, phase="prefill", prefill_pos=0, padded=ptoks,
                true_len=plen)

    def _is_finished(self, st: _SlotState) -> bool:
        if len(st.tokens) >= st.req.max_new_tokens:
            return True
        return self.ecfg.eos_id is not None and st.tokens[-1] == self.ecfg.eos_id

    def _recycle(self, slot: int) -> None:
        st = self.slots[slot]
        # TPOT is undefined for a single-output-token request (no
        # post-first token) — record NaN so means can exclude it.
        tpot = (float("nan") if len(st.tokens) < 2 else
                (st.last_token_t - st.first_token_t) / (len(st.tokens) - 1))
        self.finished.append(FinishedRequest(
            uid=st.req.uid, prompt_len=len(st.req.prompt), tokens=st.tokens,
            slot=slot, admitted_step=st.admitted_step,
            finished_step=self.step_count, ttft_s=st.ttft_s, tpot_s=tpot,
            token_latencies_s=st.token_latencies_s))
        self.allocator.free(self.slot_pages[slot])
        self.page_table[slot] = 0
        self.cache_lens[slot] = 0
        self.slot_pages[slot] = None
        self.slots[slot] = None

    def _mixed_step(self) -> None:
        """One unified-step invocation: every decode-phase slot's token plus
        as many prefill chunks as the token budget admits."""
        dec = [s for s, st in enumerate(self.slots)
               if st is not None and st.phase == "decode"]
        pre = [s for s, st in enumerate(self.slots)
               if st is not None and st.phase == "prefill"]
        if not dec and not pre:
            return
        self.stats["max_concurrency"] = max(self.stats["max_concurrency"],
                                            len(dec) + len(pre))

        # Token budget: decode tokens first, then whole chunks FCFS into the
        # static chunk lanes.  Always grant at least one chunk when prefill
        # work exists and no decode token would otherwise run (liveness).
        C = self.chunk_size
        remaining = self.token_budget - len(dec)
        grant = []
        for s in sorted(pre, key=lambda s: (self.slots[s].admitted_step, s)):
            if len(grant) >= self.chunk_lanes:
                break
            if remaining >= C or (not grant and not dec):
                grant.append(s)
                remaining -= C

        S, P = self.ecfg.max_slots, self.ecfg.max_pages_per_slot
        tokens = np.zeros((S, 1), np.int32)
        dec_table = np.zeros((S, P), np.int32)
        dec_lens = np.zeros((S,), np.int32)
        for s in dec:
            tokens[s, 0] = self.slots[s].tokens[-1]
            dec_table[s] = self.page_table[s]
            dec_lens[s] = self.cache_lens[s]

        chunk = None
        if grant:
            # Narrow chunked-prefill lane: L = chunk_lanes rows, lane i
            # carrying grant[i]'s next chunk.  With no grants the step runs
            # the decode-only signature — two static traces total, never
            # per-prompt-length.
            L, nc = self.chunk_lanes, C // self.page_size
            ctoks = np.zeros((L, C), np.int32)
            ctable = np.zeros((L, P), np.int32)
            cstart = np.zeros((L,), np.int32)
            ctrue = np.zeros((L,), np.int32)
            cbud = np.zeros((L, nc), np.int32)
            clast = np.zeros((L,), np.int32)
            for lane, s in enumerate(grant):
                st = self.slots[s]
                pos = st.prefill_pos
                avail = st.padded[pos:pos + C]
                ctoks[lane, :len(avail)] = avail
                ctable[lane] = self.page_table[s]
                cstart[lane] = pos
                ctrue[lane] = st.true_len
                cbud[lane] = chunked_lib.chunk_budget_rows(
                    self.policy, len(st.padded), pos, nc)
                clast[lane] = min(max(st.true_len - 1 - pos, 0), C - 1)
            chunk = {"tokens": jnp.asarray(ctoks),
                     "page_table": jnp.asarray(ctable),
                     "start": jnp.asarray(cstart),
                     "true_len": jnp.asarray(ctrue),
                     "budgets": jnp.asarray(cbud),
                     "last": jnp.asarray(clast)}

        dec_logits, chunk_logits, self.pools = self._unified(
            self.params, self.pools, jnp.asarray(tokens),
            jnp.asarray(dec_table), jnp.asarray(dec_lens), chunk)
        if dec:
            dec_logits = np.asarray(dec_logits)
        if grant:
            chunk_logits = np.asarray(chunk_logits)
        now = time.perf_counter()
        self.stats["step_calls"] += 1
        if dec:
            self.stats["decode_steps"] += 1

        for s in dec:
            self.cache_lens[s] += 1       # the fed-back token is now cached
            st = self.slots[s]
            st.tokens.append(int(np.argmax(dec_logits[s])))
            st.token_latencies_s.append(now - st.last_token_t)
            st.last_token_t = now
            self.stats["tokens_generated"] += 1
            if self._is_finished(st):
                self._recycle(s)

        for lane, s in enumerate(grant):
            st = self.slots[s]
            st.prefill_pos += C
            self.stats["chunks"] += 1
            if st.prefill_pos >= len(st.padded):
                # This chunk completed the prompt: its logits at the true
                # last token are the request's first generated token.
                st.tokens = [int(np.argmax(chunk_logits[lane]))]
                st.phase = "decode"
                self.cache_lens[s] = st.true_len
                st.first_token_t = st.last_token_t = now
                st.ttft_s = now - st.admit_t
                self.stats["prefills"] += 1
                self.stats["tokens_generated"] += 1
                if self._is_finished(st):
                    self._recycle(s)

    def step(self) -> None:
        """One engine iteration: admit, one mixed batched step, recycle."""
        self._admit()
        self._mixed_step()
        self.step_count += 1

    @property
    def pending(self) -> int:
        return len(self.waiting) + sum(st is not None for st in self.slots)

    def run(self, requests=(), max_steps: int = 100_000) -> list:
        """Drive submitted (+ given) requests to completion; returns
        FinishedRequests sorted by uid."""
        for r in requests:
            self.submit(r)
        while self.pending:
            if self.step_count >= max_steps:
                raise RuntimeError(f"engine stalled after {max_steps} steps")
            self.step()
        return sorted(self.finished, key=lambda f: f.uid)
