"""Continuous-batching serving engine over the paged Stem KV cache.

The first genuinely multi-tenant workload for the repo: requests with
arbitrary prompt lengths arrive over time, are admitted into a fixed set of
decode *slots* as capacity frees up, decode together in one ragged batched
step per iteration, and release their pages the moment they finish —
vLLM-shaped scheduling with Stem's coarse-to-fine selection running
natively on the page pool (a page *is* a Stem block; see
``runtime/paged.py``).

Engine loop (one ``step()``):

  1. **Admission** — FCFS from the waiting queue, gated on arrival step, a
     free slot, and an all-or-nothing page reservation for
     ``ceil((prompt_len + max_new_tokens - 1) / page_size)`` pages (the
     final generated token is never fed back, so never cached).  Admission
     runs the jitted ``insert_prefill`` (one trace per padded prompt-length
     bucket) which writes the prompt's K/V pages + block summaries into the
     pools and returns the first generated token.
  2. **Batched decode** — one jitted ``batched_decode`` over *all* slots
     (inactive slots scribble the reserved trash page and are ignored).
     Every active slot appends its token and samples greedily.
  3. **Recycling** — slots hitting EOS / max-new-tokens free their pages
     and return to the free list; the next ``step()`` can re-admit into
     them immediately.

Determinism / batch-invariance: every per-slot computation in the decode
step is row-parallel (selection, gather, softmax), so a request's token
stream is bitwise independent of which slot it occupies and who its
co-tenants are — ``tests/test_engine.py`` pins this differentially.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as steps_lib
from repro.models import transformer
from repro.runtime import paged as paged_lib


@dataclasses.dataclass
class Request:
    """One generation request."""
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival_step: int = 0         # engine step at which the request exists


@dataclasses.dataclass
class FinishedRequest:
    uid: int
    prompt_len: int
    tokens: list                  # generated token ids (greedy)
    slot: int
    admitted_step: int
    finished_step: int
    ttft_s: float                 # wall-clock prefill (admission) latency
    token_latencies_s: list       # wall-clock per generated token


def pages_needed(prompt_len: int, max_new: int, page_size: int) -> int:
    """Pages a request holds for its whole lifetime.  Tokens ever cached:
    the prompt plus every generated token that is fed back (the final one
    is not)."""
    cached = prompt_len + max(max_new - 1, 0)
    return -(-cached // page_size)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Sizing + policy knobs of the serving engine.

    ``num_pages`` includes the reserved trash page 0.  A request needs
    ``pages_needed(prompt_len, max_new_tokens, page_size)`` pages for its
    whole lifetime (conservative up-front reservation — no mid-flight OOM),
    and at most ``max_pages_per_slot`` (the static page-table width)."""
    max_slots: int = 4
    num_pages: int = 64
    max_pages_per_slot: int = 16
    budget_frac: float = 1.0      # 1.0 = dense-equivalent oracle arm
    eos_id: Optional[int] = None

    @classmethod
    def for_trace(cls, *, max_slots: int, max_prompt: int,
                  max_new_tokens: int, page_size: int,
                  budget_frac: float = 1.0,
                  eos_id: Optional[int] = None) -> "EngineConfig":
        """Size the pool so every slot can hold the largest trace request —
        the one place the reservation rule is encoded for drivers."""
        per_slot = pages_needed(max_prompt, max_new_tokens, page_size)
        return cls(max_slots=max_slots, num_pages=1 + max_slots * per_slot,
                   max_pages_per_slot=per_slot, budget_frac=budget_frac,
                   eos_id=eos_id)


@dataclasses.dataclass
class _SlotState:
    req: Request
    tokens: list
    admitted_step: int
    ttft_s: float
    token_latencies_s: list


class StemEngine:
    """Continuous-batching engine; host-side scheduler + jitted steps.

    ``stem_cfg`` names the engine's sparsity policy: a ``SparsityPolicy``,
    a registered policy name (``"stem"``, ``"streaming"``, …) or a legacy
    ``StemConfig``.  One policy drives prefill page summaries and decode
    page selection alike."""

    def __init__(self, bundle, params, stem_cfg,
                 ecfg: EngineConfig = EngineConfig()):
        from repro.core import policy as policy_lib

        transformer.assert_paged_servable(bundle.cfg)
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.params = params
        self.policy = policy_lib.as_policy(stem_cfg)
        self.stem_cfg = self.policy          # legacy attribute name
        self.ecfg = ecfg
        self.page_size = self.policy.block_size

        S, P = ecfg.max_slots, ecfg.max_pages_per_slot
        self.pools = transformer.init_page_pools(
            bundle.cfg, ecfg.num_pages, self.policy)
        self.allocator = paged_lib.PageAllocator(ecfg.num_pages)
        self.page_table = np.zeros((S, P), np.int32)
        self.cache_lens = np.zeros((S,), np.int32)
        self.slot_pages: list = [None] * S     # page ids held by each slot
        self.slots: list = [None] * S          # _SlotState | None
        self.waiting: collections.deque = collections.deque()
        self.finished: list = []
        self.step_count = 0
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens_generated": 0,
                      "slots_reused": 0, "max_concurrency": 0}
        self._slot_ever_used = [False] * S

        self._decode = jax.jit(steps_lib.make_batched_decode(
            bundle, stem_cfg=self.policy, budget_frac=ecfg.budget_frac),
            donate_argnums=(2,))
        # jit retraces per token shape: one trace per padded prompt-length
        # bucket, cached inside the one jitted callable.
        self._prefill = jax.jit(steps_lib.make_insert_prefill(
            bundle, stem_cfg=self.policy), donate_argnums=(3,))

    # -- scheduling ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        npages = self._pages_needed(len(req.prompt), req.max_new_tokens)
        if npages > self.ecfg.max_pages_per_slot:
            raise ValueError(
                f"request {req.uid} needs {npages} pages > max_pages_per_slot "
                f"{self.ecfg.max_pages_per_slot}")
        self.waiting.append(req)

    def _pages_needed(self, prompt_len: int, max_new: int) -> int:
        return pages_needed(prompt_len, max_new, self.page_size)

    def reset_metrics(self) -> None:
        """Zero the observability state (finished list, counters, slot-reuse
        tracking) without touching pools, slots, or the allocator — e.g.
        after a benchmark warmup pass."""
        self.finished.clear()
        self.stats.update({k: 0 for k in self.stats})
        self._slot_ever_used = [False] * self.ecfg.max_slots

    def _free_slot(self) -> Optional[int]:
        for s, st in enumerate(self.slots):
            if st is None:
                return s
        return None

    # -- engine iteration ---------------------------------------------------

    def _admit(self) -> None:
        while self.waiting:
            req = self.waiting[0]
            if req.arrival_step > self.step_count:
                break                           # not arrived yet (FCFS gate)
            slot = self._free_slot()
            if slot is None:
                break
            npages = self._pages_needed(len(req.prompt), req.max_new_tokens)
            pages = self.allocator.alloc(npages)
            if pages is None:
                break                           # no memory — head-of-line waits
            self.waiting.popleft()

            plen = len(req.prompt)
            npages_prompt = -(-plen // self.page_size)
            padded = npages_prompt * self.page_size
            toks = np.zeros((1, padded), np.int32)
            toks[0, :plen] = req.prompt
            # Full reservation, trash-padded: prefill resets every page in
            # the row (recycled pages carry the previous tenant's summaries)
            # before writing the leading npages_prompt prompt pages.
            row = np.zeros((self.ecfg.max_pages_per_slot,), np.int32)
            row[:npages] = pages
            t0 = time.perf_counter()
            logits, self.pools = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray(plen, jnp.int32),
                self.pools, jnp.asarray(row))
            first = int(np.argmax(np.asarray(logits)))
            ttft = time.perf_counter() - t0
            self.stats["prefills"] += 1
            if self._slot_ever_used[slot]:
                self.stats["slots_reused"] += 1
            self._slot_ever_used[slot] = True

            self.page_table[slot] = row
            self.cache_lens[slot] = plen
            self.slot_pages[slot] = pages
            self.slots[slot] = _SlotState(
                req=req, tokens=[first], admitted_step=self.step_count,
                ttft_s=ttft, token_latencies_s=[])
            self.stats["tokens_generated"] += 1
            if self._is_finished(self.slots[slot]):
                self._recycle(slot)

    def _is_finished(self, st: _SlotState) -> bool:
        if len(st.tokens) >= st.req.max_new_tokens:
            return True
        return self.ecfg.eos_id is not None and st.tokens[-1] == self.ecfg.eos_id

    def _recycle(self, slot: int) -> None:
        st = self.slots[slot]
        self.finished.append(FinishedRequest(
            uid=st.req.uid, prompt_len=len(st.req.prompt), tokens=st.tokens,
            slot=slot, admitted_step=st.admitted_step,
            finished_step=self.step_count, ttft_s=st.ttft_s,
            token_latencies_s=st.token_latencies_s))
        self.allocator.free(self.slot_pages[slot])
        self.page_table[slot] = 0
        self.cache_lens[slot] = 0
        self.slot_pages[slot] = None
        self.slots[slot] = None

    def _decode_all(self) -> None:
        active = [s for s, st in enumerate(self.slots) if st is not None]
        if not active:
            return
        self.stats["max_concurrency"] = max(self.stats["max_concurrency"],
                                            len(active))
        tokens = np.zeros((self.ecfg.max_slots, 1), np.int32)
        for s in active:
            tokens[s, 0] = self.slots[s].tokens[-1]
        t0 = time.perf_counter()
        logits, self.pools = self._decode(
            self.params, jnp.asarray(tokens), self.pools,
            jnp.asarray(self.page_table), jnp.asarray(self.cache_lens))
        logits = np.asarray(logits)
        dt = time.perf_counter() - t0
        self.stats["decode_steps"] += 1
        for s in active:
            self.cache_lens[s] += 1       # the fed-back token is now cached
            nxt = int(np.argmax(logits[s]))
            st = self.slots[s]
            st.tokens.append(nxt)
            # every active request waits the whole batched step for its
            # token, so the step wall-time IS the per-token latency
            st.token_latencies_s.append(dt)
            self.stats["tokens_generated"] += 1
            if self._is_finished(st):
                self._recycle(s)

    def step(self) -> None:
        """One engine iteration: admit, decode every active slot, recycle."""
        self._admit()
        self._decode_all()
        self.step_count += 1

    @property
    def pending(self) -> int:
        return len(self.waiting) + sum(st is not None for st in self.slots)

    def run(self, requests=(), max_steps: int = 100_000) -> list:
        """Drive submitted (+ given) requests to completion; returns
        FinishedRequests sorted by uid."""
        for r in requests:
            self.submit(r)
        while self.pending:
            if self.step_count >= max_steps:
                raise RuntimeError(f"engine stalled after {max_steps} steps")
            self.step()
        return sorted(self.finished, key=lambda f: f.uid)
