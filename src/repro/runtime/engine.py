"""Continuous-batching serving engine over the paged Stem KV cache.

Requests with arbitrary prompt lengths arrive over time, are admitted into
a fixed set of *slots* as capacity frees up, and make progress together in
**one jitted mixed-batch step per iteration** — vLLM-shaped continuous
batching with Stem's coarse-to-fine selection running natively on the page
pool (a page *is* a Stem block; see ``runtime/paged.py``).

Unified step (the default): prefill is **chunked**.  A slot admitted with a
long prompt does not stall its co-tenants behind a monolithic prefill;
instead it advances ``chunk_size`` tokens per engine step through the
chunked-prefill lane of the single jitted ``unified_step``
(``launch/steps.make_unified_step`` -> ``transformer.paged_mixed_step``),
riding in the same trace as every decode token.  The step's shapes are
fixed — a (slots, 1) decode lane plus a narrow (chunk_lanes, chunk_size)
prefill lane (lanes = the most whole chunks the token budget admits,
typically 1) — so the engine compiles each of its two signatures (mixed,
and decode-only for chunk-free steps) **exactly once**, independent of
prompt lengths (``stats["traces"]``; pinned by ``tests/test_engine.py``).
The old monolithic path retraced per padded prompt-length bucket.

Engine loop (one ``step()``):

  1. **Load shedding** — when ``EngineConfig.max_waiting`` bounds the
     waiting queue, overflow rejects the lowest-priority (newest among
     ties) pending request as an explicitly *failed* ``FinishedRequest``
     (``error="shed: ..."``) instead of growing the queue without bound.
  2. **Admission** — ordered by ``(priority desc, arrival order)`` under
     the SLO scheduler (``EngineConfig.scheduler="slo"``; ``"fcfs"`` is
     the PR 5 baseline), gated on arrival step, a free slot, and an
     all-or-nothing page reservation for the request's whole lifetime.
     When a high-priority request is slot- or memory-blocked, admission
     may **preempt** a strictly-lower-priority running request: the
     victim's pages (K/V + kg/vm selection summaries) are gathered to a
     host snapshot (``runtime/offload.py``), its device pages are evicted
     back to the allocator, and it re-admits later by scattering the
     snapshot into freshly allocated pages **bit-identically** — a page
     carries its own selection summaries, so re-admission needs *zero*
     prefill recompute and adds zero traces.
  3. **Token-budget scheduling** — each step spends at most
     ``step_token_budget`` tokens.  Decode tokens go first, ordered by
     ``(priority, SLO headroom, least-recently-served)`` (FCFS: admission
     order); decodes beyond the budget are deferred to later steps.  Then
     whole prefill chunks fill the remaining budget in the same priority
     order.  When decode-lane TPOT pressure is high (a decode was deferred
     or a TPOT SLO is being violated) the chunk grant is adaptively capped
     at one lane; a prefill-phase slot that has gone ``chunk_starve_steps``
     engine steps without any chunk grant receives one anyway (bounded
     overdraft — decode saturation cannot starve prefill forever).
  4. **Mixed step** — one jitted call advances every granted lane, wrapped
     in the failure boundary: an injected/step exception *before* any pool
     mutation is retried up to ``max_step_retries`` times, after which the
     engine degrades by aborting its lowest-priority active request (a
     failed ``FinishedRequest``, never a crashed engine) and retrying with
     the smaller batch.  ``StragglerMonitor`` times every working step;
     flagged outliers surface in ``engine.metrics``.
  5. **Recycling** — slots hitting EOS / max-new-tokens free their pages
     and return to the free list; the next ``step()`` re-admits.  Page
     accounting is asserted (``PageAllocator.check_conservation``) after
     every recovery path: no orphaned pages, no double bookkeeping.

Latency accounting: ``token_latencies_s`` records **inter-token gaps** as
experienced by the request (time between consecutive emissions — this is
what surfaces head-of-line blocking *and* swapped-out time), ``ttft_s``
the admission -> first-token wall, and ``tpot_s`` the mean per-output-token
time after the first.  ``benchmarks/serving.py`` reports them separately,
split by priority class in the overload study (``BENCH_slo.json``).

Determinism / batch-invariance: every per-slot computation in both lanes
is row-parallel (selection, gather, softmax), and chunk boundaries depend
only on ``chunk_size`` — so a request's token stream is bitwise independent
of which slot it occupies, who its co-tenants are, how the token budget
interleaves its chunks, and whether it was preempted and restored along
the way.  ``tests/test_engine.py`` and ``tests/test_preemption.py`` pin
this differentially.

Async pipeline (``EngineConfig.async_depth=1``): sampling moves inside
the jitted step (``runtime/sampling.py`` + ``paged_sampled_step``), the
fed-back decode tokens live in a device-resident buffer, and the host
dispatches step N+1 from the previous scheduler state while step N's
sampled ids are still in flight — EOS is reconciled one step late, the
single speculative step of a finished request writes only into its own
still-reserved pages, and the emitted streams stay bit-identical to the
``async_depth=0`` synchronous oracle (``tests/test_async_engine.py``).
The only per-step transfer is the ``(slots,) int32`` id array, and
``stats["host_syncs"]`` (blocking fetches with no newer step queued
behind them) drops from O(steps) to O(finished requests).
"""
from __future__ import annotations

import collections
import dataclasses
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunked as chunked_lib
from repro.launch import steps as steps_lib
from repro.models import transformer
from repro.runtime import offload as offload_lib
from repro.runtime import paged as paged_lib
from repro.runtime import sampling as sampling_lib
from repro.runtime.fault_tolerance import InjectedFailure
from repro.runtime.straggler import StragglerMonitor


class EngineStalledError(RuntimeError):
    """``StemEngine.run`` hit its step cap with requests still in flight.

    Carries the stuck uids so the operator can see *what* is wedged
    (running / waiting / preempted) instead of a silent partial result."""

    def __init__(self, max_steps: int, running: list, waiting: list,
                 preempted: list):
        self.running, self.waiting, self.preempted = running, waiting, preempted
        super().__init__(
            f"engine stalled: {max_steps} steps without draining; stuck "
            f"requests: running uids {running}, waiting uids {waiting}, "
            f"preempted uids {preempted}")


@dataclasses.dataclass
class Request:
    """One generation request.

    ``priority``: higher wins admission, decode-token grants, and may
    preempt strictly-lower-priority running requests (SLO scheduler only).
    ``ttft_slo_s`` / ``tpot_slo_s``: optional latency targets; the
    scheduler orders equal-priority work by remaining SLO headroom."""
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival_step: int = 0         # engine step at which the request exists
    priority: int = 0
    ttft_slo_s: Optional[float] = None
    tpot_slo_s: Optional[float] = None


@dataclasses.dataclass
class FinishedRequest:
    uid: int
    prompt_len: int
    tokens: list                  # generated token ids (greedy)
    slot: int
    admitted_step: int
    finished_step: int
    ttft_s: float                 # arrival -> first token (queueing included)
    tpot_s: float                 # mean per-output-token time after the
                                  # first (NaN when only one token: undefined)
    token_latencies_s: list       # inter-token gaps (includes HOL stalls
                                  # and swapped-out time while preempted)
    priority: int = 0
    preemptions: int = 0          # times swapped out to host and restored
    queue_s: float = 0.0          # arrival -> admission wait (in ttft_s too)
    error: Optional[str] = None   # None = finished; else shed/abort reason


def pages_needed(prompt_len: int, max_new: int, page_size: int) -> int:
    """Pages a request holds for its whole lifetime.  Tokens ever cached:
    the prompt plus every generated token that is fed back (the final one
    is not)."""
    cached = prompt_len + max(max_new - 1, 0)
    return -(-cached // page_size)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Sizing + policy knobs of the serving engine.

    ``num_pages`` includes the reserved trash page 0.  A request needs
    ``pages_needed(prompt_len, max_new_tokens, page_size)`` pages for its
    whole lifetime (conservative up-front reservation — no mid-flight OOM),
    and at most ``max_pages_per_slot`` (the static page-table width).

    ``chunk_size`` (tokens, a multiple of the page size; None = 2 pages)
    fixes the prefill-lane width of the unified step;
    ``step_token_budget`` (None = max_slots + chunk_size) caps the tokens
    one step may spend — decode tokens first, then whole prefill chunks.
    ``monolithic_prefill`` switches to the legacy per-length-trace
    admission prefill (the chunked-vs-monolithic A/B baseline, and the
    fallback for threshold selectors chunked prefill cannot serve).

    ``prefix_cache`` enables hash-keyed prefix-page sharing: admission
    probes the allocator's prefix index per whole prompt page, maps hits
    read-only into the slot's page table, and starts the chunked
    ``prefill_pos`` cursor past the matched prefix — only the unmatched
    suffix is prefilled and only suffix pages are newly allocated.
    Completed prompts register their full pages for future tenants.
    Requires chunked prefill (the skip is chunk-granular), so it is
    mutually exclusive with ``monolithic_prefill``.

    Overload-resilience knobs:
      ``scheduler``          "slo" (priority + SLO-headroom ordering,
                             preemption-capable) or "fcfs" (the PR 5
                             baseline: admission order everywhere, no
                             preemption).  With every request at the
                             default priority and no SLOs, "slo" degrades
                             to exactly "fcfs".
      ``preemption``         allow admission to evict strictly-lower-
                             priority running requests to host memory.
      ``max_waiting``        waiting-queue bound; overflow sheds the
                             lowest-priority pending request as a failed
                             FinishedRequest (None = unbounded).
      ``max_step_retries``   bounded retry of a failed mixed step before
                             degrading (abort lowest-priority active).
      ``max_restore_retries``retries of a failed offload-restore before
                             the request is aborted with an error.
      ``chunk_starve_steps`` max engine steps a waiting prefill can go
                             without any chunk grant before one is forced
                             (budget overdraft; liveness under decode
                             saturation).
      ``straggler_threshold``step-time outlier factor for the wired-in
                             StragglerMonitor (``engine.metrics``).

    Mesh-sharded serving (``sharding/serving.py``):
      ``mesh``               ``(dp, tp)`` — shard the page pools over a
                             device mesh: tp splits the KV-head axis
                             (shard-local selection + attention, one
                             all-gather at the output projection, bitwise
                             identical to single-device), dp adds
                             independent slot groups each with
                             ``max_slots`` slots and ``num_pages`` pages
                             driven through the same two traces.  None =
                             single device (the default, untouched path).
      ``prefix_evict``       cached prefix-page reclaim order: "lru"
                             (default) or "hit-rate" (fewest prefix hits
                             first; ties LRU).
      ``admission_control``  SLO-aware admission control: reject an
                             arrived request with an explicit error when
                             its TTFT SLO is infeasible given the queued
                             prefill tokens and the chunk-lane capacity
                             (off by default).

    Async pipeline:
      ``async_depth``        0 (default) = the synchronous loop: fetch
                             logits, sample on host, block every step —
                             kept as the differential oracle.  1 = the
                             async pipeline: on-device sampling, a
                             device-resident fed-back-token buffer, and
                             one-step-lookahead dispatch (step N+1 is
                             dispatched while step N's sampled ids are
                             in flight; EOS reconciles one step late
                             with a free discard).  Streams are
                             bit-identical between the two.
      ``sampler``            registered on-device sampler name
                             (``runtime/sampling.py``); "greedy" is the
                             default and the only stream-deterministic
                             choice."""
    max_slots: int = 4
    num_pages: int = 64
    max_pages_per_slot: int = 16
    budget_frac: float = 1.0      # 1.0 = dense-equivalent oracle arm
    executor: Optional[str] = None  # paged attention backend: "xla" gather
                                    # oracle | fused "pallas" kernels
                                    # (kernels/paged_attn.py); None defers
                                    # to policy.executor
    eos_id: Optional[int] = None
    chunk_size: Optional[int] = None
    step_token_budget: Optional[int] = None
    monolithic_prefill: bool = False
    prefix_cache: bool = False
    scheduler: str = "slo"
    preemption: bool = True
    max_waiting: Optional[int] = None
    max_step_retries: int = 2
    max_restore_retries: int = 2
    chunk_starve_steps: int = 4
    straggler_threshold: float = 3.0
    mesh: Optional[tuple] = None    # (dp, tp) serving mesh; None = 1 device
    prefix_evict: str = "lru"       # cached prefix reclaim: lru | hit-rate
    admission_control: bool = False  # reject-on-infeasible-TTFT at admission
    async_depth: int = 0            # 0 = synchronous oracle; 1 = one-step
                                    # lookahead async pipeline
    sampler: str = "greedy"         # on-device sampler (runtime/sampling.py)

    def __post_init__(self):
        if self.scheduler not in ("slo", "fcfs"):
            raise ValueError(f"unknown scheduler {self.scheduler!r} "
                             "(expected 'slo' or 'fcfs')")
        if self.prefix_cache and self.monolithic_prefill:
            raise ValueError(
                "prefix_cache needs chunked prefill (the matched-prefix "
                "skip is chunk-granular); disable monolithic_prefill")
        if self.prefix_evict not in paged_lib.PageAllocator.EVICT_POLICIES:
            raise ValueError(
                f"prefix_evict must be one of "
                f"{paged_lib.PageAllocator.EVICT_POLICIES}, "
                f"got {self.prefix_evict!r}")
        if self.mesh is not None:
            if len(self.mesh) != 2 or any(int(a) < 1 for a in self.mesh):
                raise ValueError(f"mesh must be (dp >= 1, tp >= 1), "
                                 f"got {self.mesh!r}")
            if self.monolithic_prefill:
                raise ValueError(
                    "mesh serving runs through the unified chunked step; "
                    "disable monolithic_prefill")
        if self.async_depth not in (0, 1):
            raise ValueError(
                f"async_depth must be 0 (synchronous) or 1 (one-step "
                f"lookahead), got {self.async_depth!r}")
        if self.async_depth and self.monolithic_prefill:
            raise ValueError(
                "the async pipeline runs through the unified chunked step "
                "(monolithic admission prefill blocks the host per "
                "admission); disable monolithic_prefill")
        sampling_lib.get_sampler(self.sampler)   # validate the name early

    @classmethod
    def for_trace(cls, *, max_slots: int, max_prompt: int,
                  max_new_tokens: int, page_size: int,
                  budget_frac: float = 1.0,
                  eos_id: Optional[int] = None,
                  chunk_size: Optional[int] = None,
                  step_token_budget: Optional[int] = None,
                  monolithic_prefill: bool = False,
                  **knobs) -> "EngineConfig":
        """Size the pool so every slot can hold the largest trace request —
        the one place the reservation rule is encoded for drivers.  Extra
        ``knobs`` pass through to the config (scheduler, max_waiting, ...)."""
        per_slot = pages_needed(max_prompt, max_new_tokens, page_size)
        return cls(max_slots=max_slots, num_pages=1 + max_slots * per_slot,
                   max_pages_per_slot=per_slot, budget_frac=budget_frac,
                   eos_id=eos_id, chunk_size=chunk_size,
                   step_token_budget=step_token_budget,
                   monolithic_prefill=monolithic_prefill, **knobs)


@dataclasses.dataclass
class _SlotState:
    req: Request
    tokens: list
    admitted_step: int
    admit_t: float
    arrival_t: float              # when the request became schedulable
    phase: str                    # "prefill" | "decode"
    prefill_pos: int              # next absolute prompt position to process
    padded: np.ndarray            # (Lp,) prompt right-padded to a page multiple
    true_len: int
    ttft_s: float = 0.0
    first_token_t: float = 0.0
    last_token_t: float = 0.0
    token_latencies_s: list = dataclasses.field(default_factory=list)
    preemptions: int = 0
    last_sched_step: int = 0      # last step granted a decode token
    prefix_keys: list = dataclasses.field(default_factory=list)
                                  # chained hash per full prompt page, to
                                  # register once prefill completes
    inflight: int = 0             # async: dispatched-but-unreconciled tokens
    finished: bool = False        # async: terminal — in-flight reconciles
                                  # for this request are discarded


@dataclasses.dataclass
class _Preempted:
    """A swapped-out request: slot state frozen, PRIVATE pages on the host.
    Shared prefix pages are never snapshotted — their contents belong to
    the prefix index (other tenants may be reading them); the record keeps
    one pinned reference per shared page so they survive until restore."""
    st: _SlotState
    npages: int                   # private device pages to re-reserve
    cache_len: int                # cache_lens value at preemption
    seq: int                      # original submission order
    preempt_step: int
    restore_attempts: int = 0
    shared_pages: list = dataclasses.field(default_factory=list)
    group: int = 0                # slot group — restores are pinned to it
                                  # (the snapshot's bytes belong to that
                                  # group's pool shard)


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unreconciled async step.  ``dec_ids`` /
    ``chunk_ids`` are DEVICE arrays of sampled token ids — touching them
    with ``np.asarray`` is the reconcile-time fetch.  The slot-state
    references pin the requests the values belong to: a slot may be
    recycled and re-admitted before reconcile, but ``st`` cannot — its
    ``finished`` flag marks stale entries for free discard."""
    dec_ids: object               # (T,) / (G, S) int32 device array
    chunk_ids: object             # (L,) / (G, L) int32 device array | None
    dec: list                     # [(slot, _SlotState), ...]
    chunks: list                  # [(g, lane, slot, _SlotState, completes)]
    step: int                     # engine step at dispatch
    dispatch_t: float


@dataclasses.dataclass
class _PrefixMatch:
    """Admission-time prefix probe result, refs already pinned.

    ``shared``: matched pages mapped read-only into the page table (before
    the replay window — never written again).  ``cow``: matched pages that
    overlap the replay window (only the final full page of an
    exact-page-multiple fully-matched prompt: its logits must be recomputed,
    so the chunk REWRITES that page — copy-on-write redirects the write to
    a private copy).  ``keys``: chained hash of every full prompt page."""
    keys: list
    shared: list
    cow: list


class StemEngine:
    """Continuous-batching engine; host-side scheduler + one jitted step.

    ``stem_cfg`` names the engine's sparsity policy: a ``SparsityPolicy``,
    a registered policy name (``"stem"``, ``"streaming"``, …) or a legacy
    ``StemConfig``.  One policy drives chunked prefill page summaries,
    chunk selection, and decode page selection alike.

    ``chaos`` (a ``runtime.chaos.ChaosInjector``) optionally injects
    allocator exhaustion, step failures, and restore failures at configured
    engine steps — the engine must survive all of them (bounded retry,
    per-request abort-with-error, load shedding), which
    ``tests/test_chaos.py`` asserts."""

    def __init__(self, bundle, params, stem_cfg,
                 ecfg: EngineConfig = EngineConfig(), chaos=None):
        from repro.core import policy as policy_lib

        transformer.assert_paged_servable(bundle.cfg)
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.params = params
        self.policy = policy_lib.as_policy(stem_cfg)
        self.stem_cfg = self.policy          # legacy attribute name
        self.ecfg = ecfg
        self.chaos = chaos
        self.page_size = self.policy.block_size
        self.chunk_size = ecfg.chunk_size or 2 * self.page_size
        if self.chunk_size % self.page_size:
            raise ValueError(
                f"chunk_size {self.chunk_size} must be a multiple of the "
                f"page size {self.page_size}")
        self.token_budget = (ecfg.step_token_budget
                             or ecfg.max_slots + self.chunk_size)
        # Static width of the chunked-prefill lane: the most whole chunks
        # the token budget could ever admit in one step.
        self.chunk_lanes = min(ecfg.max_slots,
                               max(1, self.token_budget // self.chunk_size))
        if not ecfg.monolithic_prefill:
            chunked_lib.validate_chunked_policy(self.policy)

        S, P = ecfg.max_slots, ecfg.max_pages_per_slot
        # Serving mesh: dp independent slot groups (flat slot ids
        # [g*max_slots, (g+1)*max_slots) per group, each with its own
        # allocator and num_pages pages), tp sharding the KV-head axis of
        # every pool leaf.  smesh=None is the unchanged single-device path
        # with one group.
        self.smesh = None
        if ecfg.mesh is not None:
            from repro.sharding import serving as serving_lib
            dp, tp = (int(a) for a in ecfg.mesh)
            self.smesh = serving_lib.make_serving_mesh(dp, tp)
            serving_lib.validate_serving(
                bundle.cfg, ecfg.executor or self.policy.executor, self.smesh)
        self.groups = self.smesh.dp if self.smesh else 1
        self.slots_per_group = S
        self.total_slots = self.groups * S
        T = self.total_slots
        self.pools = transformer.init_page_pools(
            bundle.cfg, ecfg.num_pages, self.policy, smesh=self.smesh)
        self.allocators = [
            paged_lib.PageAllocator(ecfg.num_pages,
                                    evict_policy=ecfg.prefix_evict)
            for _ in range(self.groups)]
        self.allocator = self.allocators[0]    # single-group alias
        self.page_table = np.zeros((T, P), np.int32)
        self.cache_lens = np.zeros((T,), np.int32)
        self.slot_pages: list = [None] * T     # page ids held by each slot
        self.slot_nshared = [0] * T            # leading prefix-shared pages
        self.slots: list = [None] * T          # _SlotState | None
        self.waiting: collections.deque = collections.deque()
        self.preempted: list = []              # _Preempted records
        self.finished: list = []
        self.host_store = offload_lib.HostPageStore()
        self.step_count = 0
        self.stats = {"prefills": 0, "chunks": 0, "decode_steps": 0,
                      "step_calls": 0, "tokens_generated": 0,
                      "slots_reused": 0, "max_concurrency": 0,
                      "traces": 0, "prefill_traces": 0,
                      "preemptions": 0, "restores": 0, "restore_failures": 0,
                      "step_failures": 0, "aborts": 0, "shed": 0,
                      "decode_deferrals": 0, "chunk_caps": 0,
                      "starvation_grants": 0, "alloc_denials": 0,
                      "straggler_steps": 0,
                      "prefix_hits": 0, "prefix_pages_shared": 0,
                      "prefix_cows": 0, "admission_rejects": 0,
                      "host_syncs": 0, "id_fetches": 0,
                      "lookahead_discards": 0, "pallas_fallbacks": 0,
                      "restore_bytes": 0,
                      "dispatch_s": 0.0, "sync_wait_s": 0.0}
        self._slot_ever_used = [False] * T
        self._seq: dict = {}                   # uid -> submission order
        self._arrival_t: dict = {}             # uid -> first-schedulable wall
        self._next_seq = 0
        self._last_chunk_step = [0] * self.groups
                                               # last step a chunk ran (or no
                                               # prefill work existed), per
                                               # slot group
        self.monitor = StragglerMonitor(
            threshold=ecfg.straggler_threshold,
            on_straggler=lambda s, dt, ema: self.stats.__setitem__(
                "straggler_steps", self.stats["straggler_steps"] + 1))

        def _count(key):
            def bump():
                self.stats[key] += 1
            return bump

        # THE step: decode lane + chunked-prefill lane, fixed shapes.
        # ``chunk_k_max`` is the static chunk-selection/gather width: the
        # largest block budget any admissible prompt can reach, so chunk
        # cost tracks the policy's budget, not the page-table width.
        # ``stats["traces"]`` counts (re)compiles via a trace-time side
        # effect — the regression test pins it to the two lane signatures
        # (mixed / decode-only) across heterogeneous prompt lengths;
        # preemption's extract/restore are their own jits and never touch
        # this counter.
        k_bound = (0 if ecfg.monolithic_prefill else
                   chunked_lib.chunk_budget_bound(self.policy, P))
        self._async = ecfg.async_depth > 0
        self.sampler = sampling_lib.get_sampler(ecfg.sampler)
        if self._async:
            # Async pipeline: sampling runs inside the trace, the decode
            # inputs come from the device-resident fed-back-token buffer,
            # and the step returns (slots,) int32 sampled ids — the only
            # per-step transfer.  Donation caveat: XLA:CPU blocks the
            # *dispatch* of a call whose donated input is still being
            # computed, which would re-serialize the pipeline (the pools
            # chain step to step).  On a multi-core CPU host the pipeline
            # is worth more than zero-copy, so the async step runs
            # undonated there (double-buffered pools, host free-running);
            # on a single-core host nothing can overlap anyway, so the
            # zero-copy donated update wins.  Accelerator backends
            # dispatch donated calls asynchronously and keep both.
            donate = (() if jax.default_backend() == "cpu"
                      and (os.cpu_count() or 1) > 1 else (1, 2))
            self._unified = jax.jit(steps_lib.make_unified_step(
                bundle, stem_cfg=self.policy, budget_frac=ecfg.budget_frac,
                chunk_k_max=k_bound, executor=ecfg.executor,
                on_trace=_count("traces"), smesh=self.smesh,
                sampler=self.sampler), donate_argnums=donate)
        else:
            self._unified = jax.jit(steps_lib.make_unified_step(
                bundle, stem_cfg=self.policy, budget_frac=ecfg.budget_frac,
                chunk_k_max=k_bound, executor=ecfg.executor,
                on_trace=_count("traces"), smesh=self.smesh),
                donate_argnums=(1,))
        if self.smesh is not None:
            # Group-vmapped page-management jits: every argument gains a
            # leading (dp,) axis — non-target groups ride along with
            # trash-page rows (page 0 is garbage by design), so each still
            # compiles exactly once.  out_shardings pins the pool layout so
            # extract/restore shards map 1:1 onto mesh coordinates.
            from repro.sharding import serving as serving_lib
            pool_sh = serving_lib.pool_sharding(self.smesh)
            self._reset = jax.jit(jax.vmap(paged_lib.reset_pools_stacked),
                                  donate_argnums=(0,), out_shardings=pool_sh)
            self._extract = jax.jit(jax.vmap(steps_lib.make_page_extract()),
                                    out_shardings=pool_sh)
            self._restore_pages = jax.jit(
                jax.vmap(steps_lib.make_page_restore()),
                donate_argnums=(0,), out_shardings=pool_sh)
            self._page_copy = jax.jit(jax.vmap(steps_lib.make_page_copy()),
                                      donate_argnums=(0,),
                                      out_shardings=pool_sh)
        else:
            self._reset = jax.jit(paged_lib.reset_pools_stacked,
                                  donate_argnums=(0,))
            self._extract = jax.jit(steps_lib.make_page_extract())
            self._restore_pages = jax.jit(steps_lib.make_page_restore(),
                                          donate_argnums=(0,))
            # Copy-on-write device copy (prefix caching); traced page ids,
            # so this compiles once and never touches the trace counters.
            self._page_copy = jax.jit(steps_lib.make_page_copy(),
                                      donate_argnums=(0,))
        self._prefill = None
        if ecfg.monolithic_prefill:
            # Legacy A/B arm: one trace per padded prompt-length bucket.
            # The first token is sampled on-device (same sampler op as the
            # async step), so the admission fetch is one int32, not a
            # vocab-sized logits row.
            self._prefill = jax.jit(steps_lib.make_monolithic_prefill(
                bundle, stem_cfg=self.policy,
                on_trace=_count("prefill_traces"),
                sampler=self.sampler), donate_argnums=(3,))

        # Async pipeline state.  ``token_buf`` is the device-resident
        # fed-back-token buffer — decode lanes read last step's sampled
        # ids from it without a host round trip; only restores write it
        # from the host side (``_set_token``, traced indices: one trace).
        self._inflight: collections.deque = collections.deque()
        self.token_buf = None
        self._set_token = None
        if self._async:
            if self.smesh is not None:
                from repro.sharding import serving as serving_lib
                grp_sh = serving_lib.group_sharding(self.smesh)
                self.token_buf = jax.device_put(
                    jnp.zeros((self.groups, S), jnp.int32), grp_sh)
                self._set_token = jax.jit(
                    lambda buf, g, s, val: buf.at[g, s].set(val),
                    donate_argnums=(0,), out_shardings=grp_sh)
            else:
                self.token_buf = jnp.zeros((T,), jnp.int32)
                self._set_token = jax.jit(
                    lambda buf, s, val: buf.at[s].set(val),
                    donate_argnums=(0,))

        # Restore-cost model: preemption victims are priced by the bytes
        # their restore moves host->device over a measured-bandwidth EMA
        # (seeded pessimistically until the first timed restore).
        self._page_nbytes = (
            sum(l.nbytes for l in jax.tree_util.tree_leaves(self.pools))
            // (self.groups * ecfg.num_pages))
        self._h2d_bw_ema: Optional[float] = None

        # Pallas-fallback observability: the fused kernels silently hand
        # unsupported configurations back to the XLA gather oracle at
        # trace time; surface that in stats instead (kernels module keeps
        # a process-wide counter — snapshot the baseline at init).
        self._track_fallbacks = (
            (ecfg.executor or self.policy.executor) == "pallas")
        self._pallas_fb_base = 0
        if self._track_fallbacks:
            from repro.kernels import paged_attn
            self._pallas_fb_base = sum(paged_attn.FALLBACKS.values())

    # -- scheduling ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        npages = self._pages_needed(len(req.prompt), req.max_new_tokens)
        if npages > self.ecfg.max_pages_per_slot:
            raise ValueError(
                f"request {req.uid} needs {npages} pages > max_pages_per_slot "
                f"{self.ecfg.max_pages_per_slot}")
        if req.uid in self._seq:
            raise ValueError(f"duplicate request uid {req.uid}")
        self._seq[req.uid] = self._next_seq
        self._next_seq += 1
        self.waiting.append(req)

    def _pages_needed(self, prompt_len: int, max_new: int) -> int:
        return pages_needed(prompt_len, max_new, self.page_size)

    def reset_metrics(self) -> None:
        """Zero the workload observability state (finished list, counters,
        slot-reuse tracking, straggler flags) without touching pools, slots,
        or the allocator — e.g. after a benchmark warmup pass.  Trace
        counters are *kept*: they record compiles over the engine's lifetime
        (a warmed engine adds zero), and benchmarks report them as evidence
        of the no-retrace property.  The straggler EMA is kept warm too —
        only its flag history resets."""
        self.finished.clear()
        keep = ("traces", "prefill_traces", "pallas_fallbacks")
        self.stats.update({k: 0 for k in self.stats if k not in keep})
        self.stats["dispatch_s"] = 0.0
        self.stats["sync_wait_s"] = 0.0
        self._slot_ever_used = [False] * self.total_slots
        self.monitor.flagged.clear()

    def _refresh_fallbacks(self) -> None:
        """Mirror the kernels module's process-wide fallback counter into
        ``stats`` (delta since this engine was built)."""
        if not self._track_fallbacks:
            return
        from repro.kernels import paged_attn
        self.stats["pallas_fallbacks"] = (
            sum(paged_attn.FALLBACKS.values()) - self._pallas_fb_base)

    @property
    def metrics(self) -> dict:
        """Live observability: straggler flags, offload residency, chaos
        counters — the serving-side mirror of ``stats`` for dashboards."""
        self._refresh_fallbacks()
        return {
            "inflight_steps": len(self._inflight),
            "h2d_bw_bytes_per_s": self._h2d_bw_ema,
            "pallas_fallbacks": self.stats["pallas_fallbacks"],
            "step_time_ema_s": self.monitor.ema,
            "straggler_steps": list(self.monitor.flagged),
            "offloaded_requests": len(self.preempted),
            "offload_resident_bytes": self.host_store.nbytes,
            "offload_peak_bytes": self.host_store.peak_nbytes,
            "allocator_evictions": sum(a.evictions for a in self.allocators),
            "allocator_restores": sum(a.restores for a in self.allocators),
            "allocator_total_alloced": sum(a.total_alloced
                                           for a in self.allocators),
            "prefix_shares": sum(a.shares for a in self.allocators),
            "prefix_cached_pages": sum(a.cached_pages
                                       for a in self.allocators),
            "chaos": self.chaos.counts if self.chaos else None,
        }

    def _group_of(self, slot: int) -> int:
        return slot // self.slots_per_group

    def _group_slots(self, g: int) -> range:
        S = self.slots_per_group
        return range(g * S, (g + 1) * S)

    def _free_slot_in(self, g: int) -> Optional[int]:
        for s in self._group_slots(g):
            if self.slots[s] is None:
                return s
        return None

    def _check_pages(self) -> None:
        """Refcount conservation after any path that moves pages: each
        group's live references — one per slot-held page, plus one per
        shared prefix page pinned by an offloaded request — must match that
        group's allocator refcounts exactly (a MULTISET: a page shared by k
        slots appears k times)."""
        for g, alloc in enumerate(self.allocators):
            held = [p for s in self._group_slots(g)
                    if self.slot_pages[s] for p in self.slot_pages[s]]
            held += [p for rec in self.preempted if rec.group == g
                     for p in rec.shared_pages]
            alloc.check_conservation(held)

    # -- preemption + host offload ------------------------------------------

    def preempt(self, slot: int) -> None:
        """Swap a running request out to host memory: gather its PRIVATE
        pages (K/V + kg/vm summaries) into a host snapshot, evict them, and
        park the frozen slot state on the preempted list.  Prefix-shared
        pages are neither snapshotted nor evicted — their contents stay
        live for co-tenants; the record re-pins them (keeps this request's
        reference) so they cannot be reclaimed before restore.
        Re-admission restores bit-identically with zero recompute.

        Async: the in-flight step is drained first — the host token list
        and ``cache_lens`` must agree with the page contents the snapshot
        gathers, and an unreconciled sampled id would otherwise be lost
        with the eviction."""
        if self._async and self._inflight:
            self._drain()
        st = self.slots[slot]
        if st is None:
            raise ValueError(f"slot {slot} is not active")
        g = self._group_of(slot)
        pages = self.slot_pages[slot]
        nshared = self.slot_nshared[slot]
        shared, private = pages[:nshared], pages[nshared:]
        W = self.ecfg.max_pages_per_slot
        if self.smesh is not None:
            # Extract the victim's rows for its own group only; other
            # groups gather their trash page.  The snapshot stays sharded
            # per mesh coordinate on the host, so restore puts each tp
            # shard's bytes back exactly where they came from.
            rows = np.zeros((self.groups, W), np.int32)
            rows[g, :len(private)] = private
            snap = self._extract(self.pools, jnp.asarray(rows))
            snap = offload_lib.shard_snapshot_to_host(snap, self.smesh, g)
        else:
            row = np.zeros((W,), np.int32)
            row[:len(private)] = private
            snap = self._extract(self.pools, jnp.asarray(row))
        self.host_store.put(st.req.uid, snap, pinned=shared)
        st.preemptions += 1
        self.preempted.append(_Preempted(
            st=st, npages=len(private), cache_len=int(self.cache_lens[slot]),
            seq=self._seq[st.req.uid], preempt_step=self.step_count,
            shared_pages=list(shared), group=g))
        self.allocators[g].evict(private)
        self.page_table[slot] = 0
        self.cache_lens[slot] = 0
        self.slot_pages[slot] = None
        self.slot_nshared[slot] = 0
        self.slots[slot] = None
        self.stats["preemptions"] += 1
        self._check_pages()

    def _admit_restore(self, rec: _Preempted, slot: int, pages: list) -> bool:
        """Swap a preempted request back in: scatter the private snapshot
        into the fresh pages; the pinned shared prefix pages re-enter the
        page table untouched (their contents never left the device).  On an
        injected restore failure: free the fresh pages (conservation), keep
        the snapshot + pins, retry on a later step — or abort the request
        with an explicit error once ``max_restore_retries`` is exhausted
        (releasing the pins)."""
        g = rec.group
        W = self.ecfg.max_pages_per_slot
        try:
            if self.chaos:
                self.chaos.maybe_fail_restore(self.step_count)
        except InjectedFailure as e:
            self.allocators[g].free(pages)
            rec.restore_attempts += 1
            self.stats["restore_failures"] += 1
            if rec.restore_attempts > self.ecfg.max_restore_retries:
                self.host_store.drop(rec.st.req.uid)
                if rec.shared_pages:
                    self.allocators[g].free(rec.shared_pages)
                self.stats["aborts"] += 1
                self._finish_with_error(
                    rec.st, slot=-1,
                    error=f"aborted: restore failed "
                          f"{rec.restore_attempts} times ({e})")
            else:
                self.preempted.append(rec)
            self._check_pages()
            return False
        snap = self.host_store.pop(rec.st.req.uid)
        # Time the host->device scatter to feed the restore-cost model's
        # bandwidth EMA.  Only an un-overlapped restore is a clean sample:
        # with an async step in flight the block would also wait out the
        # step and undersell the link.
        measure = not (self._async and self._inflight)
        t0 = time.perf_counter()
        if self.smesh is not None:
            rows = np.zeros((self.groups, W), np.int32)
            rows[g, :rec.npages] = pages
            snap = offload_lib.assemble_sharded_snapshot(snap, self.smesh, g)
            self.pools = self._restore_pages(self.pools, jnp.asarray(rows),
                                             snap)
        else:
            row = np.zeros((W,), np.int32)
            row[:rec.npages] = pages
            self.pools = self._restore_pages(self.pools, jnp.asarray(row),
                                             snap)
        nbytes = rec.npages * self._page_nbytes
        self.stats["restore_bytes"] += nbytes
        if measure and nbytes:
            jax.block_until_ready(jax.tree_util.tree_leaves(self.pools)[0])
            bw = nbytes / max(time.perf_counter() - t0, 1e-9)
            self._h2d_bw_ema = (bw if self._h2d_bw_ema is None
                                else 0.5 * self._h2d_bw_ema + 0.5 * bw)
        if self._async and rec.st.tokens:
            # Re-seed the device-resident fed-back-token buffer: the
            # restored request's next decode step feeds its last emitted
            # token, which left the device with the preemption drain.
            last = jnp.asarray(rec.st.tokens[-1], jnp.int32)
            if self.smesh is not None:
                local = jnp.asarray(slot - g * self.slots_per_group,
                                    jnp.int32)
                self.token_buf = self._set_token(
                    self.token_buf, jnp.asarray(g, jnp.int32), local, last)
            else:
                self.token_buf = self._set_token(
                    self.token_buf, jnp.asarray(slot, jnp.int32), last)
        all_pages = list(rec.shared_pages) + list(pages)
        full_row = np.zeros((self.ecfg.max_pages_per_slot,), np.int32)
        full_row[:len(all_pages)] = all_pages
        if self._slot_ever_used[slot]:
            self.stats["slots_reused"] += 1
        self._slot_ever_used[slot] = True
        self.page_table[slot] = full_row
        self.cache_lens[slot] = rec.cache_len
        self.slot_pages[slot] = all_pages
        self.slot_nshared[slot] = len(rec.shared_pages)
        self.slots[slot] = rec.st
        self.stats["restores"] += 1
        self._check_pages()
        return True

    def _try_preempt_for(self, priority: int, need_pages: int,
                         group: int) -> bool:
        """Preempt one strictly-lower-priority running request in slot
        group ``group`` to make room (a slot and/or pages) for an admission
        at ``priority``.  Refuses when evicting every eligible victim still
        could not free enough pages — no pointless offloads."""
        if (self.ecfg.scheduler != "slo" or not self.ecfg.preemption):
            return False
        if self._async and self._inflight:
            # Reconcile before evicting anyone: an in-flight step may
            # finish a request outright, freeing a slot and its pages —
            # in which case the preemption is moot and the caller can
            # retry its allocation directly.
            self._drain()
            if (self._free_slot_in(group) is not None
                    and self.allocators[group].available >= need_pages):
                return True
        victims = [s for s in self._group_slots(group)
                   if self.slots[s] is not None
                   and self.slots[s].req.priority < priority]
        if not victims:
            return False
        # Only a victim's PRIVATE pages come back (shared prefix pages stay
        # pinned by its preemption record); still an upper bound when a
        # private page is also shared by another slot.
        reclaimable = sum(len(self.slot_pages[s]) - self.slot_nshared[s]
                          for s in victims)
        if self.allocators[group].available + reclaimable < need_pages:
            return False
        # Restore-cost model: the victim class is the LOWEST priority
        # present (never climb the ladder for a cheaper restore); within
        # it, evict the request whose restore is cheapest in SECONDS —
        # private pages x page nbytes over the measured host->device
        # bandwidth EMA (``_restore_cost_s``).  Only PRIVATE pages price
        # in: shared prefix pages stay on-device either way.  Ties break
        # toward most-recently-admitted (least sunk progress), then the
        # higher slot id, keeping the pick deterministic.
        lowest = min(self.slots[s].req.priority for s in victims)
        cls = [s for s in victims if self.slots[s].req.priority == lowest]
        victim = min(cls, key=lambda s: (
            self._restore_cost_s(s),
            -self.slots[s].admitted_step, -s))
        self.preempt(victim)
        return True

    # Pessimistic PCIe-class seed bandwidth until the first timed restore.
    _BW_SEED = 8e9

    def _restore_cost_s(self, slot: int) -> float:
        """Estimated seconds to swap ``slot`` back in: the host->device
        bytes its restore would move (private pages x page nbytes — the
        snapshot round-trips exactly those) over the measured restore
        bandwidth EMA.  With the uniform page size this is monotone in
        the private-page count, so victim ordering is stable as the EMA
        moves; the seconds scale is what ``metrics`` and future
        multi-tier offload decisions consume."""
        private = len(self.slot_pages[slot]) - self.slot_nshared[slot]
        return (private * self._page_nbytes
                / (self._h2d_bw_ema or self._BW_SEED))

    # -- failure paths ------------------------------------------------------

    def _finish_with_error(self, st: _SlotState, slot: int, error: str) -> None:
        st.finished = True   # async: discard any in-flight work for it
        tpot = (float("nan") if len(st.tokens) < 2 else
                (st.last_token_t - st.first_token_t) / (len(st.tokens) - 1))
        self.finished.append(FinishedRequest(
            uid=st.req.uid, prompt_len=len(st.req.prompt), tokens=st.tokens,
            slot=slot, admitted_step=st.admitted_step,
            finished_step=self.step_count,
            ttft_s=st.ttft_s if st.tokens else float("nan"), tpot_s=tpot,
            token_latencies_s=st.token_latencies_s,
            priority=st.req.priority, preemptions=st.preemptions,
            queue_s=st.admit_t - st.arrival_t, error=error))
        self._seq.pop(st.req.uid, None)   # uid may be resubmitted later

    def _abort(self, slot: int, error: str) -> None:
        """Terminate an active request with an explicit error; its pages go
        back to the free list and the slot frees up."""
        st = self.slots[slot]
        self._finish_with_error(st, slot, error)
        self.allocators[self._group_of(slot)].free(self.slot_pages[slot])
        self.page_table[slot] = 0
        self.cache_lens[slot] = 0
        self.slot_pages[slot] = None
        self.slot_nshared[slot] = 0
        self.slots[slot] = None
        self.stats["aborts"] += 1
        self._check_pages()

    def _shed(self) -> None:
        """Bound the waiting queue: overflow rejects the lowest-priority
        (newest among ties; FCFS: the newest, period) pending request as an
        explicitly failed FinishedRequest."""
        lim = self.ecfg.max_waiting
        if lim is None:
            return
        while len(self.waiting) > lim:
            if self.ecfg.scheduler == "fcfs":
                i = len(self.waiting) - 1
            else:
                i = min(range(len(self.waiting)),
                        key=lambda j: (self.waiting[j].priority,
                                       -self._seq[self.waiting[j].uid]))
            req = self.waiting[i]
            del self.waiting[i]
            self.finished.append(FinishedRequest(
                uid=req.uid, prompt_len=len(req.prompt), tokens=[], slot=-1,
                admitted_step=-1, finished_step=self.step_count,
                ttft_s=float("nan"), tpot_s=float("nan"),
                token_latencies_s=[], priority=req.priority,
                error=f"shed: waiting queue exceeded max_waiting={lim}"))
            self._seq.pop(req.uid, None)
            self.stats["shed"] += 1

    def _admission_control(self) -> None:
        """SLO-aware admission control (off by default): reject a waiting
        request up front, with an explicit error, when its TTFT SLO is
        already infeasible at the current measured step time.

        The feasibility model is deliberately coarse — prefill throughput
        is bounded by ``groups * chunk_lanes * chunk_size`` tokens per
        step, so a request behind ``ahead`` backlogged prompt tokens needs
        at least ``ceil((ahead + own) / cap)`` more steps before its first
        token, each costing the engine's step-time EMA.  Queueing time
        already spent counts too.  Requests without a TTFT SLO are never
        rejected; with no EMA yet (cold engine) everything is admitted."""
        if not self.ecfg.admission_control:
            return
        ema = self.monitor.ema
        if not ema:
            return
        now = time.perf_counter()
        cap = self.groups * self.chunk_lanes * self.chunk_size
        backlog = sum(len(st.padded) - st.prefill_pos
                      for st in self.slots
                      if st is not None and st.phase == "prefill")
        arrived = [r for r in self.waiting
                   if r.arrival_step <= self.step_count]
        if self.ecfg.scheduler == "slo":
            arrived.sort(key=lambda r: (-r.priority, self._seq[r.uid]))
        ahead = backlog
        reject = []
        for r in arrived:
            padded = -(-len(r.prompt) // self.page_size) * self.page_size
            if r.ttft_slo_s is not None:
                steps = -(-(ahead + padded) // cap)
                est = ((now - self._arrival_t.get(r.uid, now))
                       + steps * ema)
                if est > r.ttft_slo_s:
                    reject.append((r, est, steps))
                    continue
            ahead += padded
        for r, est, steps in reject:
            self.waiting.remove(r)
            self.finished.append(FinishedRequest(
                uid=r.uid, prompt_len=len(r.prompt), tokens=[], slot=-1,
                admitted_step=-1, finished_step=self.step_count,
                ttft_s=float("nan"), tpot_s=float("nan"),
                token_latencies_s=[], priority=r.priority,
                error=(f"rejected: TTFT SLO {r.ttft_slo_s * 1e3:.1f} ms "
                       f"infeasible (>= {steps} prefill steps "
                       f"~ {est * 1e3:.1f} ms at current load)")))
            self._seq.pop(r.uid, None)
            self.stats["admission_rejects"] += 1

    def _lowest_priority_active(self) -> Optional[int]:
        active = [s for s, st in enumerate(self.slots) if st is not None]
        if not active:
            return None
        return min(active, key=lambda s: (self.slots[s].req.priority,
                                          -self.slots[s].admitted_step, -s))

    def _try_alloc(self, n: int, group: int, restore: bool = False):
        """(pages | None, chaos_denied) from ``group``'s allocator.  An
        injected denial models transient allocator exhaustion: the
        admission blocks this step and retries on the next — it must never
        trigger preemption."""
        if self.chaos and self.chaos.deny_alloc(self.step_count):
            self.stats["alloc_denials"] += 1
            return None, True
        alloc = self.allocators[group]
        pages = alloc.restore(n) if restore else alloc.alloc(n)
        return pages, False

    # -- engine iteration ---------------------------------------------------

    def _next_candidate(self):
        """Head-of-line admission candidate, or None.  FCFS: strictly the
        waiting head.  SLO: the best of (preempted + *arrived* waiting) by
        (priority desc, submission order) — re-admissions compete with
        fresh work on equal terms, and admission never skips past a better
        candidate that is blocked (no priority inversion via bypass)."""
        if self.ecfg.scheduler == "fcfs":
            if self.waiting and self.waiting[0].arrival_step <= self.step_count:
                return ("new", 0)
            return None
        best, best_key = None, None
        for i, rec in enumerate(self.preempted):
            key = (-rec.st.req.priority, rec.seq)
            if best_key is None or key < best_key:
                best, best_key = ("pre", i), key
        for i, req in enumerate(self.waiting):
            if req.arrival_step > self.step_count:
                continue
            key = (-req.priority, self._seq[req.uid])
            if best_key is None or key < best_key:
                best, best_key = ("new", i), key
        return best

    def _admit(self) -> None:
        # Admit first, shed after: the queue bound applies to what remains
        # waiting once this step's capacity is used — never to a request a
        # free slot could serve right now.
        self._admit_loop()
        self._shed()

    def _probe_prefix(self, req: Request, group: int) -> _PrefixMatch:
        """Probe ``group``'s prefix index for the request's whole prompt
        pages and PIN every hit (take a reference) before any allocation —
        an alloc drawing on the cached pool could otherwise reclaim a
        just-probed page.  The caller must ``_release_prefix`` if admission
        blocks.  The longest matched *chain* wins: a miss at page j stops
        the scan (page j+1's contents depend on page j's tokens).  Prefix
        indexes are per slot group: pages only exist in their group's pool
        shard (cross-group sharing is the ROADMAP cross-engine item)."""
        alloc = self.allocators[group]
        plen = len(req.prompt)
        bs = self.page_size
        padded_len = -(-plen // bs) * bs
        budgets = self.policy.prefill_budgets(padded_len)
        keys = paged_lib.prefix_page_keys(req.prompt, budgets, bs)
        # The page holding the prompt's LAST token is always replayed (its
        # position produces the first generated token's logits), and the
        # replay chunk rewrites it — a hit there goes to the CoW list.
        last_page = (plen - 1) // bs
        shared, cow = [], []
        for j, key in enumerate(keys):
            p = alloc.probe(key)
            if p is None:
                break
            alloc.share(p)
            (shared if j < last_page else cow).append(p)
        return _PrefixMatch(keys=keys, shared=shared, cow=cow)

    def _release_prefix(self, prefix: Optional[_PrefixMatch],
                        group: int) -> None:
        if prefix is not None and (prefix.shared or prefix.cow):
            self.allocators[group].free(prefix.shared + prefix.cow)

    def _candidate_groups(self) -> list:
        """Placement preference for a NEW request: groups with a free slot
        first, then most available pages, then the lowest group id — cheap
        host-side balancing across the dp slot groups.  Restores never get
        a choice: a preempted request's snapshot bytes belong to its
        original group's pool shard."""
        def key(g):
            return (self._free_slot_in(g) is None,
                    -self.allocators[g].available, g)
        return sorted(range(self.groups), key=key)

    def _admit_loop(self) -> None:
        while True:
            cand = self._next_candidate()
            if cand is None:
                return
            kind, idx = cand
            if kind == "new":
                req = self.waiting[idx]
                prio = req.priority
                npages_full = self._pages_needed(len(req.prompt),
                                                 req.max_new_tokens)
                groups = self._candidate_groups()
            else:
                rec = self.preempted[idx]
                prio = rec.st.req.priority
                groups = [rec.group]
            # Try each eligible group in preference order; the head-of-line
            # candidate waits (no bypass) only when EVERY group is blocked.
            placed = False
            for g in groups:
                prefix = None
                if kind == "new":
                    npages = npages_full
                    if self.ecfg.prefix_cache:
                        prefix = self._probe_prefix(req, g)
                        npages -= len(prefix.shared)
                else:
                    npages = rec.npages
                slot = self._free_slot_in(g)
                if slot is None:
                    if not self._try_preempt_for(prio, npages, g):
                        self._release_prefix(prefix, g)
                        continue            # slot-blocked in this group
                    slot = self._free_slot_in(g)
                pages, denied = self._try_alloc(npages, g,
                                                restore=(kind == "pre"))
                if denied:
                    self._release_prefix(prefix, g)
                    return                  # transient exhaustion — retry later
                while pages is None:
                    if not self._try_preempt_for(prio, npages, g):
                        break
                    pages, denied = self._try_alloc(npages, g,
                                                    restore=(kind == "pre"))
                    if denied:
                        self._release_prefix(prefix, g)
                        return
                if pages is None:
                    self._release_prefix(prefix, g)
                    continue                # memory-blocked in this group
                placed = True
                break
            if not placed:
                return                      # head-of-line waits everywhere
            if kind == "pre":
                del self.preempted[idx]
                if not self._admit_restore(rec, slot, pages):
                    return                  # restore failed — handled inside
                continue
            del self.waiting[idx]
            self._admit_new(req, slot, pages, prefix)

    def _admit_new(self, req: Request, slot: int, pages: list,
                   prefix: Optional[_PrefixMatch] = None) -> None:
        plen = len(req.prompt)
        npages_prompt = -(-plen // self.page_size)
        padded_len = npages_prompt * self.page_size
        shared = list(prefix.shared) if prefix else []
        n_share = len(shared)
        all_pages = shared + list(pages)
        # Full reservation, trash-padded: shared prefix pages first (the
        # page table is position-ordered), then the private allocation.
        row = np.zeros((self.ecfg.max_pages_per_slot,), np.int32)
        row[:len(all_pages)] = all_pages
        if self._slot_ever_used[slot]:
            self.stats["slots_reused"] += 1
        self._slot_ever_used[slot] = True
        self.page_table[slot] = row
        self.slot_pages[slot] = all_pages
        self.slot_nshared[slot] = n_share
        now = time.perf_counter()
        arrival = self._arrival_t.get(req.uid, now)

        if self.ecfg.monolithic_prefill:
            # Legacy: prefill the whole prompt at admission (resets the
            # reserved pages inside prefill_kv_pages), per-length trace.
            # The first token is sampled ON-DEVICE (same op as the async
            # step) — the admission fetch is one int32, not a logits row.
            toks = np.zeros((1, padded_len), np.int32)
            toks[0, :plen] = req.prompt
            first_id, self.pools = self._prefill(
                self.params, jnp.asarray(toks),
                jnp.asarray(plen, jnp.int32), self.pools,
                jnp.asarray(row))
            first = int(first_id)
            self.stats["id_fetches"] += 1
            done = time.perf_counter()
            self.stats["prefills"] += 1
            self.stats["tokens_generated"] += 1
            self.cache_lens[slot] = plen
            st = _SlotState(
                req=req, tokens=[first], admitted_step=self.step_count,
                admit_t=now, arrival_t=arrival, phase="decode",
                prefill_pos=padded_len,
                padded=np.zeros((0,), np.int32), true_len=plen,
                ttft_s=done - arrival, first_token_t=done, last_token_t=done,
                last_sched_step=self.step_count)
            self.slots[slot] = st
            if self._is_finished(st):
                self._recycle(slot)
            return

        # Chunked: reset the PRIVATE reservation to pristine (recycled
        # pages are dirty; chunk writes + decode increments assume fresh
        # pages).  Shared prefix pages carry live canonical contents and
        # must NOT be reset.  The reset row is the same fixed trash-padded
        # width either way — no new traces.
        g = self._group_of(slot)
        if self.smesh is not None:
            fresh_rows = np.zeros((self.groups, self.ecfg.max_pages_per_slot),
                                  np.int32)
            fresh_rows[g, :len(pages)] = pages
            self.pools = self._reset(self.pools, jnp.asarray(fresh_rows))
        else:
            fresh_row = np.zeros((self.ecfg.max_pages_per_slot,), np.int32)
            fresh_row[:len(pages)] = pages
            self.pools = self._reset(self.pools, jnp.asarray(fresh_row))
        if prefix and prefix.cow:
            # Copy-on-write: a fully-matched exact-page-multiple prompt
            # still replays its final page (first-token logits), and the
            # replay chunk REWRITES that page — so the matched page's
            # contents are copied into the private page at table index
            # n_share and the probe's pin on the original is dropped.
            src = prefix.cow[0]
            dst = pages[0]
            if self.smesh is not None:
                # Non-target groups copy trash page 0 onto itself (no-op).
                srcv = np.zeros((self.groups,), np.int32)
                dstv = np.zeros((self.groups,), np.int32)
                srcv[g], dstv[g] = src, dst
                self.pools = self._page_copy(self.pools, jnp.asarray(srcv),
                                             jnp.asarray(dstv))
            else:
                self.pools = self._page_copy(self.pools,
                                             jnp.asarray(src, jnp.int32),
                                             jnp.asarray(dst, jnp.int32))
            self.allocators[g].free([src])
            self.allocators[g].cows += 1  # private dst came from the bulk
                                          # alloc, not allocator.cow()
            self.stats["prefix_cows"] += 1
        if prefix and (prefix.shared or prefix.cow):
            self.stats["prefix_hits"] += 1
            self.stats["prefix_pages_shared"] += n_share
        ptoks = np.zeros((padded_len,), np.int32)
        ptoks[:plen] = req.prompt
        self.cache_lens[slot] = 0
        # The prefill cursor starts past the matched prefix: only the
        # unmatched suffix (always >= one page — the last-token page is
        # replayed) flows through the chunk lane.
        self.slots[slot] = _SlotState(
            req=req, tokens=[], admitted_step=self.step_count,
            admit_t=now, arrival_t=arrival, phase="prefill",
            prefill_pos=n_share * self.page_size,
            padded=ptoks, true_len=plen, last_sched_step=self.step_count,
            prefix_keys=list(prefix.keys) if prefix else [])

    def _is_finished(self, st: _SlotState) -> bool:
        if len(st.tokens) >= st.req.max_new_tokens:
            return True
        return self.ecfg.eos_id is not None and st.tokens[-1] == self.ecfg.eos_id

    def _recycle(self, slot: int) -> None:
        st = self.slots[slot]
        st.finished = True   # async: the one speculative EOS-lookahead
                             # step reconciles against this flag and is
                             # discarded for free
        # TPOT is undefined for a single-output-token request (no
        # post-first token) — record NaN so means can exclude it.
        tpot = (float("nan") if len(st.tokens) < 2 else
                (st.last_token_t - st.first_token_t) / (len(st.tokens) - 1))
        self.finished.append(FinishedRequest(
            uid=st.req.uid, prompt_len=len(st.req.prompt), tokens=st.tokens,
            slot=slot, admitted_step=st.admitted_step,
            finished_step=self.step_count, ttft_s=st.ttft_s, tpot_s=tpot,
            token_latencies_s=st.token_latencies_s,
            priority=st.req.priority, preemptions=st.preemptions,
            queue_s=st.admit_t - st.arrival_t))
        # Retire the uid: submission order only matters while the request is
        # schedulable, and benchmarks legitimately replay a trace (same
        # uids) against a warmed engine.
        self._seq.pop(st.req.uid, None)
        # Shared refs decrement (co-tenants keep the pages); a registered
        # page at ref 0 parks in the allocator's cached set, contents
        # intact, so the NEXT tenant with this prefix still hits.
        self.allocators[self._group_of(slot)].free(self.slot_pages[slot])
        self.page_table[slot] = 0
        self.cache_lens[slot] = 0
        self.slot_pages[slot] = None
        self.slot_nshared[slot] = 0
        self.slots[slot] = None

    def _decode_key(self, s: int, now: float):
        """Decode-token grant order.  SLO: priority first, then remaining
        TPOT headroom (violators and near-deadline slots first; no-SLO
        slots last within the tier), then least-recently-served for
        round-robin fairness under budget pressure."""
        st = self.slots[s]
        if self.ecfg.scheduler == "fcfs":
            return (0, 0.0, st.admitted_step, s)
        slo = st.req.tpot_slo_s
        headroom = (slo - (now - st.last_token_t)) if slo else float("inf")
        return (-st.req.priority, headroom, st.last_sched_step, s)

    def _chunk_key(self, s: int, now: float):
        """Chunk grant order: priority, then remaining TTFT headroom."""
        st = self.slots[s]
        if self.ecfg.scheduler == "fcfs":
            return (0, 0.0, st.admitted_step, s)
        slo = st.req.ttft_slo_s
        headroom = (slo - (now - st.arrival_t)) if slo else float("inf")
        return (-st.req.priority, headroom, st.admitted_step, s)

    def _grantable_decodes(self) -> list:
        """Decode-phase slots still owed a token.  Sync: every active
        decode slot (a finished slot recycles immediately, so the grant
        condition is vacuous).  Async: the grant accounting counts
        IN-FLIGHT tokens too — a max-new-tokens finish is deterministic
        at dispatch time and never speculates; only an unknowable EOS
        earns the single lookahead step, whose discard is free."""
        return [s for s, st in enumerate(self.slots)
                if st is not None and st.phase == "decode"
                and len(st.tokens) + st.inflight < st.req.max_new_tokens]

    def _schedule(self, dec_all: list, pre_all: list,
                  sched_now: float) -> tuple:
        """The token-budget grant pass, shared verbatim by the sync and
        async paths: partition this step's decode grants and prefill-chunk
        grants per slot group.  Pure host bookkeeping — nothing here
        touches the device, which is what lets the async loop run it for
        step N+1 while step N is still in flight.  Returns
        ``(dec, grants)``: the granted decode slots (all groups) and the
        per-group lists of granted chunk slots."""
        self.stats["max_concurrency"] = max(self.stats["max_concurrency"],
                                            len(dec_all) + len(pre_all))
        G, Sg = self.groups, self.slots_per_group
        C = self.chunk_size
        cap = max(1, self.token_budget)         # per slot group
        dec, grants = [], []
        for g in range(G):
            # Token budget: decode tokens first — ordered by (priority, SLO
            # headroom, least-recently-served); FCFS: admission order —
            # with decodes beyond the budget deferred to later steps.
            dec_g_all = sorted((s for s in dec_all if s // Sg == g),
                               key=lambda s: self._decode_key(s, sched_now))
            dec_g = dec_g_all[:cap]
            deferred = dec_g_all[cap:]
            self.stats["decode_deferrals"] += len(deferred)

            # Adaptive chunk sizing: under this group's decode-lane TPOT
            # pressure (a decode was deferred, or a TPOT SLO is currently
            # violated) cap the chunk grant at one lane — prefill yields
            # to the decode SLOs.
            pre_g = sorted((s for s in pre_all if s // Sg == g),
                           key=lambda s: self._chunk_key(s, sched_now))
            pressure = False
            if self.ecfg.scheduler == "slo":
                violating = any(
                    self.slots[s].req.tpot_slo_s is not None
                    and sched_now - self.slots[s].last_token_t
                        > self.slots[s].req.tpot_slo_s
                    for s in dec_g_all)
                pressure = bool(deferred) or violating
            lanes_cap = 1 if pressure else self.chunk_lanes
            if pressure and pre_g and lanes_cap < self.chunk_lanes:
                self.stats["chunk_caps"] += 1

            # Whole chunks into the static chunk lanes, priority/TTFT-
            # headroom order (FCFS: admission order).  Always grant at
            # least one chunk when prefill work exists and nothing else
            # would run in this group, and force one when prefill has
            # starved ``chunk_starve_steps`` steps — the bounded overdraft
            # that keeps decode saturation from starving prefill forever.
            remaining = self.token_budget - len(dec_g)
            grant_g = []
            for s in pre_g:
                if len(grant_g) >= lanes_cap:
                    break
                if remaining >= C or (not grant_g and not dec_g):
                    grant_g.append(s)
                    remaining -= C
            if (not grant_g and pre_g and
                    self.step_count - self._last_chunk_step[g]
                    >= self.ecfg.chunk_starve_steps):
                grant_g = [pre_g[0]]
                self.stats["starvation_grants"] += 1
            if grant_g or not pre_g:
                self._last_chunk_step[g] = self.step_count
            dec += dec_g
            grants.append(grant_g)
        return dec, grants

    def _mixed_step(self) -> bool:
        """One SYNCHRONOUS unified-step invocation: the scheduled decode
        tokens plus as many prefill chunks as the token budget admits, for
        EVERY slot group at once — the replicated host scheduler
        partitions its grants per group (each group gets the full
        per-group token budget and its own chunk lanes), and one jitted
        call advances all of them; the host then blocks on the logits
        fetch and samples with ``np.argmax``.  This is the
        ``async_depth=0`` differential oracle.  Returns whether any work
        ran (for straggler timing)."""
        dec_all = self._grantable_decodes()
        pre_all = [s for s, st in enumerate(self.slots)
                   if st is not None and st.phase == "prefill"]
        if not dec_all and not pre_all:
            self._last_chunk_step = [self.step_count] * self.groups
            return False
        # Injection point: strictly BEFORE any pool mutation, so a bounded
        # retry of this step never double-applies summary increments.
        if self.chaos:
            self.chaos.maybe_fail_step(self.step_count)
        dec, grants = self._schedule(dec_all, pre_all, time.perf_counter())

        G, Sg = self.groups, self.slots_per_group
        C = self.chunk_size
        T, P = self.total_slots, self.ecfg.max_pages_per_slot
        tokens = np.zeros((T, 1), np.int32)
        dec_table = np.zeros((T, P), np.int32)
        dec_lens = np.zeros((T,), np.int32)
        for s in dec:
            tokens[s, 0] = self.slots[s].tokens[-1]
            dec_table[s] = self.page_table[s]
            dec_lens[s] = self.cache_lens[s]
            self.slots[s].last_sched_step = self.step_count

        any_grant = any(grants)
        chunk = None
        if any_grant:
            # Narrow chunked-prefill lane: L = chunk_lanes rows PER GROUP,
            # lane i carrying that group's i-th granted chunk.  With no
            # grants anywhere the step runs the decode-only signature —
            # two static traces total, never per-prompt-length.
            L, nc = self.chunk_lanes, C // self.page_size
            ctoks = np.zeros((G, L, C), np.int32)
            ctable = np.zeros((G, L, P), np.int32)
            cstart = np.zeros((G, L), np.int32)
            ctrue = np.zeros((G, L), np.int32)
            cbud = np.zeros((G, L, nc), np.int32)
            clast = np.zeros((G, L), np.int32)
            for g, grant_g in enumerate(grants):
                for lane, s in enumerate(grant_g):
                    st = self.slots[s]
                    pos = st.prefill_pos
                    avail = st.padded[pos:pos + C]
                    ctoks[g, lane, :len(avail)] = avail
                    ctable[g, lane] = self.page_table[s]
                    cstart[g, lane] = pos
                    ctrue[g, lane] = st.true_len
                    cbud[g, lane] = chunked_lib.chunk_budget_rows(
                        self.policy, len(st.padded), pos, nc)
                    clast[g, lane] = min(max(st.true_len - 1 - pos, 0), C - 1)
            grp = (lambda a: a) if self.smesh is not None else (lambda a: a[0])
            chunk = {"tokens": jnp.asarray(grp(ctoks)),
                     "page_table": jnp.asarray(grp(ctable)),
                     "start": jnp.asarray(grp(cstart)),
                     "true_len": jnp.asarray(grp(ctrue)),
                     "budgets": jnp.asarray(grp(cbud)),
                     "last": jnp.asarray(grp(clast))}

        if self.smesh is not None:
            dec_in = jnp.asarray(tokens.reshape(G, Sg, 1))
            tab_in = jnp.asarray(dec_table.reshape(G, Sg, P))
            len_in = jnp.asarray(dec_lens.reshape(G, Sg))
        else:
            dec_in = jnp.asarray(tokens)
            tab_in = jnp.asarray(dec_table)
            len_in = jnp.asarray(dec_lens)
        t_dispatch = time.perf_counter()
        dec_logits, chunk_logits, self.pools = self._unified(
            self.params, self.pools, dec_in, tab_in, len_in, chunk)
        t_fetch = time.perf_counter()
        self.stats["dispatch_s"] += t_fetch - t_dispatch
        # The ONLY per-step host syncs, mesh or not: one logits fetch per
        # active lane kind (tracked so the scaling benchmark can assert the
        # mesh adds none).
        if dec:
            dec_logits = np.asarray(dec_logits)
            if self.smesh is not None:
                dec_logits = dec_logits.reshape(T, -1)
            self.stats["host_syncs"] += 1
        if any_grant:
            chunk_logits = np.asarray(chunk_logits)
            if self.smesh is None:
                chunk_logits = chunk_logits[None]       # (1, L, vocab)
            self.stats["host_syncs"] += 1
        now = time.perf_counter()
        self.stats["sync_wait_s"] += now - t_fetch
        self.stats["step_calls"] += 1
        if dec:
            self.stats["decode_steps"] += 1

        for s in dec:
            self.cache_lens[s] += 1       # the fed-back token is now cached
            st = self.slots[s]
            st.tokens.append(int(np.argmax(dec_logits[s])))
            st.token_latencies_s.append(now - st.last_token_t)
            st.last_token_t = now
            self.stats["tokens_generated"] += 1
            if self._is_finished(st):
                self._recycle(s)

        for g, grant_g in enumerate(grants):
            for lane, s in enumerate(grant_g):
                st = self.slots[s]
                st.prefill_pos += C
                self.stats["chunks"] += 1
                if st.prefill_pos >= len(st.padded):
                    # This chunk completed the prompt: its logits at the
                    # true last token are the request's first generated
                    # token.
                    st.tokens = [int(np.argmax(chunk_logits[g, lane]))]
                    st.phase = "decode"
                    self.cache_lens[s] = st.true_len
                    if st.prefix_keys:
                        # Contents of every full prompt page are now final
                        # — content-address them for future tenants
                        # (idempotent for pages this request itself
                        # shared; the partial tail page has no key and
                        # stays private).
                        for j, key in enumerate(st.prefix_keys):
                            self.allocators[g].register(
                                self.slot_pages[s][j], key)
                    st.first_token_t = st.last_token_t = now
                    st.ttft_s = now - st.arrival_t
                    self.stats["prefills"] += 1
                    self.stats["tokens_generated"] += 1
                    if self._is_finished(st):
                        self._recycle(s)
        return True

    # -- async pipeline -----------------------------------------------------

    def _dispatch(self, dec: list, grants: list) -> None:
        """Launch one sampled unified step and return WITHOUT waiting for
        its results.  All value-independent state advances here, at
        dispatch time, so the next ``_schedule`` sees it: ``cache_lens``
        (+1 per granted decode — the fed-back token will be cached),
        ``prefill_pos``/phase flips, prefix registration (the completing
        chunk's writes land before any later-dispatched reader, by
        per-device program order), and the step/chunk/prefill counters.
        Token VALUES — emissions, EOS, timestamps — wait for
        ``_reconcile``.  Decode inputs come from the device-resident
        ``token_buf``; idle lanes are masked out and their trash-page
        writes discarded, exactly like the sync step."""
        G, Sg, C = self.groups, self.slots_per_group, self.chunk_size
        T, P = self.total_slots, self.ecfg.max_pages_per_slot
        mask = np.zeros((T,), bool)
        dec_table = np.zeros((T, P), np.int32)
        dec_lens = np.zeros((T,), np.int32)
        dec_entries = []
        for s in dec:
            st = self.slots[s]
            mask[s] = True
            dec_table[s] = self.page_table[s]
            dec_lens[s] = self.cache_lens[s]
            st.last_sched_step = self.step_count
            dec_entries.append((s, st))

        any_grant = any(grants)
        chunk = None
        chunk_entries = []
        if any_grant:
            L, nc = self.chunk_lanes, C // self.page_size
            ctoks = np.zeros((G, L, C), np.int32)
            ctable = np.zeros((G, L, P), np.int32)
            cstart = np.zeros((G, L), np.int32)
            ctrue = np.zeros((G, L), np.int32)
            cbud = np.zeros((G, L, nc), np.int32)
            clast = np.zeros((G, L), np.int32)
            # Chunk-lane feedback routing: a COMPLETING chunk's sampled id
            # is the request's first token — "emit" steers it into the
            # lane's slot entry of token_buf inside the trace, so the
            # decode that follows next step reads it with no host hop.
            cslot = np.zeros((G, L), np.int32)
            cemit = np.zeros((G, L), bool)
            for g, grant_g in enumerate(grants):
                for lane, s in enumerate(grant_g):
                    st = self.slots[s]
                    pos = st.prefill_pos
                    avail = st.padded[pos:pos + C]
                    ctoks[g, lane, :len(avail)] = avail
                    ctable[g, lane] = self.page_table[s]
                    cstart[g, lane] = pos
                    ctrue[g, lane] = st.true_len
                    cbud[g, lane] = chunked_lib.chunk_budget_rows(
                        self.policy, len(st.padded), pos, nc)
                    clast[g, lane] = min(max(st.true_len - 1 - pos, 0),
                                         C - 1)
                    completes = pos + C >= len(st.padded)
                    cslot[g, lane] = s - g * Sg
                    cemit[g, lane] = completes
                    chunk_entries.append((g, lane, s, st, completes))
            grp = ((lambda a: a) if self.smesh is not None
                   else (lambda a: a[0]))
            chunk = {"tokens": jnp.asarray(grp(ctoks)),
                     "page_table": jnp.asarray(grp(ctable)),
                     "start": jnp.asarray(grp(cstart)),
                     "true_len": jnp.asarray(grp(ctrue)),
                     "budgets": jnp.asarray(grp(cbud)),
                     "last": jnp.asarray(grp(clast)),
                     "slot": jnp.asarray(grp(cslot)),
                     "emit": jnp.asarray(grp(cemit))}

        if self.smesh is not None:
            mask_in = jnp.asarray(mask.reshape(G, Sg))
            tab_in = jnp.asarray(dec_table.reshape(G, Sg, P))
            len_in = jnp.asarray(dec_lens.reshape(G, Sg))
        else:
            mask_in = jnp.asarray(mask)
            tab_in = jnp.asarray(dec_table)
            len_in = jnp.asarray(dec_lens)
        t0 = time.perf_counter()
        dec_ids, chunk_ids, self.token_buf, self.pools = self._unified(
            self.params, self.pools, self.token_buf, mask_in, tab_in,
            len_in, chunk)
        t1 = time.perf_counter()
        self.stats["dispatch_s"] += t1 - t0
        self.stats["step_calls"] += 1
        if dec:
            self.stats["decode_steps"] += 1

        for s in dec:
            self.cache_lens[s] += 1   # the fed-back token is now cached
            self.slots[s].inflight += 1
        for g, lane, s, st, completes in chunk_entries:
            st.prefill_pos += C
            self.stats["chunks"] += 1
            if completes:
                st.phase = "decode"
                self.cache_lens[s] = st.true_len
                st.inflight += 1      # the first token is in flight
                if st.prefix_keys:
                    for j, key in enumerate(st.prefix_keys):
                        self.allocators[g].register(
                            self.slot_pages[s][j], key)
                self.stats["prefills"] += 1
        self._inflight.append(_InFlight(
            dec_ids=dec_ids, chunk_ids=chunk_ids, dec=dec_entries,
            chunks=chunk_entries, step=self.step_count, dispatch_t=t1))

    def _reconcile(self, infl: _InFlight) -> None:
        """Absorb one in-flight step's sampled ids into host state: append
        decode tokens, materialize chunk-completion first tokens, stamp
        emission timestamps, detect EOS/max-tokens, recycle.  Entries
        whose request finished in the meantime (the EOS one-step
        lookahead, or an abort) are DISCARDED — their speculative step
        wrote only into the request's own still-reserved pages, so the
        discard costs nothing and streams stay bit-identical to the sync
        oracle.  ``host_syncs`` counts only non-overlapped reconciles
        (no newer dispatched step behind this one): those are the fetches
        that can leave the device idle — O(finished requests), not
        O(steps)."""
        overlapped = bool(self._inflight)
        t0 = time.perf_counter()
        dec_ids = chunk_ids = None
        if infl.dec:
            dec_ids = np.asarray(infl.dec_ids)
            if self.smesh is not None:
                dec_ids = dec_ids.reshape(-1)
            self.stats["id_fetches"] += 1
        if infl.chunks:
            chunk_ids = np.asarray(infl.chunk_ids)
            if self.smesh is None:
                chunk_ids = chunk_ids[None]             # (1, L)
            self.stats["id_fetches"] += 1
        now = time.perf_counter()
        self.stats["sync_wait_s"] += now - t0
        if not overlapped and (infl.dec or infl.chunks):
            self.stats["host_syncs"] += 1
        self.monitor.observe(infl.step, now - infl.dispatch_t)

        for s, st in infl.dec:
            st.inflight -= 1
            if st.finished:
                self.stats["lookahead_discards"] += 1
                continue
            st.tokens.append(int(dec_ids[s]))
            st.token_latencies_s.append(now - st.last_token_t)
            st.last_token_t = now
            self.stats["tokens_generated"] += 1
            if self._is_finished(st):
                self._recycle(s)
        for g, lane, s, st, completes in infl.chunks:
            if not completes:
                continue
            st.inflight -= 1
            if st.finished:
                self.stats["lookahead_discards"] += 1
                continue
            st.tokens = [int(chunk_ids[g, lane])]
            st.first_token_t = st.last_token_t = now
            st.ttft_s = now - st.arrival_t
            self.stats["tokens_generated"] += 1
            if self._is_finished(st):
                self._recycle(s)

    def _drain(self) -> None:
        """Reconcile every in-flight step, oldest first.  Callers that
        mutate pools or host token state out of band (preemption/offload,
        injected-failure aborts, the run() tail) must drain first: the
        device pipeline is always safe under program order, but host-side
        ``st.tokens`` runs one step behind it."""
        while self._inflight:
            self._reconcile(self._inflight.popleft())

    def drain(self) -> None:
        """Public: block until every dispatched step is reconciled.
        No-op for the synchronous engine.  Drivers stepping the engine
        manually (rather than through ``run``) call this before reading
        ``finished``/``stats`` as final."""
        self._drain()

    def _async_step(self) -> bool:
        """One ASYNC engine iteration: schedule from the current (one step
        stale in values, exact in structure) host state, dispatch without
        blocking, then reconcile only what exceeds ``async_depth``.  With
        depth 1 the host prepares and launches step N+1 while the device
        crunches step N — the logits-fetch stall of the sync loop
        disappears from the critical path."""
        dec_all = self._grantable_decodes()
        pre_all = [s for s, st in enumerate(self.slots)
                   if st is not None and st.phase == "prefill"]
        if not dec_all and not pre_all:
            self._last_chunk_step = [self.step_count] * self.groups
            self._drain()
            return False
        # Same injection point as the sync loop: strictly before this
        # step's dispatch, so a bounded retry never double-applies — and
        # the already-in-flight step is untouched by the failure.
        if self.chaos:
            self.chaos.maybe_fail_step(self.step_count)
        dec, grants = self._schedule(dec_all, pre_all, time.perf_counter())
        if not dec and not any(grants):
            # Every grantable token is already in flight (e.g. the final
            # token of the last active request): reconcile to make
            # progress instead of dispatching an empty step.
            self._drain()
            return False
        self._dispatch(dec, grants)
        while len(self._inflight) > self.ecfg.async_depth:
            self._reconcile(self._inflight.popleft())
        return True

    def _guarded_step(self) -> None:
        """The failure boundary around the mixed step: bounded retry of a
        failed step (injection precedes pool mutation, so retry is sound),
        then graceful degradation — abort the lowest-priority active
        request and retry with the smaller batch.  Working steps are timed
        by the StragglerMonitor; failed/idle ones don't pollute its EMA."""
        retries = 0
        while True:
            if not self._async:
                self.monitor.start()
            try:
                did_work = (self._async_step() if self._async
                            else self._mixed_step())
            except InjectedFailure as e:
                if not self._async:
                    self.monitor.cancel()
                self.stats["step_failures"] += 1
                retries += 1
                if retries > self.ecfg.max_step_retries:
                    if self._async:
                        # Drain before degrading: the in-flight step may
                        # finish (or already hold tokens for) the victim
                        # we are about to abort, and the abort frees
                        # pages the pipeline still references host-side.
                        self._drain()
                    victim = self._lowest_priority_active()
                    if victim is None:
                        if self._async:
                            continue   # drain cleared the actives; the
                                       # retry sees no work and returns
                        raise
                    self._abort(victim,
                                f"aborted: step failed {retries} times ({e})")
                    retries = 0
                continue
            if self._async:
                # Step latency is observed per reconcile (dispatch ->
                # ids materialized), not start/stop around the host-only
                # dispatch — see ``_reconcile``.
                return
            if did_work:
                self.monitor.stop(self.step_count)
            else:
                self.monitor.cancel()
            return

    def step(self) -> None:
        """One engine iteration: admit (with preemption) + shed, one guarded
        mixed batched step, recycle."""
        # Stamp arrival wall time the first step each request is
        # schedulable — TTFT and TTFT-SLO headroom count queueing time, so
        # a scheduler cannot hide latency in the waiting queue.
        now = time.perf_counter()
        for r in self.waiting:
            if r.arrival_step <= self.step_count and r.uid not in self._arrival_t:
                self._arrival_t[r.uid] = now
        self._admission_control()
        self._admit()
        self._guarded_step()
        self.step_count += 1
        if self._track_fallbacks:
            self._refresh_fallbacks()

    @property
    def pending(self) -> int:
        return (len(self.waiting) + len(self.preempted)
                + sum(st is not None for st in self.slots))

    def run(self, requests=(), max_steps: int = 100_000) -> list:
        """Drive submitted (+ given) requests to completion; returns
        FinishedRequests sorted by uid (failed ones carry ``.error``).
        Raises ``EngineStalledError`` naming the stuck requests if the
        engine cannot drain within ``max_steps`` further steps."""
        for r in requests:
            self.submit(r)
        start = self.step_count
        while self.pending:
            if self.step_count - start >= max_steps:
                raise EngineStalledError(
                    max_steps,
                    running=[st.req.uid for st in self.slots
                             if st is not None],
                    waiting=[r.uid for r in self.waiting],
                    preempted=[rec.st.req.uid for rec in self.preempted])
            self.step()
        if self._inflight:          # belt-and-braces: pending==0 implies
            self._drain()           # drained, but keep the invariant local
        return sorted(self.finished, key=lambda f: f.uid)
