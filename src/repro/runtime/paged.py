"""Block-paged Stem KV cache: page pool, per-page summaries, paged decode.

The serving engine (``runtime/engine.py``) stores every attention layer's
KV cache in a shared *page pool* instead of per-sequence contiguous
buffers.  A page holds ``page_size`` tokens (= the Stem ``block_size``, so
a page **is** a Stem block) and carries the block-pooled representations —
the anti-diagonal K group means and the max-pooled log||V|| — alongside the
raw K/V.  That makes Stem's coarse-to-fine decode native to the paged
layout: the page table *is* the block index, OAM scores pages directly
from the pooled summaries, and only the selected pages are gathered.

Layout (one attention layer):

  k, v : (hk, num_pages, page_size, d)    raw cache tokens
  kg   : (hk, num_pages, stride, d)       anti-diag group means (fp32)
  vm   : (hk, num_pages)                  max-pooled log ||V||  (fp32)

Page 0 is **reserved as the trash page**: inactive engine slots carry an
all-zero page table, so their (masked-out) decode writes land in page 0 and
never alias a live sequence.  The allocator never hands out page 0.

Per-slot logical state (page table row + cache length) lives *outside* the
pool and is passed to the jitted steps as plain ``(slots, max_pages)`` /
``(slots,)`` arrays — the pool itself is sequence-agnostic, which is what
makes admission/recycling a pure host-side page-table edit.
"""
from __future__ import annotations

import hashlib
from collections import Counter, OrderedDict
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunked as chunked_lib
from repro.core import decode as decode_lib
from repro.core import metric as metric_lib
from repro.core import policy as policy_lib
from repro.core.config import StemConfig  # noqa: F401  (legacy annotation)

TRASH_PAGE = 0

# ``cfg`` arguments below accept a legacy StemConfig, a SparsityPolicy or a
# registered policy name; the write paths only need ``block_size``/``stride``
# (duck-typed on both spellings), and the decode path routes metric +
# selection through the policy objects — the exact same ones the prefill
# and fixed-batch decode paths consume.


class PagePool(NamedTuple):
    """One attention layer's paged KV + Stem summary storage."""

    k: jnp.ndarray    # (hk, P, page, d)
    v: jnp.ndarray    # (hk, P, page, d)
    kg: jnp.ndarray   # (hk, P, stride, d) fp32 anti-diag group means
    vm: jnp.ndarray   # (hk, P) fp32 max-pooled log ||V||


def init_pool(num_pages: int, num_kv_heads: int, page_size: int, head_dim: int,
              stride: int, dtype=jnp.float32) -> PagePool:
    hk, p = num_kv_heads, num_pages
    return PagePool(
        k=jnp.zeros((hk, p, page_size, head_dim), dtype),
        v=jnp.zeros((hk, p, page_size, head_dim), dtype),
        kg=jnp.zeros((hk, p, stride, head_dim), jnp.float32),
        vm=jnp.full((hk, p), decode_lib.V_MAG_FLOOR, jnp.float32),
    )


def reset_pages(pool: PagePool, page_ids: jnp.ndarray) -> PagePool:
    """Return pages to their pristine state (zero K/V and group means, vm at
    the norm floor).  Must run on every page a request reserves *before* its
    first write: the allocator recycles pages without touching the pool, and
    ``append_token``'s kg-add / vm-max increments assume a fresh page — a
    previous tenant's summaries would otherwise leak into OAM selection.
    Duplicate ids (e.g. trash-page padding) are harmless: every write is the
    same pristine value."""
    return PagePool(
        k=pool.k.at[:, page_ids].set(0),
        v=pool.v.at[:, page_ids].set(0),
        kg=pool.kg.at[:, page_ids].set(0),
        vm=pool.vm.at[:, page_ids].set(decode_lib.V_MAG_FLOOR),
    )


def write_prefill_pages(pool: PagePool, page_ids: jnp.ndarray,
                        k: jnp.ndarray, v: jnp.ndarray, true_len: jnp.ndarray,
                        cfg) -> PagePool:
    """Scatter one prefilled sequence's K/V + summaries into the pool.

    k, v: (hk, L, d) with L = len(page_ids) * page_size (right-padded
    prompt).  Positions >= true_len are zeroed before the write so page
    contents and summaries match the zero-padded-cache semantics that
    ``append_token`` extends incrementally.
    """
    cfg = policy_lib.as_policy(cfg)
    hk, L, d = k.shape
    bs = cfg.block_size
    npages = L // bs
    keep = (jnp.arange(L) < true_len)[None, :, None]
    k = jnp.where(keep, k, 0)
    v = jnp.where(keep, v, 0)
    kp = k.reshape(hk, npages, bs, d)
    vp = v.reshape(hk, npages, bs, d)
    kg = metric_lib.antidiag_pool(k, bs, cfg.stride)        # (hk, npages, s, d)
    vm = metric_lib.value_block_magnitude(v, bs)            # (hk, npages)
    return PagePool(
        k=pool.k.at[:, page_ids].set(kp.astype(pool.k.dtype)),
        v=pool.v.at[:, page_ids].set(vp.astype(pool.v.dtype)),
        kg=pool.kg.at[:, page_ids].set(kg.astype(jnp.float32)),
        vm=pool.vm.at[:, page_ids].set(vm.astype(jnp.float32)),
    )


def reset_pools_stacked(pools, page_ids: jnp.ndarray):
    """``reset_pages`` over the engine's per-layer pool tree (PagePool
    leaves stacked ``(n_layers, hk, P, ...)``).  Runs once per admission in
    the chunked engine: chunk writes fully rewrite the prompt pages, but the
    decode-spill pages and the chunk grid's overrun pages must start
    pristine (the allocator recycles pages dirty, and ``append_token``'s
    kg-add / vm-max increments assume fresh pages)."""
    def one(pool: PagePool) -> PagePool:
        return PagePool(
            k=pool.k.at[:, :, page_ids].set(0),
            v=pool.v.at[:, :, page_ids].set(0),
            kg=pool.kg.at[:, :, page_ids].set(0),
            vm=pool.vm.at[:, :, page_ids].set(decode_lib.V_MAG_FLOOR),
        )

    return jax.tree.map(one, pools,
                        is_leaf=lambda x: isinstance(x, PagePool))


def copy_pages_stacked(pools, src: jnp.ndarray, dst: jnp.ndarray):
    """Copy one page's full contents (K/V + kg/vm summaries) ``src`` -> ``dst``
    across every layer's pool — the device half of copy-on-write.  A write
    into a prefix-shared page first redirects the writer to a fresh page via
    ``PageAllocator.cow``; this op then duplicates the shared contents so the
    writer's view is unchanged while other tenants keep the original.

    src, dst: scalar global page ids (static or traced int32)."""
    def one(pool: PagePool) -> PagePool:
        return PagePool(
            k=pool.k.at[:, :, dst].set(pool.k[:, :, src]),
            v=pool.v.at[:, :, dst].set(pool.v[:, :, src]),
            kg=pool.kg.at[:, :, dst].set(pool.kg[:, :, src]),
            vm=pool.vm.at[:, :, dst].set(pool.vm[:, :, src]),
        )

    return jax.tree.map(one, pools,
                        is_leaf=lambda x: isinstance(x, PagePool))


def prefix_page_keys(tokens, budgets, page_size: int) -> list:
    """Chained content keys for every FULL page of a prompt.

    Page j's K/V (and summaries) at layer l>0 depend on the *entire* token
    prefix up to page j — not just page j's tokens — and chunked prefill's
    per-row sparsity budgets depend on the prompt's padded length (the TPD
    schedule allots budget by row position over the whole prompt).  So the
    key for page j chains: key_j = H(key_{j-1} || tokens[j*bs:(j+1)*bs] ||
    budget_row_j).  Two tenants share page j iff every token through page j
    AND every budget row through page j agree — exactly the condition under
    which the engine's chunked prefill writes bit-identical pages.

    tokens: int sequence (the prompt).  budgets: per-block prefill budget
    rows for the prompt's padded length (``policy.prefill_budgets``).  The
    partial tail page (len(tokens) % page_size != 0 remainder) gets no key:
    it is always privately held.
    """
    full = len(tokens) // page_size
    keys = []
    h = b"stem-prefix-v1"
    for j in range(full):
        page = np.asarray(
            tokens[j * page_size:(j + 1) * page_size], np.int32).tobytes()
        row = int(budgets[j]).to_bytes(4, "little")
        h = hashlib.blake2b(h + page + row, digest_size=16).digest()
        keys.append(h.hex())
    return keys


def write_chunk_pages(pool: PagePool, page_table: jnp.ndarray,
                      chunk_start: jnp.ndarray, k_chunk: jnp.ndarray,
                      v_chunk: jnp.ndarray, true_len: jnp.ndarray,
                      cfg) -> PagePool:
    """Scatter one prefill *chunk* per slot into the pool, summaries included.

    The chunked-prefill write path: chunk starts are block-aligned and the
    chunk width is a page multiple, so every page a chunk touches is written
    whole — k/v zeroed at positions >= ``true_len`` (matching the
    zero-padded-cache semantics of ``write_prefill_pages``), kg/vm pooled
    from the zeroed chunk.  Building a prompt up chunk by chunk therefore
    reproduces ``write_prefill_pages`` of the full sequence page-for-page
    (pinned by ``tests/test_chunked.py``), and the partial final page is
    left exactly where ``append_token`` can continue it incrementally.

    page_table: (slots, max_pages) global page ids (all-zero rows for slots
    without a chunk this step — their writes land in the trash page).
    chunk_start, true_len: (slots,) int32 absolute positions.
    k_chunk, v_chunk: (slots, hk, C, d) with C % page_size == 0.
    Chunk-grid overrun past the prompt's pages writes the pristine value
    (zeros + the vm floor) into reserved-but-unused spill pages — harmless,
    decode has not started for a slot still prefilling.
    """
    cfg = policy_lib.as_policy(cfg)
    slots, hk, c, d = k_chunk.shape
    bs = cfg.block_size
    nc = c // bs
    pos = chunk_start[:, None] + jnp.arange(c)                  # (slots, C)
    keep = (pos < true_len[:, None])[:, None, :, None]
    k = jnp.where(keep, k_chunk, 0)
    v = jnp.where(keep, v_chunk, 0)
    kg = metric_lib.antidiag_pool(k, bs, cfg.stride)      # (slots, hk, nc, s, d)
    vm = metric_lib.value_block_magnitude(v, bs)          # (slots, hk, nc)
    kp = k.reshape(slots, hk, nc, bs, d)
    vp = v.reshape(slots, hk, nc, bs, d)

    maxp = page_table.shape[1]
    j_abs = chunk_start[:, None] // bs + jnp.arange(nc)[None, :]  # (slots, nc)
    # Chunk-grid blocks past the page-table width go to the trash page —
    # never clamp onto page maxp-1, which may hold real data from this very
    # chunk (all-zero payload either way: overrun positions are >= true_len).
    pids = jnp.where(
        j_abs < maxp,
        jnp.take_along_axis(page_table, jnp.minimum(j_abs, maxp - 1), axis=1),
        TRASH_PAGE)
    flat = pids.reshape(-1)                                       # (slots*nc,)

    def per_head(x):
        # (slots, hk, nc, ...) -> (hk, slots*nc, ...) aligned with ``flat``.
        return jnp.swapaxes(x, 0, 1).reshape((hk, slots * nc) + x.shape[3:])

    return PagePool(
        k=pool.k.at[:, flat].set(per_head(kp).astype(pool.k.dtype)),
        v=pool.v.at[:, flat].set(per_head(vp).astype(pool.v.dtype)),
        kg=pool.kg.at[:, flat].set(per_head(kg).astype(jnp.float32)),
        vm=pool.vm.at[:, flat].set(per_head(vm).astype(jnp.float32)),
    )


def append_token(pool: PagePool, page_table: jnp.ndarray,
                 cache_lens: jnp.ndarray, k_new: jnp.ndarray,
                 v_new: jnp.ndarray, cfg) -> PagePool:
    """Write one new token per slot into its current page + fold summaries.

    The increments reproduce ``write_prefill_pages`` of the grown sequence
    exactly (pinned by tests/test_engine.py): group means divide by the
    *full* group population (block_size / stride), so adding
    ``k_new / per_group`` into the token's group matches the batch pooling
    once the page fills — and the zero-dilution of a partial page in the
    meantime, which is the forced-local block anyway.

    page_table: (slots, max_pages) global page ids; cache_lens: (slots,)
    tokens already present (the new token lands at this position).
    k_new, v_new: (slots, hk, 1, d).  Slots whose page table points at the
    trash page (inactive) scribble page 0 harmlessly.
    """
    cfg = policy_lib.as_policy(cfg)
    b = k_new.shape[0]
    bs, stride = cfg.block_size, cfg.stride
    per_group = bs // stride
    lens = jnp.asarray(cache_lens, jnp.int32)
    pids = jnp.take_along_axis(page_table, (lens // bs)[:, None], axis=1)[:, 0]
    offs = lens % bs
    kn = k_new[:, :, 0]                                     # (slots, hk, d)
    vn = v_new[:, :, 0]
    knh = jnp.swapaxes(kn, 0, 1)                            # (hk, slots, d)
    vnh = jnp.swapaxes(vn, 0, 1)
    log_norm = jnp.log(jnp.maximum(
        jnp.linalg.norm(vnh.astype(jnp.float32), axis=-1), 1e-20))
    return PagePool(
        k=pool.k.at[:, pids, offs].set(knh.astype(pool.k.dtype)),
        v=pool.v.at[:, pids, offs].set(vnh.astype(pool.v.dtype)),
        kg=pool.kg.at[:, pids, offs % stride].add(
            (knh / per_group).astype(jnp.float32)),
        vm=pool.vm.at[:, pids].max(log_norm),
    )


def paged_sparse_decode(
    q: jnp.ndarray,             # (slots, hq, 1, d)
    pool: PagePool,
    page_table: jnp.ndarray,    # (slots, max_pages) global page ids
    cache_lens: jnp.ndarray,    # (slots,) valid tokens per slot
    cfg,
    budget_frac: float = decode_lib.DEFAULT_BUDGET_FRAC,
    executor: Optional[str] = None,
) -> jnp.ndarray:
    """Policy-sparse decode attention straight off the page pool.

    Identical math to ``core.decode.sparse_decode_attention`` over the
    logical (page-table-ordered) cache.  At ``budget_frac=1.0`` (top-k
    selector, the shared default) this equals dense decode over each slot's
    prefix.  ``executor`` picks the paged backend from the
    ``core/policy.py`` registry — "xla" (the gather oracle below) or
    "pallas" (the fused scalar-prefetch kernels in
    ``kernels/paged_attn.py``); None defers to ``policy.executor``.
    """
    cfg = policy_lib.as_policy(cfg)
    spec = policy_lib.get_paged_executor(executor or cfg.executor)
    return spec.decode_fn(q, pool, page_table, cache_lens, cfg, budget_frac)


def _paged_decode_xla(
    q: jnp.ndarray,
    pool: PagePool,
    page_table: jnp.ndarray,
    cache_lens: jnp.ndarray,
    cfg,
    budget_frac: float,
) -> jnp.ndarray:
    """The XLA gather backend: summaries are gathered per slot via the page
    table, the policy's metric + budget rule select *logical* page slots per
    row, and only the selected pages are fetched from the pool.  Kept as the
    differential oracle for the fused kernel (and the CPU-friendly default):
    every stage is a separate inspectable XLA op.  A metric registered once
    in ``core/policy.py`` serves the engine with no paged-specific code.
    """
    cfg = policy_lib.as_policy(cfg)
    b, hq, _, d = q.shape
    hk = pool.k.shape[0]
    group = hq // hk
    bs = cfg.block_size
    maxp = page_table.shape[1]

    # Gather per-slot summaries through the page table (cheap: pooled reps).
    kg_rows = jnp.swapaxes(pool.kg[:, page_table], 0, 1)   # (b, hk, maxp, s, d)
    vm_rows = jnp.swapaxes(pool.vm[:, page_table], 0, 1)   # (b, hk, maxp)

    m = decode_lib.decode_block_metric(q, kg_rows, vm_rows, cfg)
    sel = decode_lib.select_decode_blocks(m, cache_lens, cfg, budget_frac)

    # Logical slot index -> global page id, then fetch only selected pages.
    gp = jnp.take_along_axis(
        jnp.broadcast_to(page_table[:, None, None, :],
                         (b, hk, group, maxp)),
        sel.indices, axis=-1)                               # (b, hk, g, kmax)

    def fetch(kp, vp, gph):
        # kp, vp: (P, page, d); gph: (b, g, kmax) -> (b, g, kmax, page, d)
        return kp[gph], vp[gph]

    gk, gv = jax.vmap(fetch, in_axes=(0, 0, 1), out_axes=1)(
        pool.k, pool.v, gp)                                 # (b,hk,g,kmax,bs,d)
    return decode_lib.attend_selected(q, gk, gv, sel, cache_lens, bs)


# The gather oracle is the registry's "xla" backend for both serving lanes
# (kernels/paged_attn.py registers "pallas").
policy_lib.register_paged_executor(
    "xla", decode_fn=_paged_decode_xla,
    chunk_fn=chunked_lib._chunked_prefill_xla,
    sharding="kv-head")


# ---------------------------------------------------------------------------
# Host-side page allocator (pure python; page 0 reserved)
# ---------------------------------------------------------------------------

class PageAllocator:
    """Ref-counted free-list page allocator with a hash-keyed prefix index.
    Page 0 (the trash page for inactive slots) is never handed out.

    Every page id is in exactly one of THREE places at all times — the free
    list, the cached set (registered prefix pages at refcount 0, contents
    retained for future hits, reclaimable LRU-first), or the allocated set
    (refcount >= 1) — and ``check_conservation`` asserts that partition plus
    refcount bookkeeping.  ``evict``/``restore`` are the preemption-facing
    spellings of ``free``/``alloc``: a victim's pages return to the free
    list while its contents move to host memory (``runtime/offload.py``),
    and re-admission draws a fresh (possibly different) set of physical
    pages to scatter the snapshot back into.

    Prefix caching (``runtime/engine.py`` drives this):

    * ``register(page, key)`` content-addresses a full prompt page by its
      chained hash (``prefix_page_keys``) once its contents are final.
    * ``probe(key)`` answers admission's per-page lookup; ``share(page)``
      takes a reference on a hit (reviving a cached page if needed).
    * ``free`` decrements: a page leaves the allocated set only at ref 0,
      and a *registered* page then parks in the cached set instead of the
      free list, so a later tenant with the same prefix still hits.
    * ``cow(page)`` is the bookkeeping half of copy-on-write: it redirects
      the caller's reference on a shared page to a freshly allocated private
      page (the device copy is ``copy_pages_stacked``).

    ``evict_policy`` picks which cached (ref-0) page ``alloc`` cannibalizes
    when the free list runs dry: "lru" (default, least-recently parked) or
    "hit-rate" (fewest prefix hits since registration, LRU breaking ties) —
    a page that keeps getting shared is worth keeping over one that parked
    earlier but never hit.
    """

    EVICT_POLICIES = ("lru", "hit-rate")

    def __init__(self, num_pages: int, evict_policy: str = "lru"):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        if evict_policy not in self.EVICT_POLICIES:
            raise ValueError(f"evict_policy must be one of "
                             f"{self.EVICT_POLICIES}, got {evict_policy!r}")
        self.num_pages = num_pages
        self.evict_policy = evict_policy
        self._free = list(range(num_pages - 1, 0, -1))  # pop() -> lowest id
        self._allocated: set = set()
        self._ref: dict = {}            # page -> live reference count (>= 1)
        self._index: dict = {}          # prefix key -> page id (injective)
        self._key_of: dict = {}         # page id -> its prefix key
        self._cached: OrderedDict = OrderedDict()   # ref-0 registered, LRU
        self._hits: dict = {}           # registered page -> prefix-hit count
        self.evictions = 0
        self.restores = 0
        self.total_alloced = 0          # pages handed out, lifetime
        self.shares = 0                 # references taken via prefix hits
        self.cows = 0
        self.cache_reclaims = 0         # cached pages cannibalized by alloc

    @property
    def available(self) -> int:
        """Pages an ``alloc`` could obtain: truly free plus reclaimable
        (ref-0 cached prefix pages)."""
        return len(self._free) + len(self._cached)

    @property
    def cached_pages(self) -> int:
        return len(self._cached)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def alloc(self, n: int) -> Optional[list]:
        """Return n page ids at refcount 1, or None (all-or-nothing).
        Draws from the free list first, then reclaims cached prefix pages
        per ``evict_policy`` (unregistering them — their contents are
        gone)."""
        if n > self.available:
            return None
        pages = []
        for _ in range(n):
            if self._free:
                p = self._free.pop()
            else:
                p = self._reclaim_cached()
            pages.append(p)
            self._ref[p] = 1
        self._allocated.update(pages)
        self.total_alloced += n
        return pages

    def _reclaim_cached(self) -> int:
        """Pick a cached (ref-0) prefix page to cannibalize.  "lru" takes
        the least-recently parked page; "hit-rate" takes the page with the
        fewest prefix hits since registration, breaking ties LRU-first."""
        if self.evict_policy == "hit-rate":
            lru_rank = {q: i for i, q in enumerate(self._cached)}
            p = min(self._cached,
                    key=lambda q: (self._hits.get(q, 0), lru_rank[q]))
            del self._cached[p]
        else:
            p, _ = self._cached.popitem(last=False)
        self._unregister(p)
        self.cache_reclaims += 1
        return p

    def free(self, pages) -> None:
        """Drop one reference per listed page.  A page leaves the allocated
        set only when its refcount hits 0; registered pages then park in the
        cached set (contents retained for prefix hits), others return to the
        free list."""
        for p in pages:
            if not (0 < p < self.num_pages):
                raise ValueError(f"bad page id {p}")
            if p not in self._allocated:
                raise ValueError(f"double free of page {p}")
            self._ref[p] -= 1
            if self._ref[p] > 0:
                continue
            del self._ref[p]
            self._allocated.discard(p)
            if p in self._key_of:
                self._cached[p] = None          # most-recently-used end
            else:
                self._free.append(p)

    def probe(self, key) -> Optional[int]:
        """Page currently holding the content addressed by ``key`` (live or
        cached), or None.  Probing does NOT pin — callers must ``share``
        every hit before any ``alloc`` that could reclaim a cached page."""
        return self._index.get(key)

    def share(self, page: int) -> int:
        """Take one reference on an indexed page (a prefix-cache hit).  A
        cached (ref-0) page is revived into the allocated set."""
        if page in self._cached:
            del self._cached[page]
            self._allocated.add(page)
            self._ref[page] = 1
        elif page in self._allocated:
            self._ref[page] += 1
        else:
            raise ValueError(f"page {page} is neither allocated nor cached")
        self.shares += 1
        self._hits[page] = self._hits.get(page, 0) + 1
        return page

    def register(self, page: int, key) -> None:
        """Content-address an allocated page under ``key``.  First writer
        wins: if an equivalent page is already canonical for the key the
        call is a no-op (both pages hold identical contents; the newcomer
        stays an ordinary private page)."""
        if page not in self._allocated:
            raise ValueError(f"cannot register unallocated page {page}")
        old = self._key_of.get(page)
        if old == key:
            return
        if key in self._index:
            return
        if old is not None:
            del self._index[old]
        self._index[key] = page
        self._key_of[page] = key

    def cow(self, page: int) -> Optional[int]:
        """Copy-on-write bookkeeping: exchange the caller's reference on a
        shared page for a fresh private page (all-or-nothing; None if no
        page is available, caller's reference untouched).  The caller then
        copies device contents via ``copy_pages_stacked``."""
        fresh = self.alloc(1)
        if fresh is None:
            return None
        self.free([page])
        self.cows += 1
        return fresh[0]

    def _unregister(self, page: int) -> None:
        key = self._key_of.pop(page, None)
        if key is not None and self._index.get(key) == page:
            del self._index[key]
        self._hits.pop(page, None)

    def evict(self, pages) -> None:
        """Free a preemption victim's pages (contents live on in the host
        snapshot; the device pages are immediately reusable)."""
        self.free(pages)
        self.evictions += 1

    def restore(self, n: int) -> Optional[list]:
        """Allocate pages for a re-admitted (offloaded) request.  The ids
        need not match the evicted ones — the page table re-maps."""
        pages = self.alloc(n)
        if pages is not None:
            self.restores += 1
        return pages

    def check_conservation(self, held=None) -> bool:
        """Assert the three-way partition: free list, cached set and
        allocated set are disjoint and together cover pages 1..num_pages-1;
        every allocated page has a refcount >= 1, every cached page is
        registered, and the prefix index is consistent.  With ``held`` (a
        MULTISET of page ids — one entry per live reference the caller
        believes it holds, e.g. slot_pages plus preempted pins), the
        per-page counts must equal the refcounts exactly — no orphaned pages
        or leaked references after any recycle/preempt/restore/share path."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate page ids in the free list")
        cached = set(self._cached)
        parts = [("free", free), ("cached", cached),
                 ("allocated", self._allocated)]
        for i in range(len(parts)):
            for j in range(i + 1, len(parts)):
                (na, a), (nb, b) = parts[i], parts[j]
                if a & b:
                    raise AssertionError(
                        f"pages both {na} and {nb}: {sorted(a & b)}")
        universe = set(range(1, self.num_pages))
        if free | cached | self._allocated != universe:
            lost = sorted(universe - free - cached - self._allocated)
            raise AssertionError(f"orphaned pages (neither free, cached nor "
                                 f"allocated): {lost}")
        if set(self._ref) != self._allocated:
            raise AssertionError(
                f"refcount table out of sync with allocated set: "
                f"refs {sorted(self._ref)} vs {sorted(self._allocated)}")
        if any(r < 1 for r in self._ref.values()):
            bad = {p: r for p, r in self._ref.items() if r < 1}
            raise AssertionError(f"allocated pages with refcount < 1: {bad}")
        for p in cached:
            if p not in self._key_of:
                raise AssertionError(f"cached page {p} has no prefix key")
        for key, p in self._index.items():
            if self._key_of.get(p) != key:
                raise AssertionError(
                    f"prefix index out of sync: key {key!r} -> page {p} but "
                    f"page maps to {self._key_of.get(p)!r}")
            if p in free:
                raise AssertionError(f"indexed page {p} is on the free list")
        if held is not None:
            counts = dict(Counter(held))
            if counts != self._ref:
                over = {p: c for p, c in counts.items()
                        if c != self._ref.get(p, 0)}
                under = {p: r for p, r in self._ref.items()
                         if r != counts.get(p, 0)}
                raise AssertionError(
                    f"allocator/holder refcount mismatch: held {over} vs "
                    f"allocated {under}")
        return True
