"""On-device token sampling for the serving engine.

The synchronous engine fetches full ``(slots, vocab)`` logits to the host
every step and samples with ``np.argmax`` — a per-step device->host
transfer that scales with vocab size and serves exactly one int32 of
information per slot.  A ``Sampler`` closes that gap: it runs INSIDE the
jitted unified step (``transformer.paged_sampled_step``), so the only
per-step transfer is the sampled ``(slots,) int32`` token ids, and the
fed-back decode inputs never leave the device at all.

Samplers are pure jax functions ``logits (..., vocab) -> ids (...) int32``
over the last axis, registered by name so ``EngineConfig.sampler`` /
``--sampler`` stay declarative.  ``"greedy"`` (argmax) is the default and
the only stream-deterministic choice — the bit-identity differentials
(async vs sync, sharded vs single-device) are pinned against it.
Stochastic samplers (temperature / top-p) slot into the same hook but are
engine-stream-deterministic only with a threaded PRNG, which the engine
does not carry yet; ``TemperatureSampler`` exists as the op-level
reference for that extension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class GreedySampler:
    """argmax over the vocab axis — matches ``np.argmax`` tie-breaking
    (first maximal index), so on-device sampling is bit-identical to the
    legacy host-side sampling of the same logits.

    Not ``jnp.argmax``: XLA lowers argmax to a variadic (value, index)
    reduce that runs scalar on CPU — ~3x slower than two plain reduces at
    serving vocab sizes, enough to erase the async pipeline's win.  A
    vectorizable max + first-matching-index min is the same function:
    ``min`` over the iota keeps the FIRST maximal index on ties, exactly
    numpy's rule."""

    deterministic = True

    def __call__(self, logits: jnp.ndarray) -> jnp.ndarray:
        m = jnp.max(logits, axis=-1, keepdims=True)
        vocab = logits.shape[-1]
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        return jnp.min(jnp.where(logits == m, iota, vocab), axis=-1)


class TemperatureSampler:
    """Categorical sampling at ``temperature`` — the op-level reference for
    the stochastic-sampler extension.  Requires an explicit PRNG key per
    call; the serving engine does not thread one yet, so this sampler is
    exercised at the op level only (``tests/test_async_engine.py``)."""

    deterministic = False

    def __init__(self, temperature: float = 1.0):
        if temperature <= 0:
            raise ValueError(f"temperature must be > 0, got {temperature}")
        self.temperature = temperature

    def __call__(self, logits: jnp.ndarray, *, key=None) -> jnp.ndarray:
        if key is None:
            raise ValueError("TemperatureSampler needs an explicit PRNG key")
        return jax.random.categorical(
            key, logits / self.temperature, axis=-1).astype(jnp.int32)


_SAMPLERS = {}


def register_sampler(name: str, factory) -> None:
    """Register a sampler factory (``() -> Sampler``) under ``name``."""
    if name in _SAMPLERS:
        raise ValueError(f"sampler {name!r} already registered")
    _SAMPLERS[name] = factory


def get_sampler(name: str):
    try:
        return _SAMPLERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown sampler {name!r} (registered: {sorted(_SAMPLERS)})")


register_sampler("greedy", GreedySampler)
