"""Engine-level fault injection: the serving-side chaos harness.

Generalizes the training path's ``FailureInjector`` (one channel: "the
step raised") into the failure modes a serving engine actually meets,
each injectable at configured *engine* steps:

  * **allocator exhaustion** (``deny_alloc_steps``) — an allocation that
    should succeed reports no memory.  The engine must treat it exactly
    like a genuinely full pool: the admission blocks (or sheds) and retries
    next step; nothing leaks, nothing crashes.
  * **step failure** (``fail_steps``) — the mixed batched step raises
    *before* any pool mutation (the injection point is ahead of the jitted
    call, which is what makes bounded retry sound: no partial summary
    increments to double-apply).  Transient by default; ``step_repeats``
    > the engine's retry bound models a persistent fault, which the engine
    degrades through by aborting its lowest-priority active request and
    retrying with the smaller batch.
  * **restore failure** (``fail_restore_steps``) — re-admitting an
    offloaded request fails mid-swap-in.  The engine must free the freshly
    allocated pages (conservation), keep the host snapshot, and either
    retry later or abort the request with an explicit error.

Every injection is deterministic (configured steps, no RNG) so chaos runs
are reproducible and assertable in CI.  ``counts`` records what actually
fired, which the chaos tests cross-check against engine stats.
"""
from __future__ import annotations

import dataclasses

from repro.runtime.fault_tolerance import FailureInjector, InjectedFailure


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Deterministic injection plan, in engine-step coordinates."""
    deny_alloc_steps: tuple = ()     # page allocations forced to fail
    fail_steps: tuple = ()           # mixed steps that raise pre-mutation
    fail_restore_steps: tuple = ()   # offload restores that raise mid-swap
    step_repeats: int = 1            # consecutive failures per fail_step
    restore_repeats: int = 1         # consecutive failures per restore step


class ChaosInjector:
    """Per-channel failure injectors + fired counters for one engine."""

    def __init__(self, cfg: ChaosConfig = ChaosConfig()):
        self.cfg = cfg
        self._alloc = FailureInjector(tuple(cfg.deny_alloc_steps))
        self._step = FailureInjector(tuple(cfg.fail_steps),
                                     repeats=cfg.step_repeats)
        self._restore = FailureInjector(tuple(cfg.fail_restore_steps),
                                        repeats=cfg.restore_repeats)

    @property
    def counts(self) -> dict:
        return {"alloc_denied": self._alloc.fired,
                "step_failed": self._step.fired,
                "restore_failed": self._restore.fired}

    def deny_alloc(self, step: int) -> bool:
        """True when this step's page allocation must report exhaustion."""
        return self._alloc.should_fail(step)

    def maybe_fail_step(self, step: int) -> None:
        """Raise ``InjectedFailure`` ahead of the jitted mixed step."""
        if self._step.should_fail(step):
            raise InjectedFailure(f"injected step failure at engine step {step}")

    def maybe_fail_restore(self, step: int) -> None:
        """Raise ``InjectedFailure`` mid-restore of an offloaded request."""
        if self._restore.should_fail(step):
            raise InjectedFailure(
                f"injected restore failure at engine step {step}")
