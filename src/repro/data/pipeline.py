"""Deterministic, seekable synthetic data pipeline.

Requirements served:
  * **O(1) skip-ahead** — ``batch_at(step)`` is a pure function of
    (seed, step), so a restarted job resumes the exact token stream without
    replaying the pipeline (fault-tolerance contract, tested in
    tests/test_fault_tolerance.py).
  * **Shard-aware** — ``make_global_batch`` materializes only the local
    shard per process via ``jax.make_array_from_callback`` (single-process
    here, but the code path is the multi-host one).
  * **Structured tokens** — Zipf marginals + copied motifs, so attention on
    trained-from-scratch models develops sinks/heavy-hitters rather than
    white noise (matters for the Stem accuracy benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 32
    kind: str = "lm"              # lm | vlm | encdec
    d_model: int = 0              # for stub embeddings (vlm/encdec)
    frames: int = 0

    def _rng(self, step: int) -> np.random.Generator:
        # Philox is counter-based: O(1) seek to any step.
        return np.random.Generator(np.random.Philox(key=self.seed, counter=step))

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = self._rng(step)
        b, s, v = self.global_batch, self.seq_len, self.vocab_size
        if self.kind == "vlm":
            s_img = s // 4
            s_tok = s - s_img
        else:
            s_tok = s
        # Zipf-distributed tokens (clipped to vocab).
        toks = rng.zipf(self.zipf_a, size=(b, s_tok + 1)).astype(np.int64)
        toks = (toks - 1) % v
        # Plant copied motifs: a motif early in the sequence reappears later
        # (retrieval structure -> long-range dependencies for Stem to keep).
        m = min(self.motif_len, s_tok // 4)
        if m > 1:
            src = rng.integers(0, s_tok // 2 - m, size=b)
            dst = rng.integers(s_tok // 2, s_tok - m, size=b)
            for i in range(b):
                toks[i, dst[i] : dst[i] + m] = toks[i, src[i] : src[i] + m]
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if self.kind == "vlm":
            batch["patch_embeds"] = rng.standard_normal(
                (b, s_img, self.d_model), dtype=np.float32)
        if self.kind == "encdec":
            batch["frames"] = rng.standard_normal(
                (b, self.frames, self.d_model), dtype=np.float32)
        return batch


def make_global_batch(batch: dict[str, np.ndarray], mesh, shardings: dict):
    """Host batch -> global jax.Arrays laid out per the input shardings.

    Uses make_array_from_callback so each process only touches its shard —
    the single-host degenerate case of the multi-host feed."""
    out = {}
    for name, arr in batch.items():
        sh = shardings[name]

        def cb(index, arr=arr):
            return arr[index]

        out[name] = jax.make_array_from_callback(arr.shape, sh, cb)
    return out
