from repro.data.pipeline import SyntheticLMData, make_global_batch

__all__ = ["SyntheticLMData", "make_global_batch"]
