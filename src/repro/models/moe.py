"""Mixture-of-Experts FFN: expert-parallel shard_map dispatch.

Why shard_map (DESIGN.md §4): the dispatch is a data-dependent scatter,
which GSPMD either replicates (hundreds of GB of dispatch buffers) or wraps
in enormous masked all-reduces.  Writing the communication pattern
explicitly gives the textbook expert-parallel layer:

  * routing (softmax -> top-k -> per-row cumsum positions) is elementwise /
    local — computed under normal GSPMD, batch-sharded on `data`;
  * inside ``shard_map``: each `model` shard owns E/|model| experts, scatters
    *its own* tokens into a local (b, E_local, C, d) buffer (tokens routed
    to remote experts contribute zero), runs the expert FFN on local
    weights, gathers back, and the partial outputs are combined with ONE
    ``psum`` over `model` per layer (Megatron-MLP pattern);
  * FSDP archs all-gather the expert weights over `data` on entry —
    backward automatically reduce-scatters the weight grads (ZeRO-3).

Capacity is per batch row (GShard group = sequence): position-in-expert is
a cumsum along the row's own (s x K) slots, so there are no cross-shard
prefix sums and every shape is static; overflow drops (Switch-style).

Covers: plain top-k routed experts, deepseek (+1 shared expert, first-k
dense in the assembly), arctic (+parallel dense residual FFN),
Switch load-balance aux loss.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.models import common, mlp
from repro.sharding import context as shctx
from repro.sharding import rules as rules_lib


def init(ini: common.Initializer, d_model: int, moe: MoEConfig, activation: str) -> dict:
    e, f = moe.num_experts, moe.expert_d_ff
    p = {
        "router": ini.normal((d_model, e), ("embed", "experts"), dtype=jnp.float32),
        "w_gate": ini.normal((e, d_model, f), ("experts", "embed", "expert_mlp")),
        "w_up": ini.normal((e, d_model, f), ("experts", "embed", "expert_mlp")),
        "w_down": ini.normal((e, f, d_model), ("experts", "expert_mlp", "embed")),
    }
    if moe.shared_experts:
        p["shared"] = mlp.init(ini, d_model, moe.shared_d_ff * moe.shared_experts, activation)
    if moe.residual_dense:
        p["residual"] = mlp.init(ini, d_model, moe.residual_d_ff, activation)
    return p


def _route(params, x, moe: MoEConfig):
    """Top-k routing + per-row positions.  All local/elementwise.

    Position-in-expert uses a **sort-based ranking** instead of the classic
    cumsum over a (T*K, E) one-hot: that one-hot costs O(s*K*E) int32 per
    layer (67 GB/device/layer at deepseek scale) while the stable argsort
    costs O(s*K log) on int32 vectors (§Perf deepseek iteration 1)."""
    b, s, d = x.shape
    E, K = moe.num_experts, moe.top_k
    capacity = max(1, int(moe.capacity_factor * s * K / E))
    router_logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    gates = jax.nn.softmax(router_logits, axis=-1)
    weights, experts = jax.lax.top_k(gates, K)                  # (b, s, K)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    flat_e = experts.reshape(b, s * K)                          # slot-major
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    idx = jnp.arange(s * K, dtype=jnp.int32)[None]
    is_start = jnp.concatenate(
        [jnp.ones((b, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    start_idx = jax.lax.cummax(jnp.where(is_start, idx, 0), axis=1)
    pos_sorted = idx - start_idx                                # rank in group
    inv = jnp.argsort(order, axis=1)
    pos = jnp.take_along_axis(pos_sorted, inv, axis=1).reshape(b, s, K)
    keep = pos < capacity
    return gates, weights, experts, pos.astype(jnp.int32), keep, capacity


def _expert_ffn_local(x, experts, pos, keep, weights, wg, wu, wd,
                      *, e_offset, e_local, capacity, activation):
    """Dispatch + expert FFN + combine for the experts [e_offset,
    e_offset + e_local) on this shard.  Everything local; the caller psums.
    """
    b, s, d = x.shape
    K = experts.shape[-1]
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    e_rel = experts - e_offset
    own = (e_rel >= 0) & (e_rel < e_local) & keep
    e_rel = jnp.clip(e_rel, 0, e_local - 1)
    rows = jnp.arange(b)[:, None]

    buf = jnp.zeros((b, e_local, capacity, d), x.dtype)
    for k in range(K):
        p_k = jnp.where(own[..., k], pos[..., k], capacity - 1)
        contrib = jnp.where(own[..., k, None], x, 0)
        buf = buf.at[rows, e_rel[..., k], p_k].add(contrib, mode="drop")

    g = act(jnp.einsum("becd,edf->becf", buf, wg))
    u = jnp.einsum("becd,edf->becf", buf, wu)
    out_buf = jnp.einsum("becf,efd->becd", g * u, wd)

    y = jnp.zeros((b, s, d), x.dtype)
    for k in range(K):
        p_k = jnp.where(own[..., k], pos[..., k], capacity - 1)
        got = out_buf[rows, e_rel[..., k], p_k]
        w_k = (weights[..., k] * own[..., k]).astype(x.dtype)
        y = y + got * w_k[..., None]
    return y


def apply(
    params: dict,
    x: jnp.ndarray,                  # (b, s, d)
    moe: MoEConfig,
    activation: str,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (b, s, d), aux_loss scalar)."""
    E, K = moe.num_experts, moe.top_k
    gates, weights, experts, pos, keep, capacity = _route(params, x, moe)

    ctx = shctx.current()
    if ctx is not None and "model" in ctx[1].axis_names \
            and ctx[1].shape["model"] > 1 and E % ctx[1].shape["model"] == 0:
        rules, mesh = ctx
        n_model = mesh.shape["model"]
        e_local = E // n_model
        batch_axes = rules["batch"]
        bspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0])
        fsdp = rules.get("expert_mlp", ()) == ("data",)
        wspec = P("model", None, None)

        def local_fn(x, experts, pos, keep, weights, wg, wu, wd):
            shard = jax.lax.axis_index("model")
            if fsdp:
                # ZeRO-3: weights additionally sharded on data over d_model /
                # d_ff; gather on use, reduce-scatter grads on the way back.
                wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
            y = _expert_ffn_local(
                x, experts, pos, keep, weights, wg, wu, wd,
                e_offset=shard * e_local, e_local=e_local,
                capacity=capacity, activation=activation)
            return jax.lax.psum(y, "model")

        if fsdp:
            wspec_g = P("model", "data", None)
            wspec_d = P("model", None, "data")
        else:
            wspec_g = wspec_d = wspec
        tok_spec = P(*bspec, None, None)
        small = P(*bspec, None, None)
        y = jax.shard_map(
            local_fn, mesh=mesh,
            in_specs=(tok_spec, small, small, small, small,
                      wspec_g, wspec_g, wspec_d),
            out_specs=tok_spec,
            check_vma=False,
        )(x, experts, pos, keep, weights,
          params["w_gate"], params["w_up"], params["w_down"])
    else:
        y = _expert_ffn_local(
            x, experts, pos, keep, weights,
            params["w_gate"], params["w_up"], params["w_down"],
            e_offset=0, e_local=E, capacity=capacity, activation=activation)

    if moe.shared_experts:
        y = y + mlp.apply(params["shared"], x, activation)
    if moe.residual_dense:
        y = y + mlp.apply(params["residual"], x, activation)

    # Switch-style load-balance aux loss: E * sum_e f_e * p_e.
    # (bincount scatter, not a (b,s,K,E) one-hot.)
    me = gates.mean(axis=(0, 1))                              # (E,)
    b_, s_ = x.shape[0], x.shape[1]
    ce = jnp.zeros((E,), jnp.float32).at[experts.reshape(-1)].add(1.0) / (b_ * s_)
    aux = (me * ce).sum() * E * moe.router_aux_weight
    return y, aux


def expert_flops_per_token(d_model: int, moe: MoEConfig) -> float:
    """Active FLOPs per token for MODEL_FLOPS accounting."""
    per_expert = 3 * 2 * d_model * moe.expert_d_ff
    total = moe.top_k * per_expert
    if moe.shared_experts:
        total += 3 * 2 * d_model * moe.shared_d_ff * moe.shared_experts
    if moe.residual_dense:
        total += 3 * 2 * d_model * moe.residual_d_ff
    return total
