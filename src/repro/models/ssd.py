"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Selective state space with scalar-identity A per head:
  h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t^T     (state: headdim x N)
  y_t = C_t . h_t + D x_t

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
einsums *within* chunks (MXU-friendly (Q x Q) tiles) and a sequential
``lax.scan`` over chunk states — O(N Q d) compute, O(N/Q) scan depth.
Decode is the O(1) recurrence.  Heads shard on the `model` axis.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSDConfig
from repro.models import common


class SSDState(NamedTuple):
    h: jnp.ndarray        # (b, heads, headdim, state) fp32
    conv: jnp.ndarray     # (b, conv_width-1, conv_dim)
    pos: jnp.ndarray


def _dims(cfg: ArchConfig):
    s: SSDConfig = cfg.ssd
    d_inner = s.expand * cfg.d_model
    heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.state_dim     # x, B, C go through the conv
    return s, d_inner, heads, conv_dim


def init(ini: common.Initializer, cfg: ArchConfig) -> dict:
    s, d_inner, heads, conv_dim = _dims(cfg)
    d = cfg.d_model
    return {
        "w_in": ini.normal((d, 2 * d_inner + 2 * s.state_dim + heads),
                           ("embed", "rnn")),
        "conv_w": ini.normal((s.conv_width, conv_dim), ("conv", "rnn"), scale=0.1),
        "conv_b": ini.zeros((conv_dim,), ("rnn",)),
        "a_log": ini.value(jnp.log(jnp.linspace(1.0, 16.0, heads)), ("heads",)),
        "dt_bias": ini.value(jnp.log(jnp.expm1(jnp.full((heads,), 0.01))), ("heads",)),
        "d_skip": ini.ones((heads,), ("heads",), dtype=jnp.float32),
        "norm": ini.zeros((d_inner,), ("rnn",)),
        "w_out": ini.normal((d_inner, d), ("rnn", "embed")),
    }


def _split_proj(params, x, cfg: ArchConfig):
    s, d_inner, heads, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    return z, xbc, dt


def _conv_silu(xbc, params):
    cw = params["conv_w"].shape[0]
    pads = jnp.pad(xbc, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pads[:, i : i + xbc.shape[1], :] * params["conv_w"][i] for i in range(cw))
    return jax.nn.silu(out + params["conv_b"])


def _ssm_inputs(xbc, dt, params, cfg: ArchConfig):
    s, d_inner, heads, _ = _dims(cfg)
    xi, B, C = jnp.split(xbc, [d_inner, d_inner + s.state_dim], axis=-1)
    b, n = xi.shape[0], xi.shape[1]
    xh = xi.reshape(b, n, heads, s.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])    # (b,n,h)
    a = -jnp.exp(params["a_log"])                                        # (h,)
    log_decay = dt * a                                                   # (b,n,h) <= 0
    return xh, B, C, dt, log_decay


def _chunked_ssd(xh, B, C, dt, log_decay, chunk: int, d_skip):
    """Chunked SSD scan.  xh: (b,n,h,p); B,C: (b,n,N); dt,log_decay: (b,n,h)."""
    b, n, h, p = xh.shape
    N = B.shape[-1]
    q = min(chunk, n)
    n_orig = n
    if n % q:
        # pad to a chunk multiple: dt=0 at padding -> a=1, b=0, so the
        # carried state is unaffected; padded outputs are sliced off.
        pad = q - n % q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0)))
        n = n + pad
    nc = n // q
    xc = xh.reshape(b, nc, q, h, p).astype(jnp.float32)
    Bc = B.reshape(b, nc, q, N).astype(jnp.float32)
    Cc = C.reshape(b, nc, q, N).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h)
    ld = log_decay.reshape(b, nc, q, h)
    cum = jnp.cumsum(ld, axis=2)                                 # (b,nc,q,h)

    # Intra-chunk (quadratic within chunk): L[i,j] = exp(cum_i - cum_j), j<=i.
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]           # (b,nc,i,j,h)
    mask = jnp.tril(jnp.ones((q, q), bool))
    # Mask *before* exp: li > 0 above the diagonal would overflow and poison
    # gradients through the where.
    li = jnp.where(mask[None, None, :, :, None], li, -jnp.inf)
    L = jnp.exp(li)
    cb = jnp.einsum("bciN,bcjN->bcij", Cc, Bc)                   # (b,nc,i,j)
    w = cb[..., None] * L * dtc[:, :, None, :, :]                # (b,nc,i,j,h)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)

    # Chunk-final states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j^T.
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)              # (b,nc,q,h)
    sB = Bc[..., None, :] * (dtc * decay_to_end)[..., None]      # (b,nc,q,h,N)
    S_chunk = jnp.einsum("bcqhN,bcqhp->bchpN", sB, xc)           # (b,nc,h,p,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (b,nc,h)

    # Sequential pass over chunks for the carried state.
    def step(S_prev, inp):
        S_c, dec = inp                                           # (b,h,p,N), (b,h)
        S_new = S_prev * dec[..., None, None] + S_c
        return S_new, S_prev

    S0 = jnp.zeros((b, h, p, N), jnp.float32)
    S_final, S_prevs = jax.lax.scan(
        step,
        S0,
        (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)                   # (b,nc,h,p,N)

    # Inter-chunk: y_inter[i] = exp(cum_i) * C_i . S_prev.
    decay_in = jnp.exp(cum)                                      # (b,nc,q,h)
    y_inter = jnp.einsum("bciN,bchpN->bcihp", Cc, S_prevs) * decay_in[..., None]

    y = (y_intra + y_inter).reshape(b, n, h, p)
    y = y + d_skip[None, None, :, None] * xh.astype(jnp.float32)
    return y[:, :n_orig], S_final


def apply_full(params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    out, _ = _forward(params, x, cfg)
    return out


def _forward(params, x, cfg: ArchConfig):
    s, d_inner, heads, _ = _dims(cfg)
    z, xbc, dt = _split_proj(params, x, cfg)
    xbc = _conv_silu(xbc, params)
    xh, B, C, dtv, ld = _ssm_inputs(xbc, dt, params, cfg)
    y, S_final = _chunked_ssd(xh, B, C, dtv, ld, s.chunk_size, params["d_skip"])
    b, n = x.shape[0], x.shape[1]
    y = y.reshape(b, n, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = common.rms_norm(y, params["norm"])
    return jnp.einsum("bse,ed->bsd", y, params["w_out"]), S_final


def init_state(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> SSDState:
    s, d_inner, heads, conv_dim = _dims(cfg)
    return SSDState(
        h=jnp.zeros((batch, heads, s.head_dim, s.state_dim), jnp.float32),
        conv=jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def prefill_into_state(params, x, cfg: ArchConfig):
    s, d_inner, heads, conv_dim = _dims(cfg)
    out, S_final = _forward(params, x, cfg)
    _, xbc_raw, _ = _split_proj(params, x, cfg)
    cw = s.conv_width
    state = SSDState(
        h=S_final,
        conv=xbc_raw[:, -(cw - 1):].astype(x.dtype),
        pos=jnp.asarray(x.shape[1], jnp.int32),
    )
    return out, state


def apply_decode(params, x: jnp.ndarray, cfg: ArchConfig, state: SSDState):
    """One step recurrence.  x: (b, 1, d)."""
    s, d_inner, heads, conv_dim = _dims(cfg)
    z, xbc, dt = _split_proj(params, x, cfg)
    hist = jnp.concatenate([state.conv, xbc], axis=1)            # (b,cw,conv_dim)
    xbc_c = jax.nn.silu((hist * params["conv_w"][None]).sum(1) + params["conv_b"])
    xh, B, C, dtv, ld = _ssm_inputs(xbc_c[:, None], dt, params, cfg)
    a = jnp.exp(ld[:, 0])                                        # (b,h)
    dbx = jnp.einsum("bh,bN,bhp->bhpN", dtv[:, 0], B[:, 0], xh[:, 0].astype(jnp.float32))
    h_new = state.h * a[..., None, None] + dbx
    y = jnp.einsum("bN,bhpN->bhp", C[:, 0].astype(jnp.float32), h_new)
    y = y + params["d_skip"][None, :, None] * xh[:, 0].astype(jnp.float32)
    b = x.shape[0]
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = common.rms_norm(y, params["norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, SSDState(h=h_new, conv=hist[:, 1:], pos=state.pos + 1)
