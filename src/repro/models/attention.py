"""Multi-head / grouped-query attention layer with pluggable sparse policy.

Modes:
  * ``full``   — training / prefill over a whole sequence.  Dense flash-style
    attention by default; when a sparsity policy is supplied (a
    ``SparsityPolicy``, a registered policy name, or a legacy ``StemConfig``)
    and the layer is causal self-attention, the policy-sparse path
    (core/sparse_attention.sparse_attention) is used — the paper's technique
    as a first-class integration point, with per-layer policy overrides
    supported at the transformer level.
  * ``decode`` — one new token against a KV cache (global or ring/windowed).
  * ``cross``  — encoder-decoder cross attention (whisper).

Local (windowed) attention runs as a chunked band so FLOPs scale with
N * window rather than N^2 — required for recurrentgemma's 500k decode cell.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import policy as policy_lib
from repro.core.config import StemConfig
from repro.core.decode import DEFAULT_BUDGET_FRAC
from repro.core.sparse_attention import (dense_attention, dense_attention_auto,
                                          sparse_attention)
from repro.models import common


class KVCache(NamedTuple):
    k: jnp.ndarray        # (b, hk, L, dh)
    v: jnp.ndarray
    pos: jnp.ndarray      # int32 next write position: scalar (uniform batch)
                          # or (b,) per-sequence (ragged/continuous batching)


def init(ini: common.Initializer, cfg: ArchConfig) -> dict:
    d, h, hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": ini.normal((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": ini.normal((d, hk, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ini.normal((d, hk, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ini.normal((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ini.zeros((h, dh), ("heads", "head_dim"))
        p["bk"] = ini.zeros((hk, dh), ("kv_heads", "head_dim"))
        p["bv"] = ini.zeros((hk, dh), ("kv_heads", "head_dim"))
    if cfg.qk_norm:
        p["q_norm"] = ini.zeros((dh,), ("head_dim",))
        p["k_norm"] = ini.zeros((dh,), ("head_dim",))
    return p


def _project(params, x, cfg: ArchConfig, positions, *, use_rope: bool = True):
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"][None, :, None, :]
        k = k + params["bk"][None, :, None, :]
        v = v + params["bv"][None, :, None, :]
    if cfg.qk_norm:
        q = common.rms_norm(q, params["q_norm"])
        k = common.rms_norm(k, params["k_norm"])
    if use_rope:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def local_attention(q, k, v, window: int):
    """Banded sliding-window attention, chunked so cost is O(N * 2w).

    q, k, v: (b, h, n, d) with n % window == 0 (configs guarantee this).
    Each query chunk of length w attends to its own and the previous chunk
    with an exact |i-j| < w mask.
    """
    b, h, n, d = q.shape
    w = window
    if n <= w:
        return _masked_window_dense(q, k, v, w)
    n_orig = n
    if n % w:
        # pad to a window multiple; padded queries are sliced off and padded
        # keys sit strictly in the future of every real query (causal band).
        pad = w - n % w
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        n = n + pad
    nc = n // w
    qc = q.reshape(b, h, nc, w, d)
    kc = k.reshape(b, h, nc, w, d)
    vc = v.reshape(b, h, nc, w, d)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :, :1]), kc[:, :, :-1]], axis=2)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :, :1]), vc[:, :, :-1]], axis=2)
    kk = jnp.concatenate([k_prev, kc], axis=3)          # (b,h,nc,2w,d)
    vv = jnp.concatenate([v_prev, vc], axis=3)
    s = jnp.einsum("bhcqd,bhckd->bhcqk", qc.astype(jnp.float32), kk.astype(jnp.float32))
    s = s * (d ** -0.5)
    qi = jnp.arange(w)[:, None] + w                     # position within 2w band
    kj = jnp.arange(2 * w)[None, :]
    mask = (kj <= qi) & (kj > qi - w)
    first_chunk = jnp.arange(nc)[:, None, None] == 0
    valid = jnp.where(first_chunk, mask & (kj >= w), mask)
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhcqk,bhckd->bhcqd", p, vv.astype(jnp.float32))
    return o.reshape(b, h, n, d)[:, :, :n_orig].astype(q.dtype)


def _masked_window_dense(q, k, v, window: int):
    n = q.shape[2]
    qi = jnp.arange(n)[:, None]
    kj = jnp.arange(n)[None, :]
    mask = (kj <= qi) & (kj > qi - window)
    b, hq = q.shape[0], q.shape[1]
    return dense_attention(q, k, v, causal=True,
                           mask=jnp.broadcast_to(mask, (b, hq, n, n)))


def apply_full(
    params,
    x: jnp.ndarray,
    cfg: ArchConfig,
    *,
    positions: jnp.ndarray,
    stem_cfg=None,
    window: Optional[int] = None,
    use_rope: bool = True,
    causal: bool = True,
    return_stats: bool = False,
):
    """Training / prefill attention over the full sequence.

    ``stem_cfg``: SparsityPolicy | registered policy name | StemConfig |
    None (dense).  ``return_stats`` additionally returns the realized
    ``StemStats`` of the sparse path (None when the dense/local path ran) —
    the transformer's per-layer density diagnostics use this.
    """
    pol = policy_lib.as_policy_opt(stem_cfg)
    q, k, v = _project(params, x, cfg, positions, use_rope=use_rope)
    stats = None
    if window is not None:
        group = q.shape[1] // k.shape[1]
        o = local_attention(q, jnp.repeat(k, group, axis=1), jnp.repeat(v, group, axis=1), window)
    elif pol is not None and causal and x.shape[1] % pol.block_size == 0 \
            and x.shape[1] // pol.block_size >= 2:
        if return_stats:
            o, stats = sparse_attention(q, k, v, pol, return_stats=True)
        else:
            o = sparse_attention(q, k, v, pol)
    else:
        o = dense_attention_auto(q, k, v, causal=causal)
    out = jnp.einsum("bhsk,hkd->bsd", o, params["wo"])
    return (out, stats) if return_stats else out


def apply_decode(
    params,
    x: jnp.ndarray,                  # (b, 1, d) — one new token
    cfg: ArchConfig,
    cache: KVCache,
    *,
    window: Optional[int] = None,
    use_rope: bool = True,
    stem_cfg=None,
    budget_frac: float = DEFAULT_BUDGET_FRAC,
) -> tuple[jnp.ndarray, KVCache]:
    """One decode step against the cache (ring buffer when windowed).

    ``cache.pos`` may be a scalar (every row at the same length — the seed
    behaviour) or a ``(b,)`` vector (ragged batch: each sequence writes and
    masks at its own length; rope uses the per-row position).

    With ``stem_cfg`` (any policy spelling; global attention only) the step
    is POLICY-SPARSE over the contiguous cache: the cache is re-summarized
    per step (O(L) — a test/reference arm, not a serving path) and the
    policy's metric + budget rule select blocks exactly as the paged
    engine's ``apply_decode_paged`` does over pages.  This is the
    fixed-batch differential reference for every registered policy."""
    pos = cache.pos
    b = x.shape[0]
    if stem_cfg is not None:
        # Validate before any projection work: the sparse path summarizes
        # the cache at block granularity, so its capacity must be a block
        # multiple.
        if window is not None:
            raise NotImplementedError(
                "policy-sparse decode needs global attention, not windowed")
        pol = policy_lib.as_policy(stem_cfg)
        L0 = cache.k.shape[2]
        if L0 % pol.block_size != 0:
            raise ValueError(
                f"policy-sparse decode needs the cache capacity to be a "
                f"multiple of the policy block size, but cache len {L0} % "
                f"block {pol.block_size} != 0. Allocate the cache padded to "
                f"a block/page multiple — ceil(max_len / {pol.block_size}) "
                f"* {pol.block_size} — as the paged engine does with whole "
                f"pages (per-row valid lengths may still be ragged; only "
                f"the buffer capacity must align).")
    rope_pos = pos[None] if pos.ndim == 0 else pos[:, None]      # (1,)|(b,1)
    q, k_new, v_new = _project(params, x, cfg, rope_pos, use_rope=use_rope)
    L = cache.k.shape[2]
    posv = jnp.broadcast_to(pos, (b,))                           # (b,)
    if window is None:
        ck, cv = common.update_cache(cache.k, cache.v, pos, k_new, v_new)
        valid = jnp.arange(L)[None, :] <= posv[:, None]          # (b, L)
    else:
        ck, cv = common.update_ring_cache(cache.k, cache.v, pos, k_new, v_new, L)
        slot_age = posv[:, None] - ((posv[:, None] - jnp.arange(L)[None, :]) % L)
        valid = (slot_age >= 0) & (slot_age > posv[:, None] - L)
    if stem_cfg is not None:
        from repro.core import decode as decode_lib

        summary = decode_lib.summarize_cache(ck, cv, pol)
        o = decode_lib.sparse_decode_attention(
            q, ck, cv, summary, posv + 1, pol, budget_frac=budget_frac)
        out = jnp.einsum("bhsk,hkd->bsd", o.astype(x.dtype), params["wo"])
        return out, KVCache(k=ck, v=cv, pos=pos + 1)
    h = q.shape[1]
    hk = ck.shape[1]
    group = h // hk
    s = jnp.einsum("bhgd,bhkd->bhgk",
                   q[:, :, 0].reshape(b, hk, group, -1).astype(jnp.float32),
                   ck.astype(jnp.float32)) * (cfg.head_dim ** -0.5)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, cv.astype(jnp.float32))
    o = o.reshape(b, h, 1, cfg.head_dim).astype(x.dtype)
    out = jnp.einsum("bhsk,hkd->bsd", o, params["wo"])
    return out, KVCache(k=ck, v=cv, pos=pos + 1)


def apply_decode_paged(
    params,
    x: jnp.ndarray,                  # (slots, 1, d) — one new token per slot
    cfg: ArchConfig,
    pool,                            # runtime.paged.PagePool for this layer
    page_table: jnp.ndarray,         # (slots, max_pages) global page ids
    cache_lens: jnp.ndarray,         # (slots,) tokens already cached
    stem_cfg,                        # any policy spelling (see apply_full)
    *,
    budget_frac: float = DEFAULT_BUDGET_FRAC,
    executor: Optional[str] = None,  # paged backend (None = policy.executor)
    use_rope: bool = True,
):
    """One decode step against the paged Stem KV cache.

    Appends the new token's K/V (+ summary increments) to each slot's
    current page, then runs OAM page selection + exact attention over the
    selected pages only.  ``budget_frac=1.0`` (the shared default) is the
    dense-equivalent oracle arm (every valid page attends).  ``executor``
    picks the paged backend — "xla" gather oracle or the fused "pallas"
    kernels.  Returns (out, new_pool)."""
    from repro.runtime import paged as paged_lib

    from repro.sharding import serving as serving_lib

    stem_cfg = policy_lib.as_policy(stem_cfg)
    lens = jnp.asarray(cache_lens, jnp.int32)
    q, k_new, v_new = _project(params, x, cfg, lens[:, None], use_rope=use_rope)
    # Under the tensor-parallel head-sharding context the full projections
    # above are computed replicated; each shard keeps its contiguous block
    # of (query and KV) heads, appends/attends shard-local against its pool
    # slice, and the per-head outputs are all-gathered back into full head
    # order before the (replicated) output projection — bitwise identical
    # to the single-device step.  All three calls are no-ops outside a mesh.
    q = serving_lib.local_heads(q, axis=1)
    k_new = serving_lib.local_heads(k_new, axis=1)
    v_new = serving_lib.local_heads(v_new, axis=1)
    pool = paged_lib.append_token(pool, page_table, lens, k_new, v_new, stem_cfg)
    o = paged_lib.paged_sparse_decode(q, pool, page_table, lens + 1, stem_cfg,
                                      budget_frac=budget_frac,
                                      executor=executor)
    o = serving_lib.gather_heads(o, axis=1)
    out = jnp.einsum("bhsk,hkd->bsd", o.astype(x.dtype), params["wo"])
    return out, pool


def apply_chunk_paged(
    params,
    x: jnp.ndarray,                  # (slots, C, d) — one prefill chunk per slot
    cfg: ArchConfig,
    pool,                            # runtime.paged.PagePool for this layer
    page_table: jnp.ndarray,         # (slots, max_pages) global page ids
    chunk_start: jnp.ndarray,        # (slots,) absolute chunk start positions
    true_len: jnp.ndarray,           # (slots,) true prompt lengths
    budgets: jnp.ndarray,            # (slots, C // block) absolute-row budgets
    stem_cfg,                        # any policy spelling (see apply_full)
    *,
    k_max: int = 0,                  # static gather width (0 = max_pages)
    executor: Optional[str] = None,  # paged backend (None = policy.executor)
    use_rope: bool = True,
):
    """One chunked-prefill step against the paged Stem KV cache.

    Writes the chunk's K/V pages + summaries first (``write_chunk_pages``),
    then runs the policy's chunked selection + exact attention over history
    *and* in-chunk pages uniformly (``core.chunked``), with rope, TPD
    budgets and sink/local floors all at absolute positions — so any chunk
    size is selection-equivalent to one-shot prefill.  Slots without a
    chunk this step carry an all-zero page table row (writes land in the
    trash page; outputs are ignored).  Returns (out, new_pool)."""
    from repro.core import chunked as chunked_lib
    from repro.runtime import paged as paged_lib
    from repro.sharding import serving as serving_lib

    stem_cfg = policy_lib.as_policy(stem_cfg)
    c = x.shape[1]
    positions = chunk_start[:, None] + jnp.arange(c)[None, :]     # (slots, C)
    q, k_new, v_new = _project(params, x, cfg, positions, use_rope=use_rope)
    # Same TP head slicing as apply_decode_paged: replicated projections,
    # shard-local chunk write + selection + attention, all-gather before wo.
    q = serving_lib.local_heads(q, axis=1)
    k_new = serving_lib.local_heads(k_new, axis=1)
    v_new = serving_lib.local_heads(v_new, axis=1)
    pool = paged_lib.write_chunk_pages(pool, page_table, chunk_start, k_new,
                                       v_new, true_len, stem_cfg)
    o = chunked_lib.chunked_prefill_attention(q, pool, page_table,
                                              chunk_start, budgets, stem_cfg,
                                              k_max, executor=executor)
    o = serving_lib.gather_heads(o, axis=1)
    out = jnp.einsum("bhsk,hkd->bsd", o.astype(x.dtype), params["wo"])
    return out, pool


# ---------------------------------------------------------------------------
# Cross attention (encoder-decoder)
# ---------------------------------------------------------------------------

def init_cross(ini: common.Initializer, cfg: ArchConfig) -> dict:
    d, h, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    return {
        "wq": ini.normal((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": ini.normal((d, h, dh), ("embed", "heads", "head_dim")),
        "wv": ini.normal((d, h, dh), ("embed", "heads", "head_dim")),
        "wo": ini.normal((h, dh, d), ("heads", "head_dim", "embed")),
    }


def cross_kv(params, enc_out: jnp.ndarray):
    """Precompute cross-attention K/V from encoder output (b, F, d)."""
    k = jnp.einsum("bsd,dhk->bhsk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", enc_out, params["wv"])
    return k, v


def apply_cross(params, x: jnp.ndarray, ck: jnp.ndarray, cv: jnp.ndarray,
                head_dim: int) -> jnp.ndarray:
    """Bidirectional cross attention: decoder x attends encoder K/V."""
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"])
    o = dense_attention_auto(q, ck, cv, causal=False, scale=head_dim ** -0.5)
    return jnp.einsum("bhsk,hkd->bsd", o, params["wo"])


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               window: Optional[int] = None, dtype=jnp.bfloat16) -> KVCache:
    L = min(max_len, window) if window else max_len
    shape = (batch, cfg.num_kv_heads, L, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.zeros((), jnp.int32))


def prefill_into_cache(
    params, x, cfg: ArchConfig, *, positions, max_len: int,
    stem_cfg=None, window: Optional[int] = None,
    use_rope: bool = True,
):
    """Prefill attention AND return the populated cache for decode.
    ``stem_cfg`` accepts any policy spelling (see ``apply_full``)."""
    stem_cfg = policy_lib.as_policy_opt(stem_cfg)
    q, k, v = _project(params, x, cfg, positions, use_rope=use_rope)
    if window is not None:
        group = q.shape[1] // k.shape[1]
        o = local_attention(q, jnp.repeat(k, group, axis=1), jnp.repeat(v, group, axis=1), window)
        L = min(max_len, window)
        # Keep the trailing `window` keys, aligned to their ring slots
        # (position p lives at slot p % L).
        n = x.shape[1]
        ck = jnp.roll(k[:, :, -L:], shift=(n % L), axis=2)
        cv = jnp.roll(v[:, :, -L:], shift=(n % L), axis=2)
    else:
        if stem_cfg is not None and x.shape[1] % stem_cfg.block_size == 0 \
                and x.shape[1] // stem_cfg.block_size >= 2:
            o = sparse_attention(q, k, v, stem_cfg)
        else:
            o = dense_attention_auto(q, k, v, causal=True)
        L = max_len
        pad = L - k.shape[2]
        ck = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = jnp.einsum("bhsk,hkd->bsd", o, params["wo"])
    cache = KVCache(k=ck, v=cv, pos=jnp.asarray(x.shape[1], jnp.int32))
    return out, cache
