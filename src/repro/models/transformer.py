"""Decoder-only LM assembly: layer programs, scan-over-layers, loss.

A model is described by a **layer program** — a list of segments
``(num_groups, kinds)`` where ``kinds`` is the tuple of sub-layer kinds in
one group.  Homogeneous stacks compile as a single ``lax.scan`` over stacked
parameters (small HLO, fast 512-way SPMD compiles); heterogeneous patterns
(griffin's rec/rec/attn, deepseek's 3 dense + 58 MoE) become several
segments.  Examples:

  dense 28L:        [(28, ("dense",))]
  deepseek 61L:     [(3, ("mla_dense",)), (58, ("mla_moe",))]
  recurrentgemma:   [(8, ("rec", "rec", "dense_local")), (1, ("rec", "rec"))]
  mamba2 48L:       [(48, ("ssd",))]

Sub-layer kinds: dense | dense_local | moe | mla_dense | mla_moe | rec | ssd.
Every kind supports three phases: full (train/prefill), prefill-with-cache,
and decode-step.

Sparsity is policy-driven: every ``stem_cfg`` argument accepts a
``SparsityPolicy``, a registered policy name, or a legacy ``StemConfig``,
and the full/prefill phases additionally take ``policies`` — a
``{global_layer_index: policy}`` override map, so deep layers can run
leaner budgets than early ones (the paper's cumulative-dependency
argument).  Layers with the same effective policy still compile as one
``lax.scan``; an override only splits the scan at its boundaries.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import policy as policy_lib
from repro.core.config import StemConfig
from repro.models import attention, common, mla, mlp, moe, rglru, ssd
from repro.sharding.context import constrain


# ---------------------------------------------------------------------------
# Layer programs
# ---------------------------------------------------------------------------

def layer_program(cfg: ArchConfig) -> list[tuple[int, tuple[str, ...]]]:
    if cfg.family == "ssm":
        return [(cfg.num_layers, ("ssd",))]
    if cfg.family == "hybrid":
        period = cfg.rglru.attn_period
        full = cfg.num_layers // period
        rem = cfg.num_layers - full * period
        group = ("rec",) * (period - 1) + ("dense_local",)
        prog = [(full, group)]
        if rem:
            prog.append((1, ("rec",) * rem))
        return prog
    if cfg.family == "moe":
        kind = "mla_moe" if cfg.mla else "moe"
        first = cfg.moe.first_k_dense
        prog = []
        if first:
            prog.append((first, ("mla_dense" if cfg.mla else "dense",)))
        prog.append((cfg.num_layers - first, (kind,)))
        return prog
    # dense / vlm backbones
    return [(cfg.num_layers, ("dense",))]


def num_layer_groups(cfg: ArchConfig) -> int:
    """Number of layer groups — the index space of per-layer ``policies``."""
    return sum(n for n, _ in layer_program(cfg))


def _layer_policies(cfg: ArchConfig, stem_cfg, policies):
    """Per-group effective policy list (length ``num_layer_groups``).

    ``policies`` maps a global layer-group index to an override (any policy
    spelling); unlisted groups use ``stem_cfg``.  Entries are normalized to
    ``SparsityPolicy`` so equal policies — however spelled — coalesce into
    one scan run."""
    total = num_layer_groups(cfg)
    base = policy_lib.as_policy_opt(stem_cfg)
    if not policies:
        return [base] * total
    bad = sorted(i for i in policies if not (isinstance(i, int) and 0 <= i < total))
    if bad:
        raise ValueError(
            f"policies keys {bad} out of range for {total} layer groups")
    return [policy_lib.as_policy_opt(policies[i]) if i in policies else base
            for i in range(total)]


def _policy_runs(eff_seg):
    """Coalesce consecutive equal policies into (start, length, policy) runs
    — each run compiles as one scan over a static slice of the stacked
    segment parameters."""
    runs: list = []
    for i, p in enumerate(eff_seg):
        if runs and runs[-1][2] == p:
            runs[-1] = (runs[-1][0], runs[-1][1] + 1, p)
        else:
            runs.append((i, 1, p))
    return runs


# ---------------------------------------------------------------------------
# Single sub-layer: init / full / prefill / decode
# ---------------------------------------------------------------------------

def _init_sublayer(ini: common.Initializer, cfg: ArchConfig, kind: str) -> dict:
    p: dict[str, Any] = {"norm1": ini.zeros((cfg.d_model,), ("embed",))}
    if kind in ("dense", "dense_local", "moe"):
        p["attn"] = attention.init(ini, cfg)
    elif kind in ("mla_dense", "mla_moe"):
        p["attn"] = mla.init(ini, cfg)
    elif kind == "rec":
        p["mixer"] = rglru.init(ini, cfg)
    elif kind == "ssd":
        p["mixer"] = ssd.init(ini, cfg)
    else:
        raise ValueError(kind)
    if kind != "ssd":   # mamba blocks have no separate FFN
        p["norm2"] = ini.zeros((cfg.d_model,), ("embed",))
        if kind in ("moe", "mla_moe"):
            p["ffn"] = moe.init(ini, cfg.d_model, cfg.moe, cfg.activation)
        else:
            d_ff = cfg.d_ff
            if kind == "mla_dense" and cfg.moe and cfg.moe.first_dense_d_ff:
                d_ff = cfg.moe.first_dense_d_ff
            p["ffn"] = mlp.init(ini, cfg.d_model, d_ff, cfg.activation)
    return p


def _sublayer_full(params, x, cfg: ArchConfig, kind: str, *, positions,
                   stem_cfg, return_stats: bool = False):
    """Returns (x, aux_loss) — or (x, aux_loss, StemStats | None) when
    ``return_stats`` (stats exist only when the sparse attention path ran)."""
    h = common.rms_norm(x, params["norm1"])
    stats = None
    if kind in ("dense", "moe"):
        if return_stats:
            mix, stats = attention.apply_full(
                params["attn"], h, cfg, positions=positions,
                stem_cfg=stem_cfg, return_stats=True)
        else:
            mix = attention.apply_full(params["attn"], h, cfg,
                                       positions=positions, stem_cfg=stem_cfg)
    elif kind == "dense_local":
        mix = attention.apply_full(params["attn"], h, cfg, positions=positions,
                                   stem_cfg=None, window=cfg.rglru.window)
    elif kind in ("mla_dense", "mla_moe"):
        if return_stats:
            mix, stats = mla.apply_full(params["attn"], h, cfg,
                                        positions=positions, stem_cfg=stem_cfg,
                                        return_stats=True)
        else:
            mix = mla.apply_full(params["attn"], h, cfg, positions=positions,
                                 stem_cfg=stem_cfg)
    elif kind == "rec":
        mix = rglru.apply_full(params["mixer"], h, cfg)
    elif kind == "ssd":
        mix = ssd.apply_full(params["mixer"], h, cfg)
    x = constrain(x + mix, ("batch", None, None))
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssd":
        return (x, aux, stats) if return_stats else (x, aux)
    h2 = common.rms_norm(x, params["norm2"])
    if kind in ("moe", "mla_moe"):
        y, aux = moe.apply(params["ffn"], h2, cfg.moe, cfg.activation)
    else:
        y = mlp.apply(params["ffn"], h2, cfg.activation)
    x = constrain(x + y, ("batch", None, None))
    return (x, aux, stats) if return_stats else (x, aux)


def _sublayer_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype):
    if kind in ("dense", "moe"):
        return attention.init_cache(cfg, batch, max_len, dtype=dtype)
    if kind == "dense_local":
        return attention.init_cache(cfg, batch, max_len, window=cfg.rglru.window, dtype=dtype)
    if kind in ("mla_dense", "mla_moe"):
        return mla.init_cache(cfg, batch, max_len, dtype=dtype)
    if kind == "rec":
        return rglru.init_state(cfg, batch)
    if kind == "ssd":
        return ssd.init_state(cfg, batch, dtype)
    raise ValueError(kind)


def _sublayer_prefill(params, x, cfg: ArchConfig, kind: str, *, positions,
                      stem_cfg, max_len: int):
    """Returns (x, aux, cache)."""
    h = common.rms_norm(x, params["norm1"])
    if kind in ("dense", "moe"):
        mix, cache = attention.prefill_into_cache(
            params["attn"], h, cfg, positions=positions, max_len=max_len,
            stem_cfg=stem_cfg)
    elif kind == "dense_local":
        mix, cache = attention.prefill_into_cache(
            params["attn"], h, cfg, positions=positions, max_len=max_len,
            window=cfg.rglru.window)
    elif kind in ("mla_dense", "mla_moe"):
        mix, cache = mla.prefill_into_cache(
            params["attn"], h, cfg, positions=positions, max_len=max_len,
            stem_cfg=stem_cfg)
    elif kind == "rec":
        mix, cache = rglru.prefill_into_state(params["mixer"], h, cfg)
    elif kind == "ssd":
        mix, cache = ssd.prefill_into_state(params["mixer"], h, cfg)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssd":
        return x, aux, cache
    h2 = common.rms_norm(x, params["norm2"])
    if kind in ("moe", "mla_moe"):
        y, aux = moe.apply(params["ffn"], h2, cfg.moe, cfg.activation)
    else:
        y = mlp.apply(params["ffn"], h2, cfg.activation)
    return x + y, aux, cache


def _sublayer_decode(params, x, cfg: ArchConfig, kind: str, cache, *,
                     stem_cfg=None, budget_frac: float = 1.0):
    h = common.rms_norm(x, params["norm1"])
    if kind in ("dense", "moe"):
        mix, cache = attention.apply_decode(params["attn"], h, cfg, cache,
                                            stem_cfg=stem_cfg,
                                            budget_frac=budget_frac)
    elif kind == "dense_local":
        mix, cache = attention.apply_decode(params["attn"], h, cfg, cache,
                                            window=cfg.rglru.window)
    elif kind in ("mla_dense", "mla_moe"):
        mix, cache = mla.apply_decode(params["attn"], h, cfg, cache)
    elif kind == "rec":
        mix, cache = rglru.apply_decode(params["mixer"], h, cfg, cache)
    elif kind == "ssd":
        mix, cache = ssd.apply_decode(params["mixer"], h, cfg, cache)
    x = x + mix
    if kind == "ssd":
        return x, cache
    h2 = common.rms_norm(x, params["norm2"])
    if kind in ("moe", "mla_moe"):
        y, _ = moe.apply(params["ffn"], h2, cfg.moe, cfg.activation)
    else:
        y = mlp.apply(params["ffn"], h2, cfg.activation)
    return x + y, cache


# ---------------------------------------------------------------------------
# Group (scan body) = sequence of sub-layers
# ---------------------------------------------------------------------------

def _init_group(ini, cfg, kinds) -> dict:
    return {f"sub{i}": _init_sublayer(ini, cfg, k) for i, k in enumerate(kinds)}


def _group_full(params, x, cfg, kinds, *, positions, stem_cfg):
    aux = jnp.zeros((), jnp.float32)
    for i, k in enumerate(kinds):
        x, a = _sublayer_full(params[f"sub{i}"], x, cfg, k,
                              positions=positions, stem_cfg=stem_cfg)
        aux = aux + a
    return x, aux


def _stacked_group_init(ini: common.Initializer, cfg, kinds, n: int):
    """Stack n group-param trees along a leading 'layers' axis (for scan)."""
    def one(key):
        sub = common.Initializer(key, ini.dtype)
        values, _ = common.unzip(_init_group(sub, cfg, kinds))
        return values
    keys = jax.random.split(ini.next_key(), n)
    values = jax.vmap(one)(keys)
    _, axes = common.unzip(_init_group(
        common.Initializer(jax.random.PRNGKey(0), ini.dtype), cfg, kinds))
    axes = jax.tree.map(lambda a: ("layers",) + a, axes,
                        is_leaf=lambda t: isinstance(t, tuple))
    return common.zip_trees(values, axes)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_lm(key: jax.Array, cfg: ArchConfig) -> dict:
    ini = common.Initializer(key, cfg.jnp_dtype)
    p: dict[str, Any] = {
        "embed": common.embed_init(ini, cfg.padded_vocab, cfg.d_model),
        "final_norm": ini.zeros((cfg.d_model,), ("embed",)),
    }
    for si, (n, kinds) in enumerate(layer_program(cfg)):
        p[f"segment{si}"] = _stacked_group_init(ini, cfg, kinds, n)
    if not cfg.tie_embeddings:
        p["head"] = ini.normal((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"),
                               scale=0.02, dtype=jnp.float32)
    if cfg.mtp:
        p["mtp_proj"] = ini.normal((2 * cfg.d_model, cfg.d_model), (None, "embed"))
        p["mtp_norm"] = ini.zeros((cfg.d_model,), ("embed",))
        p["mtp_layer"] = _stacked_group_init(ini, cfg, ("dense",), 1)
    return p


def init_params(key: jax.Array, cfg: ArchConfig):
    """Concrete parameter values (plain-array tree).  All apply functions
    consume this values-only tree."""
    return common.unzip(init_lm(key, cfg))[0]


def abstract_params(cfg: ArchConfig):
    """(ShapeDtypeStruct values tree, logical-axes tree) — no allocation.

    The axes tree is captured as a tracing side effect (axes are static
    Python tuples, not arrays, so they can't flow through eval_shape
    outputs)."""
    captured = {}

    def f(key):
        values, axes = common.unzip(init_lm(key, cfg))
        captured["axes"] = axes
        return values

    values = jax.eval_shape(f, jax.random.PRNGKey(0))
    return values, captured["axes"]


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch: dict, cfg: ArchConfig):
    """Token (+ optional stub modality prefix) embeddings -> (b, s, d)."""
    parts = []
    if cfg.vlm_stub and "patch_embeds" in batch:
        parts.append(batch["patch_embeds"].astype(cfg.jnp_dtype))
    emb = common.embed_lookup(params["embed"], batch["tokens"], cfg.jnp_dtype)
    parts.append(emb * (cfg.d_model ** 0.5) if cfg.embed_scale_flag else emb)
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]


def _run_segments(params, x, cfg: ArchConfig, *, positions, stem_cfg,
                  remat: bool, policies=None):
    eff = _layer_policies(cfg, stem_cfg, policies)
    aux_total = jnp.zeros((), jnp.float32)
    off = 0
    for si, (n, kinds) in enumerate(layer_program(cfg)):
        seg = params[f"segment{si}"]
        for start, length, pol in _policy_runs(eff[off:off + n]):

            def body(carry, layer_params, kinds=kinds, pol=pol):
                x, aux = carry
                x, a = _group_full(layer_params, x, cfg, kinds,
                                   positions=positions, stem_cfg=pol)
                return (x, aux + a), None

            if remat:
                body = jax.checkpoint(body, prevent_cse=False)
            if length == 1:
                (x, aux_total), _ = body(
                    (x, aux_total), jax.tree.map(lambda t, s=start: t[s], seg))
            else:
                sub = seg if length == n else jax.tree.map(
                    lambda t, s=start, m=length: t[s:s + m], seg)
                (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), sub)
        off += n
    return x, aux_total


def _logits(params, x, cfg: ArchConfig):
    x = common.rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        return common.lm_logits(x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                      params["head"].astype(jnp.float32))


def loss_fn(params, batch: dict, cfg: ArchConfig, *,
            stem_cfg=None, remat: bool = True, policies=None):
    """Next-token CE (+ MoE aux, + MTP).  batch: tokens (b,s), labels (b,s).

    ``stem_cfg`` accepts any policy spelling; ``policies`` optionally
    overrides it per layer group ({index: policy})."""
    x = _embed_inputs(params, batch, cfg)
    positions = jnp.arange(x.shape[1])
    x, aux = _run_segments(params, x, cfg, positions=positions,
                           stem_cfg=stem_cfg, remat=remat, policies=policies)
    txt_len = batch["tokens"].shape[1]
    x_txt = x[:, -txt_len:]
    logits = _logits(params, x_txt, cfg)
    mask = batch.get("loss_mask")
    ce = common.cross_entropy(logits, batch["labels"], mask)
    metrics = {"ce": ce, "aux": aux}
    total = ce + aux
    if cfg.mtp:
        h = common.rms_norm(x_txt[:, :-1], params["mtp_norm"])
        nxt = common.embed_lookup(params["embed"], batch["labels"][:, :-1], cfg.jnp_dtype)
        hm = jnp.einsum("bse,ed->bsd", jnp.concatenate([h, nxt], -1), params["mtp_proj"])
        hm, _ = _group_full(jax.tree.map(lambda t: t[0], params["mtp_layer"]),
                            hm, cfg, ("dense",), positions=positions[:-1], stem_cfg=None)
        mtp_logits = _logits(params, hm, cfg)
        mtp_ce = common.cross_entropy(mtp_logits, batch["labels"][:, 1:])
        metrics["mtp_ce"] = mtp_ce
        total = total + cfg.mtp_weight * mtp_ce
    metrics["loss"] = total
    return total, metrics


def forward_hiddens(params, batch: dict, cfg: ArchConfig, *,
                    stem_cfg: Optional[StemConfig] = None):
    """Forward pass that also returns every layer's residual stream —
    used by the benchmark harness for the paper's per-layer sparse-vs-dense
    MSE measurements (Table 1 / Figure 3 quantities).

    Returns (logits (b, s, vocab) fp32, list of (n_layers_i, b, s, d)).
    """
    x = _embed_inputs(params, batch, cfg)
    positions = jnp.arange(x.shape[1])
    hiddens = []
    for si, (n, kinds) in enumerate(layer_program(cfg)):
        seg = params[f"segment{si}"]

        def body(carry, layer_params, kinds=kinds):
            x, aux = carry
            x, a = _group_full(layer_params, x, cfg, kinds,
                               positions=positions, stem_cfg=stem_cfg)
            return (x, aux + a), x

        if n == 1:
            (x, _), y = body((x, 0.0), jax.tree.map(lambda t: t[0], seg))
            y = y[None]
        else:
            (x, _), y = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), seg)
        hiddens.append(y)
    logits = _logits(params, x, cfg)
    return logits, hiddens


def forward_with_stats(params, batch: dict, cfg: ArchConfig, *,
                       stem_cfg=None, policies=None):
    """Diagnostic forward pass with per-sub-layer sparse-attention stats.

    Runs the layer program unrolled (no scan / remat — small models only)
    so every attention sub-layer can report the realized ``StemStats`` of
    its *own* effective policy; this is how per-layer policy overrides are
    observed (realized density per layer).

    Returns (logits (b, s, vocab), records) where each record is a dict
    ``{"layer": global group index, "kind": sub-layer kind, "policy":
    policy name or None, "stats": StemStats | None}`` (stats is None for
    sub-layers where the sparse path did not run).
    """
    x = _embed_inputs(params, batch, cfg)
    positions = jnp.arange(x.shape[1])
    eff = _layer_policies(cfg, stem_cfg, policies)
    records = []
    li = 0
    for si, (n, kinds) in enumerate(layer_program(cfg)):
        seg = params[f"segment{si}"]
        for j in range(n):
            layer_params = jax.tree.map(lambda t, j=j: t[j], seg)
            pol = eff[li]
            for i, kind in enumerate(kinds):
                x, _, st = _sublayer_full(
                    layer_params[f"sub{i}"], x, cfg, kind, positions=positions,
                    stem_cfg=pol, return_stats=True)
                records.append({
                    "layer": li, "kind": kind,
                    "policy": (pol.name or None) if pol is not None else None,
                    "stats": st,
                })
            li += 1
    logits = _logits(params, x, cfg)
    return logits, records


# ---------------------------------------------------------------------------
# Serving: prefill + decode over stacked caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, max_len: int):
    caches = []
    for n, kinds in layer_program(cfg):
        group = {f"sub{i}": _sublayer_cache(cfg, k, batch, max_len, cfg.jnp_dtype)
                 for i, k in enumerate(kinds)}
        stacked = jax.tree.map(lambda t: jnp.broadcast_to(t, (n,) + t.shape), group)
        caches.append(stacked)
    return caches


def prefill(params, batch: dict, cfg: ArchConfig, *, max_len: int,
            stem_cfg=None, last_pos: Optional[jnp.ndarray] = None,
            policies=None):
    """Process the full prompt.  Returns (last-position logits, caches).

    The sparsity policy (the paper's contribution) runs here — this is the
    pre-filling phase whose latency the paper optimizes.  ``stem_cfg``
    accepts any policy spelling; ``policies`` optionally overrides it per
    layer group ({index: policy}).

    ``last_pos`` (scalar or (b,) int32) selects which position's logits to
    return per row — required for right-padded ragged prompts where row i's
    real last token sits at ``len_i - 1``, not at ``seq - 1``.  Default:
    the final position (uniform batch).
    """
    x = _embed_inputs(params, batch, cfg)
    positions = jnp.arange(x.shape[1])
    eff = _layer_policies(cfg, stem_cfg, policies)
    caches = []
    off = 0
    for si, (n, kinds) in enumerate(layer_program(cfg)):
        seg = params[f"segment{si}"]
        run_caches = []
        for start, length, pol in _policy_runs(eff[off:off + n]):

            def body(x, layer_params, kinds=kinds, pol=pol):
                cache = {}
                for i, k in enumerate(kinds):
                    x, _, c = _sublayer_prefill(
                        layer_params[f"sub{i}"], x, cfg, k, positions=positions,
                        stem_cfg=pol, max_len=max_len)
                    cache[f"sub{i}"] = c
                return x, cache

            if length == 1:
                x, cache = body(x, jax.tree.map(lambda t, s=start: t[s], seg))
                cache = jax.tree.map(lambda t: t[None], cache)
            else:
                sub = seg if length == n else jax.tree.map(
                    lambda t, s=start, m=length: t[s:s + m], seg)
                x, cache = jax.lax.scan(body, x, sub)
            run_caches.append(cache)
        off += n
        cache = run_caches[0] if len(run_caches) == 1 else jax.tree.map(
            lambda *ts: jnp.concatenate(ts, axis=0), *run_caches)
        caches.append(cache)
    if last_pos is None:
        x_last = x[:, -1:]
    else:
        lp = jnp.broadcast_to(jnp.asarray(last_pos, jnp.int32), (x.shape[0],))
        x_last = jnp.take_along_axis(x, lp[:, None, None], axis=1)
    logits = _logits(params, x_last, cfg)[:, 0]
    return logits, caches


# ---------------------------------------------------------------------------
# Paged serving: page pools + batched ragged decode (runtime/engine.py)
# ---------------------------------------------------------------------------

PAGED_KINDS = ("dense", "moe")   # attention sub-layers the paged engine serves


def assert_paged_servable(cfg: ArchConfig) -> None:
    """The paged engine needs every mixer to be causal global attention —
    ring/windowed, MLA-latent, and recurrent states have no page layout."""
    for _, kinds in layer_program(cfg):
        for k in kinds:
            if k not in PAGED_KINDS:
                raise NotImplementedError(
                    f"paged serving supports {PAGED_KINDS} sub-layers, got {k!r} "
                    f"(arch {cfg.name})")


def init_page_pools(cfg: ArchConfig, num_pages: int, stem_cfg, smesh=None):
    """Per-layer page pools, stacked along the scan axis like init_caches.
    Every attention layer gets its own (hk, P, page, d) pool; the page
    table (slot -> pages) is shared across layers and lives in the engine.
    ``stem_cfg`` accepts any policy spelling (page = policy block).

    With ``smesh`` (a ``sharding.serving.ServingMesh``) every leaf gains a
    leading slot-group axis and is placed sharded — ``(dp, n, hk, P, ...)``
    with dp over slot groups and the KV-head axis split over tp."""
    from repro.runtime import paged as paged_lib

    stem_cfg = policy_lib.as_policy(stem_cfg)
    assert_paged_servable(cfg)
    pools = []
    for n, kinds in layer_program(cfg):
        one = {f"sub{i}": paged_lib.init_pool(
                   num_pages, cfg.num_kv_heads, stem_cfg.block_size,
                   cfg.head_dim, stem_cfg.stride, cfg.jnp_dtype)
               for i, _ in enumerate(kinds)}
        pools.append(jax.tree.map(
            lambda t: jnp.broadcast_to(t, (n,) + t.shape), one))
    if smesh is not None:
        from repro.sharding import serving as serving_lib
        pools = serving_lib.shard_pools(pools, smesh)
    return pools


def prefill_kv_pages(params, tokens: jnp.ndarray, true_len: jnp.ndarray,
                     pools, page_row: jnp.ndarray, cfg: ArchConfig,
                     stem_cfg):
    """Prefill ONE request and write its pages + summaries into the pools.

    tokens: (1, Lp) right-padded to a page multiple; true_len: scalar int32;
    page_row: (max_pages_per_slot,) — *every* page reserved for the request
    (prompt pages first, then decode-spill pages), padded with the trash
    page.  All of them are reset to pristine before the prompt's
    (Lp / page_size) leading pages are written: the allocator recycles pages
    without clearing them, and the decode-time summary increments assume
    fresh pages.  Returns (next-token logits (vocab,), new pools).
    jit-able: one trace per padded length bucket.
    """
    from repro.runtime import paged as paged_lib

    stem_cfg = policy_lib.as_policy(stem_cfg)
    logits, caches = prefill(params, {"tokens": tokens}, cfg,
                             max_len=tokens.shape[1], stem_cfg=stem_cfg,
                             last_pos=true_len - 1)
    prompt_pages = page_row[:tokens.shape[1] // stem_cfg.block_size]
    new_pools = []
    for si, (n, kinds) in enumerate(layer_program(cfg)):
        seg = {}
        for i, _ in enumerate(kinds):
            cache = caches[si][f"sub{i}"]          # KVCache k: (n, 1, hk, Lp, d)
            pool = pools[si][f"sub{i}"]            # PagePool k: (n, hk, P, pg, d)
            seg[f"sub{i}"] = jax.vmap(
                lambda p, k, v: paged_lib.write_prefill_pages(
                    paged_lib.reset_pages(p, page_row), prompt_pages,
                    k[0], v[0], true_len, stem_cfg)
            )(pool, cache.k, cache.v)
        new_pools.append(seg)
    return logits[0], new_pools


def prefill_kv_pages_suffix(params, tokens: jnp.ndarray,
                            true_len: jnp.ndarray, start: int, pools,
                            page_row: jnp.ndarray, cfg: ArchConfig,
                            stem_cfg, budget_frac: float = 1.0,
                            executor=None):
    """Prefill ONE request's unmatched suffix against already-written
    prefix pages — the prefix-caching admission entry.

    Positions ``[start, Lp)`` run as a single chunk lane of
    ``paged_mixed_step``: the chunk's queries attend causally over the
    whole prompt *through the page table*, so the leading ``start /
    page_size`` pages of ``page_row`` may be prefix pages SHARED with other
    slots — they are read, never written (chunk writes cover only the
    chunk's own pages).  The caller must reset the private (suffix + spill)
    pages beforehand and must NOT reset the shared prefix pages.

    tokens: (1, Lp) right-padded to a page multiple; true_len: scalar int32
    (> start); start: static block-aligned matched-prefix offset; page_row:
    (max_pages_per_slot,) trash-padded.  Returns (next-token logits
    (vocab,), new pools).  jit-able: one trace per (Lp, start) bucket.
    """
    from repro.core import chunked as chunked_lib

    stem_cfg = policy_lib.as_policy(stem_cfg)
    bs = stem_cfg.block_size
    lp = tokens.shape[1]
    if start % bs != 0 or not 0 <= start < lp:
        raise ValueError(f"matched-prefix offset {start} must be a block "
                         f"multiple inside the padded prompt (Lp={lp})")
    nc = (lp - start) // bs
    budgets = chunked_lib.chunk_budget_rows(stem_cfg, lp, start, nc)
    tl = jnp.asarray(true_len, jnp.int32)
    chunk = {
        "tokens": tokens[:, start:],
        "page_table": page_row[None],
        "start": jnp.full((1,), start, jnp.int32),
        "true_len": tl[None],
        "budgets": jnp.asarray(budgets, jnp.int32)[None],
        "last": (tl - 1 - start)[None],
    }
    # Idle decode lane: zero page table -> its masked write lands in the
    # trash page, exactly like an inactive engine slot.
    _, chunk_logits, new_pools = paged_mixed_step(
        params, jnp.zeros((1, 1), jnp.int32), pools,
        jnp.zeros((1, page_row.shape[0]), jnp.int32),
        jnp.zeros((1,), jnp.int32), cfg, stem_cfg=stem_cfg,
        budget_frac=budget_frac, chunk=chunk, executor=executor)
    return chunk_logits[0], new_pools


def paged_mixed_step(params, tokens: jnp.ndarray, pools,
                     page_table: jnp.ndarray, cache_lens: jnp.ndarray,
                     cfg: ArchConfig, *, stem_cfg,
                     budget_frac: float = 1.0, chunk=None,
                     chunk_k_max: int = 0, executor=None):
    """One mixed batch of decode tokens + prefill chunks over the page pool.

    The unified serving step: every layer processes a decode lane
    (one token per slot, ``apply_decode_paged``) and — when ``chunk`` is
    given — a chunked-prefill lane (``apply_chunk_paged``) against the
    *same* per-layer pools, in one trace.  The chunk lane is *narrow*:
    ``L`` lanes (typically 1, sized by the engine's token budget), each
    carrying one slot's next chunk and that slot's page-table row — a slot
    is active in at most one lane per step, and both lanes are
    row-parallel, so batch-invariance holds across arbitrary decode/prefill
    mixes.

    tokens: (slots, 1).  ``chunk`` is None (decode-only; this degenerates to
    the legacy paged decode step) or a dict with, for L chunk lanes:
      tokens     (L, C) int32, C a multiple of the policy block;
      page_table (L, max_pages) — a zero row for an idle lane;
      start      (L,) absolute chunk start (block-aligned);
      true_len   (L,) true prompt length (K/V zeroed at/after it);
      budgets    (L, C // block) int32 absolute-row block budgets;
      last       (L,) in-chunk index whose logits to return (the
                 prompt's final token, for chunks that finish a prefill).

    Returns (decode logits (slots, vocab),
             chunk logits (L, vocab) | None, new pools).
    """
    x = common.embed_lookup(params["embed"], tokens, cfg.jnp_dtype)
    xc = None
    if chunk is not None:
        xc = common.embed_lookup(params["embed"], chunk["tokens"], cfg.jnp_dtype)
    if cfg.embed_scale_flag:
        x = x * (cfg.d_model ** 0.5)
        xc = None if xc is None else xc * (cfg.d_model ** 0.5)
    new_pools = []
    for si, (n, kinds) in enumerate(layer_program(cfg)):
        seg = params[f"segment{si}"]
        pool = pools[si]

        def body(carry, scanned, kinds=kinds):
            x, xc = carry
            layer_params, pool = scanned
            new_pool = {}
            for i, k in enumerate(kinds):
                p = layer_params[f"sub{i}"]
                pl = pool[f"sub{i}"]
                if chunk is not None:
                    hc = common.rms_norm(xc, p["norm1"])
                    mix_c, pl = attention.apply_chunk_paged(
                        p["attn"], hc, cfg, pl, chunk["page_table"],
                        chunk["start"], chunk["true_len"], chunk["budgets"],
                        stem_cfg, k_max=chunk_k_max, executor=executor)
                    xc = xc + mix_c
                h = common.rms_norm(x, p["norm1"])
                mix, pl = attention.apply_decode_paged(
                    p["attn"], h, cfg, pl, page_table,
                    cache_lens, stem_cfg, budget_frac=budget_frac,
                    executor=executor)
                x = x + mix
                new_pool[f"sub{i}"] = pl

                def ffn(h2, k=k, p=p):
                    if k == "moe":
                        y, _ = moe.apply(p["ffn"], h2, cfg.moe, cfg.activation)
                        return y
                    return mlp.apply(p["ffn"], h2, cfg.activation)

                x = x + ffn(common.rms_norm(x, p["norm2"]))
                if chunk is not None:
                    xc = xc + ffn(common.rms_norm(xc, p["norm2"]))
            return (x, xc), new_pool

        if n == 1:
            (x, xc), npool = body((x, xc),
                                  (jax.tree.map(lambda t: t[0], seg),
                                   jax.tree.map(lambda t: t[0], pool)))
            npool = jax.tree.map(lambda t: t[None], npool)
        else:
            (x, xc), npool = jax.lax.scan(body, (x, xc), (seg, pool))
        new_pools.append(npool)
    dec_logits = _logits(params, x, cfg)[:, 0]
    chunk_logits = None
    if chunk is not None:
        xl = jnp.take_along_axis(xc, chunk["last"][:, None, None], axis=1)
        chunk_logits = _logits(params, xl, cfg)[:, 0]
    return dec_logits, chunk_logits, new_pools


def paged_sampled_step(params, token_buf: jnp.ndarray, pools,
                       page_table: jnp.ndarray, cache_lens: jnp.ndarray,
                       dec_mask: jnp.ndarray, cfg: ArchConfig, *, stem_cfg,
                       sampler, budget_frac: float = 1.0, chunk=None,
                       chunk_k_max: int = 0, executor=None):
    """``paged_mixed_step`` with sampling fused into the trace — the async
    engine's step.  Decode inputs come from ``token_buf`` (slots,), the
    device-resident fed-back token buffer, instead of a host-built tokens
    array; logits never leave the device — the sampler reduces them to
    int32 ids in the same trace, and the buffer is advanced in place:

      * decode lanes granted this step (``dec_mask`` (slots,) bool) write
        their sampled id back into the buffer (the next step's input);
        ungranted lanes keep their pending token;
      * a chunk lane that completes a prefill (``chunk["emit"]`` (L,)
        bool) scatters its sampled first token into ``chunk["slot"]``'s
        buffer entry — the request's decode stream starts on-device too.

    The only thing a host ever needs to fetch is the tiny id arrays
    (``dec_ids`` (slots,), ``chunk_ids`` (L,)) — one int32 per lane
    instead of a vocab-sized logits row.

    Returns (dec_ids (slots,) int32, chunk_ids (L,) int32 | None,
             new token_buf (slots,), new pools).
    """
    dec_logits, chunk_logits, new_pools = paged_mixed_step(
        params, token_buf[:, None], pools, page_table, cache_lens, cfg,
        stem_cfg=stem_cfg, budget_frac=budget_frac, chunk=chunk,
        chunk_k_max=chunk_k_max, executor=executor)
    dec_ids = sampler(dec_logits)
    new_buf = jnp.where(dec_mask, dec_ids, token_buf)
    chunk_ids = None
    if chunk is not None:
        chunk_ids = sampler(chunk_logits)
        # Completed-prefill lanes feed their first token into the buffer;
        # idle / mid-prompt lanes scatter out of bounds and are dropped.
        slots = token_buf.shape[0]
        target = jnp.where(chunk["emit"], chunk["slot"], slots)
        new_buf = new_buf.at[target].set(chunk_ids, mode="drop")
    return dec_ids, chunk_ids, new_buf, new_pools


def paged_decode_step(params, tokens: jnp.ndarray, pools,
                      page_table: jnp.ndarray, cache_lens: jnp.ndarray,
                      cfg: ArchConfig, *, stem_cfg,
                      budget_frac: float = 1.0, executor=None):
    """One token for every engine slot against the paged Stem KV cache —
    the decode-only view of ``paged_mixed_step`` (kept for direct callers).
    Returns (logits (slots, vocab), new pools)."""
    logits, _, new_pools = paged_mixed_step(
        params, tokens, pools, page_table, cache_lens, cfg,
        stem_cfg=stem_cfg, budget_frac=budget_frac, chunk=None,
        executor=executor)
    return logits, new_pools


def decode_step(params, tokens: jnp.ndarray, caches, cfg: ArchConfig, *,
                stem_cfg=None, budget_frac: float = 1.0):
    """One token for every sequence in the batch.  tokens: (b, 1).

    With ``stem_cfg`` the attention sub-layers decode POLICY-SPARSE over
    the contiguous cache (summarize + select per step) — the fixed-batch
    reference for the paged engine's sparse decode.  Only global-attention
    architectures support it (same constraint as paged serving)."""
    if stem_cfg is not None:
        assert_paged_servable(cfg)
    x = common.embed_lookup(params["embed"], tokens, cfg.jnp_dtype)
    if cfg.embed_scale_flag:
        x = x * (cfg.d_model ** 0.5)
    new_caches = []
    for si, (n, kinds) in enumerate(layer_program(cfg)):
        seg = params[f"segment{si}"]
        cache = caches[si]

        def body(x, scanned, kinds=kinds):
            layer_params, cache = scanned
            new_cache = {}
            for i, k in enumerate(kinds):
                x, c = _sublayer_decode(layer_params[f"sub{i}"], x, cfg, k,
                                        cache[f"sub{i}"], stem_cfg=stem_cfg,
                                        budget_frac=budget_frac)
                new_cache[f"sub{i}"] = c
            return x, new_cache

        if n == 1:
            x, nc = body(x, (jax.tree.map(lambda t: t[0], seg),
                             jax.tree.map(lambda t: t[0], cache)))
            nc = jax.tree.map(lambda t: t[None], nc)
        else:
            x, nc = jax.lax.scan(body, x, (seg, cache))
        new_caches.append(nc)
    logits = _logits(params, x, cfg)[:, 0]
    return logits, new_caches
