"""Unified model API over the arch families + abstract input specs.

``build(cfg)`` returns a ``ModelBundle`` whose members are pure functions —
the launch layer (train/serve/dryrun) composes them under pjit with the
sharding rules.  ``input_specs`` yields ShapeDtypeStructs for every
(arch x run-shape) cell so the multi-pod dry-run lowers without allocating.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RunShape
from repro.core.config import StemConfig
from repro.models import encdec, transformer

VLM_PATCH_FRACTION = 4   # 1/4 of the sequence is patch positions


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init_params: Callable[[jax.Array], Any]
    abstract_params: Callable[[], tuple[Any, Any]]
    loss_fn: Callable[..., tuple[jnp.ndarray, dict]]
    prefill: Callable[..., tuple[jnp.ndarray, Any]]
    decode_step: Callable[..., tuple[jnp.ndarray, Any]]
    init_caches: Callable[..., Any]


def build(cfg: ArchConfig) -> ModelBundle:
    if cfg.family == "encdec":
        return ModelBundle(
            cfg=cfg,
            init_params=lambda key: encdec.init_params(key, cfg),
            abstract_params=lambda: encdec.abstract_params(cfg),
            loss_fn=lambda p, b, **kw: encdec.loss_fn(p, b, cfg, **kw),
            prefill=lambda p, b, **kw: encdec.prefill(p, b, cfg, **kw),
            decode_step=lambda p, t, c: encdec.decode_step(p, t, c, cfg),
            init_caches=lambda batch, max_len: encdec.init_caches(
                cfg, batch, max_len, cfg.encdec.encoder_frames),
        )
    return ModelBundle(
        cfg=cfg,
        init_params=lambda key: transformer.init_params(key, cfg),
        abstract_params=lambda: transformer.abstract_params(cfg),
        loss_fn=lambda p, b, **kw: transformer.loss_fn(p, b, cfg, **kw),
        prefill=lambda p, b, **kw: transformer.prefill(p, b, cfg, **kw),
        decode_step=lambda p, t, c, **kw: transformer.decode_step(p, t, c, cfg, **kw),
        init_caches=lambda batch, max_len: transformer.init_caches(cfg, batch, max_len),
    )


# ---------------------------------------------------------------------------
# Abstract input specs per (arch x shape) cell
# ---------------------------------------------------------------------------

def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ArchConfig, shape: RunShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.family == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct(
                    (b, cfg.encdec.encoder_frames, cfg.d_model), jnp.bfloat16),
                "tokens": _tok(b, s),
                "labels": _tok(b, s),
            }
        if cfg.vlm_stub:
            s_img = s // VLM_PATCH_FRACTION
            return {
                "patch_embeds": jax.ShapeDtypeStruct((b, s_img, cfg.d_model), jnp.bfloat16),
                "tokens": _tok(b, s - s_img),
                "labels": _tok(b, s - s_img),
            }
        return {"tokens": _tok(b, s), "labels": _tok(b, s)}
    if shape.kind == "prefill":
        spec = input_specs(cfg, dataclasses.replace(shape, kind="train"))
        spec.pop("labels")
        return spec
    if shape.kind == "decode":
        return {"tokens": _tok(b, 1)}
    raise ValueError(shape.kind)


def abstract_caches(cfg: ArchConfig, shape: RunShape):
    """ShapeDtypeStructs for the serve-step KV caches of a decode cell."""
    bundle = build(cfg)
    return jax.eval_shape(
        lambda: bundle.init_caches(shape.global_batch, shape.seq_len))


# ---------------------------------------------------------------------------
# Parameter accounting (MODEL_FLOPS = 6 N D for the roofline)
# ---------------------------------------------------------------------------

def param_counts(cfg: ArchConfig) -> tuple[float, float]:
    """(total_params, active_params_per_token) from the abstract tree."""
    bundle = build(cfg)
    values, _ = bundle.abstract_params()
    total = sum(math.prod(v.shape) for v in jax.tree.leaves(values))
    active = total
    if cfg.moe is not None:
        e, k, f, d = (cfg.moe.num_experts, cfg.moe.top_k,
                      cfg.moe.expert_d_ff, cfg.d_model)
        n_moe_layers = cfg.num_layers - cfg.moe.first_k_dense
        all_expert = n_moe_layers * e * 3 * d * f
        active_expert = n_moe_layers * k * 3 * d * f
        active = total - all_expert + active_expert
    return float(total), float(active)
