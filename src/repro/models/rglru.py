"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Hawk-style temporal-mixing block:
  x -> {branch1: linear -> conv1d(w=4) -> RG-LRU, branch2: linear -> GeLU}
  out = proj(branch1 * branch2)

RG-LRU recurrence (per channel):
  r_t = sigmoid(W_a x_t + b_a)                         (recurrence gate)
  i_t = sigmoid(W_x x_t + b_x)                         (input gate)
  log a_t = -c * softplus(Lambda) * r_t                (c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` over (a_t, b_t) pairs —
O(N log N) depth, fully parallel across channels (sharded on `model`).
Decode is the O(1) per-step recurrence with a carried state — this is what
makes the 500k long-context decode cell sub-quadratic.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RGLRUConfig
from repro.models import common

_C = 8.0


class RGLRUState(NamedTuple):
    h: jnp.ndarray           # (b, width) recurrent state
    conv: jnp.ndarray        # (b, conv_width - 1, width) conv tail
    pos: jnp.ndarray


def init(ini: common.Initializer, cfg: ArchConfig) -> dict:
    r: RGLRUConfig = cfg.rglru
    d, w = cfg.d_model, r.lru_width
    return {
        "w_in": ini.normal((d, w), ("embed", "rnn")),
        "w_gate_branch": ini.normal((d, w), ("embed", "rnn")),
        "conv_w": ini.normal((r.conv_width, w), ("conv", "rnn"), scale=0.1),
        "conv_b": ini.zeros((w,), ("rnn",)),
        # Gate weights shard on the OUTPUT dim ("rnn_in" replicates): the
        # contraction then consumes one shared all-gather of xc (bf16)
        # instead of emitting two full psums per layer (§Perf
        # recurrentgemma iteration 1).
        "w_a": ini.normal((w, w), ("rnn_in", "rnn")),
        "b_a": ini.zeros((w,), ("rnn",)),
        "w_x": ini.normal((w, w), ("rnn_in", "rnn")),
        "b_x": ini.zeros((w,), ("rnn",)),
        # Lambda parameterized so a ~ U(0.9, 0.999) at init (paper appendix).
        "lam": ini.value(jnp.linspace(2.0, 6.0, w, dtype=jnp.float32), ("rnn",)),
        "w_out": ini.normal((w, d), ("rnn", "embed")),
    }


def _conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Causal depthwise conv along time: x (b, s, w); w (cw, w)."""
    cw = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pads[:, i : i + x.shape[1], :] * w[i] for i in range(cw))
    return out + b


def _gates(params, xc: jnp.ndarray):
    """Returns (log_a, b_t) of the linear recurrence h = a h + b."""
    r = jax.nn.sigmoid(jnp.einsum("bsw,wu->bsu", xc, params["w_a"]) + params["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wu->bsu", xc, params["w_x"]) + params["b_x"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i.astype(jnp.float32) * xc.astype(jnp.float32))
    return a, b


def apply_full(params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Training/prefill over full sequence via associative scan."""
    xb = jnp.einsum("bsd,dw->bsw", x, params["w_in"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate_branch"]))
    xc = _conv1d(xb, params["conv_w"], params["conv_b"])
    a, b = _gates(params, xc)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(x.dtype) * gate)
    return jnp.einsum("bsw,wd->bsd", y, params["w_out"])


def init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> RGLRUState:
    r = cfg.rglru
    return RGLRUState(
        h=jnp.zeros((batch, r.lru_width), jnp.float32),
        conv=jnp.zeros((batch, r.conv_width - 1, r.lru_width), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def prefill_into_state(params, x, cfg: ArchConfig):
    """Full-sequence output + final recurrent state for decode."""
    out = apply_full(params, x, cfg)
    xb = jnp.einsum("bsd,dw->bsw", x, params["w_in"])
    xc = _conv1d(xb, params["conv_w"], params["conv_b"])
    a, b = _gates(params, xc)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    cw = cfg.rglru.conv_width
    state = RGLRUState(
        h=h[:, -1].astype(jnp.float32),
        conv=xb[:, -(cw - 1):].astype(x.dtype),
        pos=jnp.asarray(x.shape[1], jnp.int32),
    )
    return out, state


def apply_decode(params, x: jnp.ndarray, cfg: ArchConfig, state: RGLRUState):
    """One step: x (b, 1, d)."""
    xb = jnp.einsum("bsd,dw->bsw", x, params["w_in"])[:, 0]      # (b, w)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate_branch"]))[:, 0]
    # conv over [tail, new]
    hist = jnp.concatenate([state.conv, xb[:, None]], axis=1)    # (b, cw, w)
    w = params["conv_w"]
    xc = (hist * w[None]).sum(axis=1) + params["conv_b"]
    a, b = _gates(params, xc[:, None])
    a, b = a[:, 0], b[:, 0]
    h_new = a * state.h + b
    y = (h_new.astype(x.dtype) * gate)[:, None]
    out = jnp.einsum("bsw,wd->bsd", y, params["w_out"])
    new_state = RGLRUState(h=h_new, conv=hist[:, 1:], pos=state.pos + 1)
    return out, new_state
