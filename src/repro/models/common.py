"""Shared model building blocks (pure JAX, no flax).

Parameters are nested dicts of ``Param(value, axes)`` where ``axes`` is a
tuple of *logical* axis names consumed by sharding/rules.py.  ``unzip``
splits a param tree into a value tree (fed to jit) and an axes tree (used to
build NamedShardings); ``zip_trees`` re-attaches them.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp


class Param(NamedTuple):
    value: Any                 # jnp.ndarray | ShapeDtypeStruct
    axes: tuple[Optional[str], ...]


def is_param(x) -> bool:
    return isinstance(x, Param)


def unzip(tree):
    """Param tree -> (values, axes)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def zip_trees(values, axes):
    return jax.tree.map(Param, values, axes, is_leaf=lambda x: x is None or isinstance(x, tuple))


class Initializer:
    """Splits one PRNG key on demand — keeps init functions linear to read."""

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self._key = key
        self.dtype = dtype

    def next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def normal(self, shape: Sequence[int], axes, scale: float | None = None,
               dtype=None) -> Param:
        fan_in = max(int(math.prod(shape[:-1])) or shape[-1], 1)
        scale = (1.0 / math.sqrt(fan_in)) if scale is None else scale
        v = jax.random.normal(self.next_key(), tuple(shape), jnp.float32) * scale
        return Param(v.astype(dtype or self.dtype), tuple(axes))

    def zeros(self, shape: Sequence[int], axes, dtype=None) -> Param:
        return Param(jnp.zeros(tuple(shape), dtype or self.dtype), tuple(axes))

    def ones(self, shape: Sequence[int], axes, dtype=None) -> Param:
        return Param(jnp.ones(tuple(shape), dtype or self.dtype), tuple(axes))

    def value(self, v: jnp.ndarray, axes) -> Param:
        return Param(v, tuple(axes))


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm_simple(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Bias-free LayerNorm (whisper layers; bias dropped — noted in DESIGN)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = x32.mean(axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = x32.mean(axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., seq, head_dim); positions: (seq,) or (batch, seq)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    # Broadcast over head dims: x is (b, h, s, d); angles (s, d/2) or (b, s, d/2).
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.arange(0, dim, 2, dtype=jnp.float32) * (math.log(10000.0) / dim))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / LM head / loss
# ---------------------------------------------------------------------------

def embed_init(ini: Initializer, vocab: int, d_model: int) -> Param:
    return ini.normal((vocab, d_model), ("vocab", "embed"), scale=0.02, dtype=jnp.float32)


def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0).astype(dtype)


def lm_logits(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Tied LM head: (b, s, d) @ (vocab, d)^T -> (b, s, vocab)."""
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32), table.astype(jnp.float32))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean CE over non-masked positions.  Vocab-sharding friendly: no
    full-vocab gather materialization beyond take_along_axis (GSPMD lowers it
    to a local gather + small collective on the sharded vocab axis)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# KV-cache helpers (functional; caches are plain dicts of arrays)
# ---------------------------------------------------------------------------

def update_cache(cache_k: jnp.ndarray, cache_v: jnp.ndarray, pos: jnp.ndarray,
                 new_k: jnp.ndarray, new_v: jnp.ndarray):
    """Insert one step at position ``pos``.  cache: (b, hk, L, d);
    new: (b, hk, 1, d).  ``pos`` is a scalar (uniform batch) or a ``(b,)``
    vector (ragged batch — every row writes at its own length)."""
    if pos.ndim == 0:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache_k, new_k.astype(cache_k.dtype), pos, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache_v, new_v.astype(cache_v.dtype), pos, axis=2)
        return ck, cv
    bidx = jnp.arange(cache_k.shape[0])
    ck = cache_k.at[bidx, :, pos].set(new_k[:, :, 0].astype(cache_k.dtype))
    cv = cache_v.at[bidx, :, pos].set(new_v[:, :, 0].astype(cache_v.dtype))
    return ck, cv


def update_ring_cache(cache_k, cache_v, pos, new_k, new_v, window: int):
    """Ring-buffer cache for windowed attention: O(window) memory at any
    sequence length (what makes recurrentgemma's 500k decode sub-quadratic).
    ``pos`` scalar or (b,) — see ``update_cache``."""
    slot = pos % window
    if pos.ndim == 0:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache_k, new_k.astype(cache_k.dtype), slot, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache_v, new_v.astype(cache_v.dtype), slot, axis=2)
        return ck, cv
    bidx = jnp.arange(cache_k.shape[0])
    ck = cache_k.at[bidx, :, slot].set(new_k[:, :, 0].astype(cache_k.dtype))
    cv = cache_v.at[bidx, :, slot].set(new_v[:, :, 0].astype(cache_v.dtype))
    return ck, cv
