"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the brief: ``input_specs()`` supplies
precomputed frame embeddings (batch, frames, d_model).  The encoder is
bidirectional self-attention (+ sinusoidal positions); the decoder is a
causal LM with cross-attention (+ learned positions).  Stem applies to the
decoder *self*-attention prefill only (DESIGN.md §5): the encoder has no
causal information-flow asymmetry, and cross-attention sees a fixed small
source.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.config import StemConfig
from repro.models import attention, common, mlp


class DecLayerCache(NamedTuple):
    self_cache: attention.KVCache
    cross_k: jnp.ndarray
    cross_v: jnp.ndarray


def _init_enc_layer(ini, cfg: ArchConfig) -> dict:
    return {
        "norm1": ini.zeros((cfg.d_model,), ("embed",)),
        "attn": attention.init(ini, cfg),
        "norm2": ini.zeros((cfg.d_model,), ("embed",)),
        "ffn": mlp.init(ini, cfg.d_model, cfg.d_ff, "gelu_mlp"),
    }


def _init_dec_layer(ini, cfg: ArchConfig) -> dict:
    return {
        "norm1": ini.zeros((cfg.d_model,), ("embed",)),
        "self_attn": attention.init(ini, cfg),
        "norm2": ini.zeros((cfg.d_model,), ("embed",)),
        "cross_attn": attention.init_cross(ini, cfg),
        "norm3": ini.zeros((cfg.d_model,), ("embed",)),
        "ffn": mlp.init(ini, cfg.d_model, cfg.d_ff, "gelu_mlp"),
    }


def _stack(ini, init_one, n):
    def one(key):
        sub = common.Initializer(key, ini.dtype)
        return common.unzip(init_one(sub))[0]
    keys = jax.random.split(ini.next_key(), n)
    values = jax.vmap(one)(keys)
    _, axes = common.unzip(init_one(common.Initializer(jax.random.PRNGKey(0), ini.dtype)))
    axes = jax.tree.map(lambda a: ("layers",) + a, axes,
                        is_leaf=lambda t: isinstance(t, tuple))
    return common.zip_trees(values, axes)


def init_encdec(key: jax.Array, cfg: ArchConfig) -> dict:
    ini = common.Initializer(key, cfg.jnp_dtype)
    max_dec_pos = 65536   # learned decoder positions table
    return {
        "embed": common.embed_init(ini, cfg.padded_vocab, cfg.d_model),
        "dec_pos": ini.normal((max_dec_pos, cfg.d_model), (None, "embed"), scale=0.01),
        "enc_layers": _stack(ini, lambda i: _init_enc_layer(i, cfg), cfg.encdec.encoder_layers),
        "enc_norm": ini.zeros((cfg.d_model,), ("embed",)),
        "dec_layers": _stack(ini, lambda i: _init_dec_layer(i, cfg), cfg.num_layers),
        "final_norm": ini.zeros((cfg.d_model,), ("embed",)),
    }


def init_params(key, cfg):
    return common.unzip(init_encdec(key, cfg))[0]


def abstract_params(cfg: ArchConfig):
    captured = {}

    def f(key):
        values, axes = common.unzip(init_encdec(key, cfg))
        captured["axes"] = axes
        return values

    values = jax.eval_shape(f, jax.random.PRNGKey(0))
    return values, captured["axes"]


def encode(params, frames: jnp.ndarray, cfg: ArchConfig, *, remat: bool = True):
    """frames: (b, F, d) stub embeddings -> (b, F, d) encoder states."""
    pos = common.sinusoidal_positions(frames.shape[1], cfg.d_model)
    x = frames.astype(cfg.jnp_dtype) + pos.astype(cfg.jnp_dtype)
    positions = jnp.arange(frames.shape[1])

    def body(x, layer):
        h = common.layer_norm_simple(x, layer["norm1"])
        x = x + attention.apply_full(layer["attn"], h, cfg, positions=positions,
                                     use_rope=False, causal=False)
        h = common.layer_norm_simple(x, layer["norm2"])
        x = x + mlp.apply(layer["ffn"], h, "gelu_mlp")
        return x, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return common.layer_norm_simple(x, params["enc_norm"])


def _dec_embed(params, tokens, cfg: ArchConfig, start: int | jnp.ndarray = 0):
    x = common.embed_lookup(params["embed"], tokens, cfg.jnp_dtype)
    n = tokens.shape[1]
    pos_tab = jax.lax.dynamic_slice_in_dim(params["dec_pos"], start, n, axis=0)
    return x + pos_tab[None].astype(cfg.jnp_dtype)


def loss_fn(params, batch: dict, cfg: ArchConfig, *,
            stem_cfg: Optional[StemConfig] = None, remat: bool = True):
    """batch: frames (b,F,d), tokens (b,s), labels (b,s)."""
    enc = encode(params, batch["frames"], cfg, remat=remat)
    x = _dec_embed(params, batch["tokens"], cfg)
    positions = jnp.arange(batch["tokens"].shape[1])

    def body(x, layer):
        h = common.layer_norm_simple(x, layer["norm1"])
        x = x + attention.apply_full(layer["self_attn"], h, cfg,
                                     positions=positions, stem_cfg=stem_cfg,
                                     use_rope=False)
        h = common.layer_norm_simple(x, layer["norm2"])
        ck, cv = attention.cross_kv(layer["cross_attn"], enc)
        x = x + attention.apply_cross(layer["cross_attn"], h, ck, cv, cfg.head_dim)
        h = common.layer_norm_simple(x, layer["norm3"])
        x = x + mlp.apply(layer["ffn"], h, "gelu_mlp")
        return x, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = common.layer_norm_simple(x, params["final_norm"])
    logits = common.lm_logits(x, params["embed"])
    ce = common.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce, {"ce": ce, "loss": ce}


def prefill(params, batch: dict, cfg: ArchConfig, *, max_len: int,
            stem_cfg: Optional[StemConfig] = None):
    """Encode + run the decoder prompt; returns (logits, stacked caches)."""
    enc = encode(params, batch["frames"], cfg, remat=False)
    x = _dec_embed(params, batch["tokens"], cfg)
    n = batch["tokens"].shape[1]
    positions = jnp.arange(n)

    def body(x, layer):
        h = common.layer_norm_simple(x, layer["norm1"])
        sa, cache = attention.prefill_into_cache(
            layer["self_attn"], h, cfg, positions=positions, max_len=max_len,
            stem_cfg=stem_cfg)
        x = x + sa
        h = common.layer_norm_simple(x, layer["norm2"])
        ck, cv = attention.cross_kv(layer["cross_attn"], enc)
        x = x + attention.apply_cross(layer["cross_attn"], h, ck, cv, cfg.head_dim)
        h = common.layer_norm_simple(x, layer["norm3"])
        x = x + mlp.apply(layer["ffn"], h, "gelu_mlp")
        return x, DecLayerCache(self_cache=cache, cross_k=ck, cross_v=cv)

    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    x = common.layer_norm_simple(x, params["final_norm"])
    logits = common.lm_logits(x[:, -1:], params["embed"])[:, 0]
    return logits, caches


def decode_step(params, tokens: jnp.ndarray, caches, cfg: ArchConfig):
    pos0 = caches.self_cache.pos[0]
    x = _dec_embed(params, tokens, cfg, start=pos0)

    def body(x, scanned):
        layer, cache = scanned
        h = common.layer_norm_simple(x, layer["norm1"])
        sa, new_self = attention.apply_decode(layer["self_attn"], h, cfg,
                                              cache.self_cache, use_rope=False)
        x = x + sa
        h = common.layer_norm_simple(x, layer["norm2"])
        x = x + attention.apply_cross(layer["cross_attn"], h, cache.cross_k,
                                      cache.cross_v, cfg.head_dim)
        h = common.layer_norm_simple(x, layer["norm3"])
        x = x + mlp.apply(layer["ffn"], h, "gelu_mlp")
        return x, DecLayerCache(new_self, cache.cross_k, cache.cross_v)

    x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], caches))
    x = common.layer_norm_simple(x, params["final_norm"])
    logits = common.lm_logits(x, params["embed"])[:, 0]
    return logits, new_caches


def init_caches(cfg: ArchConfig, batch: int, max_len: int, frames: int):
    one = DecLayerCache(
        self_cache=attention.init_cache(cfg, batch, max_len, dtype=cfg.jnp_dtype),
        cross_k=jnp.zeros((batch, cfg.num_heads, frames, cfg.head_dim), cfg.jnp_dtype),
        cross_v=jnp.zeros((batch, cfg.num_heads, frames, cfg.head_dim), cfg.jnp_dtype),
    )
    return jax.tree.map(lambda t: jnp.broadcast_to(t, (cfg.num_layers,) + t.shape), one)
