"""Multi-head Latent Attention (DeepSeek-V3) with Stem integration.

MLA compresses K/V into a small latent c_kv (512) plus a shared 64-dim
rotary key.  The KV cache stores only (c_kv, k_rope) — that *is* the
memory win — and queries use a low-rank down/up projection.

Stem integration (paper §3, the DSA + Stem experiment): the TPD schedule
wraps block selection over the expanded keys, and OAM's value-magnitude term
uses ||c_j|| as the latent proxy for ||W_UV c_j|| (W_UV is shared across
positions so rankings are preserved up to its spectrum — noted in
DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLAConfig
from repro.core import policy as policy_lib
from repro.core.sparse_attention import dense_attention_auto, sparse_attention
from repro.models import common


class MLACache(NamedTuple):
    c_kv: jnp.ndarray     # (b, L, kv_rank) compressed latents
    k_rope: jnp.ndarray   # (b, L, rope_dim) shared rotary key
    pos: jnp.ndarray


def init(ini: common.Initializer, cfg: ArchConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dh_q = m.nope_head_dim + m.rope_head_dim
    return {
        "w_dq": ini.normal((d, m.q_lora_rank), ("embed", "q_lora")),
        "q_norm": ini.zeros((m.q_lora_rank,), ("q_lora",)),
        "w_uq": ini.normal((m.q_lora_rank, h, dh_q), ("q_lora", "heads", "head_dim")),
        "w_dkv": ini.normal((d, m.kv_lora_rank), ("embed", "kv_lora")),
        "kv_norm": ini.zeros((m.kv_lora_rank,), ("kv_lora",)),
        "w_uk": ini.normal((m.kv_lora_rank, h, m.nope_head_dim), ("kv_lora", "heads", "head_dim")),
        "w_uv": ini.normal((m.kv_lora_rank, h, m.v_head_dim), ("kv_lora", "heads", "head_dim")),
        "w_kr": ini.normal((d, m.rope_head_dim), ("embed", "head_dim")),
        "wo": ini.normal((h, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


def _queries(params, x, cfg: ArchConfig, positions):
    m = cfg.mla
    cq = common.rms_norm(jnp.einsum("bsd,dr->bsr", x, params["w_dq"]), params["q_norm"])
    q = jnp.einsum("bsr,rhk->bhsk", cq, params["w_uq"])
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim :]
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(params, x, cfg: ArchConfig, positions):
    m = cfg.mla
    c = common.rms_norm(jnp.einsum("bsd,dr->bsr", x, params["w_dkv"]), params["kv_norm"])
    kr = jnp.einsum("bsd,dk->bsk", x, params["w_kr"])
    kr = common.apply_rope(kr[:, None], positions, cfg.rope_theta)[:, 0]
    return c, kr


def _expand(params, c, kr, cfg: ArchConfig):
    """Expand latents to per-head keys/values; concat the shared rope key."""
    k_nope = jnp.einsum("bsr,rhk->bhsk", c, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bhsk", c, params["w_uv"])
    kr_h = jnp.broadcast_to(kr[:, None], (kr.shape[0], cfg.num_heads) + kr.shape[1:])
    k = jnp.concatenate([k_nope, kr_h], axis=-1)
    return k, v


def apply_full(
    params, x, cfg: ArchConfig, *, positions,
    stem_cfg=None, return_stats: bool = False,
):
    """``stem_cfg``: SparsityPolicy | policy name | StemConfig | None."""
    m = cfg.mla
    pol = policy_lib.as_policy_opt(stem_cfg)
    q_nope, q_rope = _queries(params, x, cfg, positions)
    c, kr = _latents(params, x, cfg, positions)
    k, v = _expand(params, c, kr, cfg)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    stats = None
    if pol is not None and x.shape[1] % pol.block_size == 0 \
            and x.shape[1] // pol.block_size >= 2:
        if return_stats:
            o, stats = sparse_attention(q, k, v, pol, return_stats=True)
        else:
            o = sparse_attention(q, k, v, pol)
    else:
        o = dense_attention_auto(q, k, v, causal=True, scale=scale)
    out = jnp.einsum("bhsk,hkd->bsd", o, params["wo"])
    return (out, stats) if return_stats else out


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> MLACache:
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def prefill_into_cache(params, x, cfg: ArchConfig, *, positions, max_len: int,
                       stem_cfg=None):
    out = apply_full(params, x, cfg, positions=positions, stem_cfg=stem_cfg)
    c, kr = _latents(params, x, cfg, positions)
    pad = max_len - x.shape[1]
    cache = MLACache(
        c_kv=jnp.pad(c, ((0, 0), (0, pad), (0, 0))).astype(jnp.bfloat16),
        k_rope=jnp.pad(kr, ((0, 0), (0, pad), (0, 0))).astype(jnp.bfloat16),
        pos=jnp.asarray(x.shape[1], jnp.int32),
    )
    return out, cache


def apply_decode(params, x, cfg: ArchConfig, cache: MLACache):
    """One decode step.  Latent cache only: expand per step.  ``cache.pos``
    scalar or (b,) — per-row positions for ragged/continuous batching."""
    m = cfg.mla
    pos = cache.pos
    b = x.shape[0]
    rope_pos = pos[None] if pos.ndim == 0 else pos[:, None]
    q_nope, q_rope = _queries(params, x, cfg, rope_pos)
    c_new, kr_new = _latents(params, x, cfg, rope_pos)
    if pos.ndim == 0:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache.c_kv, c_new.astype(cache.c_kv.dtype), pos, axis=1)
        ckr = jax.lax.dynamic_update_slice_in_dim(
            cache.k_rope, kr_new.astype(cache.k_rope.dtype), pos, axis=1)
    else:
        bidx = jnp.arange(b)
        ck = cache.c_kv.at[bidx, pos].set(c_new[:, 0].astype(cache.c_kv.dtype))
        ckr = cache.k_rope.at[bidx, pos].set(kr_new[:, 0].astype(cache.k_rope.dtype))
    L = ck.shape[1]
    posv = jnp.broadcast_to(pos, (b,))
    valid = jnp.arange(L)[None, :] <= posv[:, None]

    # Absorbed attention: score = q_nope . (W_UK c) + q_rope . k_rope.
    q_abs = jnp.einsum("bhsk,rhk->bhsr", q_nope, params["w_uk"])   # (b,h,1,r)
    s = jnp.einsum("bhsr,blr->bhsl", q_abs.astype(jnp.float32), ck.astype(jnp.float32))
    s = s + jnp.einsum("bhsk,blk->bhsl", q_rope.astype(jnp.float32), ckr.astype(jnp.float32))
    s = s * ((m.nope_head_dim + m.rope_head_dim) ** -0.5)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhsl,blr->bhsr", p, ck.astype(jnp.float32))  # (b,h,1,r)
    o = jnp.einsum("bhsr,rhk->bhsk", o_lat.astype(x.dtype), params["w_uv"])
    out = jnp.einsum("bhsk,hkd->bsd", o, params["wo"])
    return out, MLACache(c_kv=ck, k_rope=ckr, pos=pos + 1)
