"""Feed-forward variants: SwiGLU (llama/qwen/glm), GeGLU (gemma/griffin),
plain GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


def init(ini: common.Initializer, d_model: int, d_ff: int, activation: str) -> dict:
    if activation in ("silu", "gelu"):        # gated: gate + up + down
        return {
            "w_gate": ini.normal((d_model, d_ff), ("embed", "mlp")),
            "w_up": ini.normal((d_model, d_ff), ("embed", "mlp")),
            "w_down": ini.normal((d_ff, d_model), ("mlp", "embed")),
        }
    if activation == "gelu_mlp":              # plain 2-layer MLP
        return {
            "w_in": ini.normal((d_model, d_ff), ("embed", "mlp")),
            "b_in": ini.zeros((d_ff,), ("mlp",)),
            "w_out": ini.normal((d_ff, d_model), ("mlp", "embed")),
            "b_out": ini.zeros((d_model,), ("embed",)),
        }
    raise ValueError(f"unknown activation {activation!r}")


def apply(params: dict, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation in ("silu", "gelu"):
        act = jax.nn.silu if activation == "silu" else jax.nn.gelu
        g = act(jnp.einsum("bsd,df->bsf", x, params["w_gate"]))
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
        return jnp.einsum("bsf,fd->bsd", g * u, params["w_down"])
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, params["w_in"]) + params["b_in"])
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"]) + params["b_out"]
