from repro.optim.adamw import (AdamWConfig, OptState, cast_params, init_state,
                               lr_at, update)
from repro.optim import adamw

__all__ = ["AdamWConfig", "OptState", "init_state", "lr_at", "update",
           "cast_params", "adamw"]
