"""AdamW with warmup-cosine schedule, global-norm clipping, fp32 master
weights, and optional bf16 gradient compression.

Distributed posture (ZeRO-1-by-sharding): the optimizer state tree carries
the *same* logical axes as the parameters, so under the sharding rules the
fp32 master copy + moments are sharded exactly like the weights — with
``fsdp_weights`` archs that means moments shard over (data x model) and no
device ever holds a full optimizer replica.

Gradient compression: when ``grad_dtype = "bfloat16"``, gradients are cast
before the data-parallel all-reduce (GSPMD reduces in the cast dtype —
halves cross-pod DCI traffic) and the update math is fp32 on the master
copy, preserving convergence behaviour (standard mixed-precision recipe).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_dtype: Optional[str] = "bfloat16"   # gradient-compression cast
    moment_dtype: str = "float32"            # "bfloat16" halves mu/nu memory


class OptState(NamedTuple):
    step: jnp.ndarray
    master: Any      # fp32 master weights (same tree/logical axes as params)
    mu: Any
    nu: Any


def init_state(params, cfg: Optional[AdamWConfig] = None) -> OptState:
    mdt = jnp.bfloat16 if (cfg and cfg.moment_dtype == "bfloat16") else jnp.float32
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def lr_at(step: jnp.ndarray, cfg: AdamWConfig) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def compress_grads(grads, cfg: AdamWConfig):
    """Cast gradients before the DP all-reduce (bandwidth compression)."""
    if cfg.grad_dtype is None:
        return grads
    dt = jnp.bfloat16 if cfg.grad_dtype == "bfloat16" else jnp.float32
    return jax.tree.map(lambda g: g.astype(dt), grads)


def update(grads, state: OptState, cfg: AdamWConfig):
    """One AdamW step on the fp32 master; returns (bf16-cast params for the
    next forward, new state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1.0 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1.0 - b2) * g * g
        mh = m_new / c1
        vh = v_new / c2
        p_new = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return m_new.astype(mdt), v_new.astype(mdt), p_new

    flat = jax.tree.map(upd, grads, state.mu, state.nu, state.master)
    mu = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_state = OptState(step=step, master=master, mu=mu, nu=nu)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_state, metrics


def cast_params(state: OptState, like) -> Any:
    """Master fp32 -> forward dtype of the reference tree."""
    return jax.tree.map(lambda m, p: m.astype(p.dtype), state.master, like)
