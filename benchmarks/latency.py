"""Paper Figure 1 analog: prefill attention cost, Dense vs Stem vs baselines.

On this CPU container wall-clock is a proxy (XLA-CPU, fp32); the transferable
quantities are the computed-pair budgets and FLOP counts, which are
hardware-independent, plus the wall-time *ratio* trend across lengths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import StemConfig, schedule, stem_attention
from repro.core.sparse_attention import dense_attention_chunked
from repro.core.baselines import baseline_attention


def run() -> list[tuple]:
    rows = []
    B, Hq, Hk, D = 1, 4, 2, 64
    for n in (2048, 4096, 8192, 16384):
        ks = jax.random.split(jax.random.PRNGKey(n), 3)
        q = jax.random.normal(ks[0], (B, Hq, n, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hk, n, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hk, n, D), jnp.float32)
        cfg = common.bench_stem(block_size=128, k_start_frac=None,
                                min_budget_blocks=4)

        dense_t = common.timer(
            functools.partial(dense_attention_chunked, causal=True), q, k, v)
        stem_fn = jax.jit(functools.partial(stem_attention, cfg=cfg))
        stem_t = common.timer(lambda q, k, v: stem_fn(q=q, k=k, v=v), q, k, v)

        budgets = schedule.schedule_for(cfg, n)
        pairs_dense = n * (n + 1) / 2
        pairs_stem = schedule.measured_cost_blocks(budgets, cfg.block_size)
        rows.append((f"fig1/dense_n{n}", dense_t * 1e6,
                     f"pairs={pairs_dense:.3g}"))
        rows.append((f"fig1/stem_n{n}", stem_t * 1e6,
                     f"pairs={pairs_stem:.3g};speedup={dense_t/stem_t:.2f}x;"
                     f"budget={pairs_stem/pairs_dense:.3f}"))
    return rows
