"""A/B harness: padded seed executor vs the ragged/deduped engine.

Times the sparse *execution phase* in isolation (selection is identical in
both arms) at the acceptance geometry — seq=8192, block=128, mu=0.25,
GQA group=4 — and verifies the ragged output against a row-chunked dense
masked oracle (same selection, full-softmax fp32 math).  Demonstrates that
ragged wall-clock tracks ``avg_budget_blocks`` where the padded executor
pays ``k_max`` on every row (DESIGN.md §Ragged slot layout).

Writes ``BENCH_ragged.json`` so CI keeps a perf trajectory across PRs.

Standalone: ``PYTHONPATH=src python benchmarks/ragged_exec.py [--quick]``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import StemConfig, schedule
from repro.core.sparse_attention import _gather_executor, select_for

NEG_INF = -1e30


def bench_cfg(**kw) -> StemConfig:
    base = dict(
        block_size=128, k_start_frac=0.5, mu=0.25, beta=0.2,
        sink_blocks=1, local_blocks=1, min_budget_blocks=2, stride=16,
        group_reduce="mean", slot_chunk=4,
    )
    base.update(kw)
    return StemConfig(**base)


def timer(fn, *args, repeats=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def dense_oracle_rowchunked(q, k, v, block_mask, block_size, rows_per_chunk=4):
    """O(N^2) masked oracle, streamed over query-block-row chunks so the
    (sq_chunk, sk) score matrix stays bounded at long sequence lengths.

    q: (b, hq, sq, d); k, v: (b, hk, sk, d); block_mask: (b, hq, nq, nk).
    Full-softmax fp32 math — the bitwise reference the executors chase.
    """
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    group = hq // hk
    bs = block_size
    nq = sq // bs
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    outs = []
    for r0 in range(0, nq, rows_per_chunk):
        r1 = min(r0 + rows_per_chunk, nq)
        qc = q[:, :, r0 * bs:r1 * bs].astype(jnp.float32) * (d ** -0.5)
        qc = qc.reshape(b, hk, group, (r1 - r0) * bs, d)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kf)
        bm = block_mask[:, :, r0:r1]                     # (b, hq, rows, nk)
        tok = jnp.repeat(jnp.repeat(bm, bs, axis=-2), bs, axis=-1)
        qi = (sk - sq) + r0 * bs + jnp.arange((r1 - r0) * bs)[:, None]
        kj = jnp.arange(sk)[None, :]
        tok = tok & (kj <= qi)
        s = jnp.where(tok.reshape(b, hk, group, (r1 - r0) * bs, sk), s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        outs.append(jnp.einsum("bhgqk,bhkd->bhgqd", p, vf))
    out = jnp.concatenate(outs, axis=3)
    return out.reshape(b, hq, sq, -1)


def run_case(seq: int, dtype, repeats: int) -> dict:
    b, hk, group, d = 1, 2, 4, 64
    hq = hk * group
    cfg = bench_cfg()
    bs = cfg.block_size
    scale = d ** -0.5

    ks = jax.random.split(jax.random.PRNGKey(seq), 3)
    q = jax.random.normal(ks[0], (b, hq, seq, d), dtype)
    k = jax.random.normal(ks[1], (b, hk, seq, d), dtype)
    v = jax.random.normal(ks[2], (b, hk, seq, d), dtype)

    # One shared selection for both arms (block mask only feeds the oracle;
    # at block granularity it is tiny).
    sel, k_max = select_for(q, k, v, cfg, with_block_mask=True)
    sel = jax.tree.map(jax.block_until_ready, sel)
    budgets = schedule.schedule_for(cfg, seq)
    idx_dedup = sel.indices[:, ::group]
    msk_dedup = sel.slot_mask[:, ::group]

    padded_fn = jax.jit(lambda q, k, v, i, m: _gather_executor(
        q, k, v, i, m, block_size=bs, scale=scale, slot_chunk=cfg.slot_chunk,
        budgets=None, group_dedup=False))
    ragged_fn = jax.jit(lambda q, k, v, i, m: _gather_executor(
        q, k, v, i, m, block_size=bs, scale=scale, slot_chunk=cfg.slot_chunk,
        budgets=budgets, group_dedup=True))

    t_padded = timer(padded_fn, q, k, v, sel.indices, sel.slot_mask, repeats=repeats)
    t_ragged = timer(ragged_fn, q, k, v, idx_dedup, msk_dedup, repeats=repeats)

    out_ragged = ragged_fn(q, k, v, idx_dedup, msk_dedup)
    out_padded = padded_fn(q, k, v, sel.indices, sel.slot_mask)
    oracle = dense_oracle_rowchunked(q, k, v, sel.block_mask, bs)
    err_ragged = float(jnp.abs(out_ragged.astype(jnp.float32) - oracle).max())
    err_padded = float(jnp.abs(out_padded.astype(jnp.float32) - oracle).max())

    chunk = cfg.slot_chunk
    padded_chunks = (len(budgets) * -(-int(k_max) // chunk))
    ragged_chunks = int(sum(max(1, -(-int(x) // chunk)) for x in budgets))
    return {
        "seq": seq,
        "dtype": str(jnp.dtype(dtype)),
        "block_size": bs,
        "mu": cfg.mu,
        "group": group,
        "heads": {"q": hq, "kv": hk},
        "k_max": int(k_max),
        "avg_budget_blocks": float(np.mean(budgets)),
        "slot_chunks": {"padded": padded_chunks, "ragged": ragged_chunks},
        "t_padded_s": t_padded,
        "t_ragged_s": t_ragged,
        "speedup": t_padded / t_ragged,
        "max_abs_err_ragged": err_ragged,
        "max_abs_err_padded": err_padded,
    }


def run(quick: bool = True):
    """benchmarks/run.py entry point: CSV rows from the quick geometry."""
    case = run_case(2048 if quick else 8192, jnp.bfloat16, repeats=3)
    return [
        ("ragged_exec/padded", case["t_padded_s"] * 1e6,
         f"k_max={case['k_max']}"),
        ("ragged_exec/ragged", case["t_ragged_s"] * 1e6,
         f"speedup={case['speedup']:.2f}x;avg_budget={case['avg_budget_blocks']:.1f};"
         f"err={case['max_abs_err_ragged']:.2e}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: seq=2048, fewer repeats")
    ap.add_argument("--out", default="BENCH_ragged.json")
    args = ap.parse_args()

    seq = 2048 if args.quick else 8192
    repeats = 3 if args.quick else 5
    case = run_case(seq, jnp.bfloat16, repeats=repeats)
    report = {
        "benchmark": "ragged_exec",
        "mode": "quick" if args.quick else "full",
        "backend": jax.default_backend(),
        "case": case,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))
    ok = case["speedup"] >= 1.5 and case["max_abs_err_ragged"] <= 2e-2
    print(f"# speedup {case['speedup']:.2f}x "
          f"(padded {case['t_padded_s']*1e3:.1f} ms -> ragged {case['t_ragged_s']*1e3:.1f} ms), "
          f"max|err| {case['max_abs_err_ragged']:.2e} "
          f"-> {'PASS' if ok else 'BELOW TARGET'}")


if __name__ == "__main__":
    main()
