"""Shared benchmark infrastructure.

``trained_model()`` trains a small decoder LM from scratch on the
structured synthetic stream (sinks + copied motifs) and caches it under
results/bench_model — so the accuracy benchmarks measure Stem on *real*
attention distributions (sinks and heavy hitters emerge within a few
hundred steps even at this scale), exactly the quantities the paper's
Table 1 / Table 5 / Figures 3 & 5 report (sparse-vs-dense MSE), rather
than white-noise QKV.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, optim
from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.core.config import StemConfig
from repro.data import SyntheticLMData
from repro.models import registry, transformer

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "results")

BENCH_ARCH = ArchConfig(
    name="bench-lm", family="dense", num_layers=6, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=512,
    qk_norm=True, dtype="float32",
)
BENCH_SEQ = 2048
BENCH_STEPS = 300

# Block/budget geometry scaled to the bench model (seq 2048, B=32 -> 64
# blocks; paper geometry B=128 over 8k-128k scales equivalently).
def bench_stem(**kw) -> StemConfig:
    base = dict(block_size=32, k_start_frac=0.25, mu=0.7, beta=0.2,
                sink_blocks=1, local_blocks=1, min_budget_blocks=2, stride=8)
    base.update(kw)
    return StemConfig(**base)


def data_stream(seq_len=BENCH_SEQ, batch=8) -> SyntheticLMData:
    return SyntheticLMData(vocab_size=BENCH_ARCH.vocab_size, seq_len=seq_len,
                           global_batch=batch, seed=42, motif_len=48)


def trained_model():
    """(cfg, params) — trained once, cached on disk."""
    cfg = BENCH_ARCH
    mgr = CheckpointManager(os.path.join(RESULTS, "bench_model"), keep=1)
    bundle = registry.build(cfg)
    abstract_values, _ = bundle.abstract_params()
    if mgr.latest_step() is not None:
        params, _ = mgr.restore(abstract_values)
        return cfg, params
    print("# training bench model (~300 steps, cached afterwards)...", flush=True)
    data = data_stream(seq_len=256, batch=16)
    params = bundle.init_params(jax.random.PRNGKey(0))
    opt_cfg = optim.AdamWConfig(peak_lr=1e-3, warmup_steps=20, decay_steps=BENCH_STEPS)
    state = optim.init_state(params, opt_cfg)

    @jax.jit
    def step(state, batch):
        def loss_of(m):
            p = jax.tree.map(lambda t: t.astype(cfg.jnp_dtype), m)
            return bundle.loss_fn(p, batch, remat=False)[0]
        loss, g = jax.value_and_grad(loss_of)(state.master)
        state, _ = optim.update(g, state, opt_cfg)
        return state, loss

    for i in range(BENCH_STEPS):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, loss = step(state, b)
        if i % 100 == 0:
            print(f"#   step {i}: loss {float(loss):.3f}", flush=True)
    params = optim.cast_params(state, params)
    mgr.save(BENCH_STEPS, params)
    return cfg, params


def eval_batch(seq_len=BENCH_SEQ, batch=4):
    d = data_stream(seq_len=seq_len, batch=batch)
    return {k: jnp.asarray(v) for k, v in d.batch_at(10_001).items()}


def head_logit_mse(cfg, params, batch, stem_cfg) -> dict:
    """Paper's 'Head Logits' loss + per-layer MSE (Table 1 quantities)."""
    dense_logits, dense_h = transformer.forward_hiddens(params, batch, cfg)
    sparse_logits, sparse_h = transformer.forward_hiddens(params, batch, cfg,
                                                          stem_cfg=stem_cfg)
    out = {"head_logits_mse": float(jnp.mean((dense_logits - sparse_logits) ** 2))}
    li = 0
    for dh, sh in zip(dense_h, sparse_h):
        for l in range(dh.shape[0]):
            out[f"L{li}"] = float(jnp.mean(
                (dh[l].astype(jnp.float32) - sh[l].astype(jnp.float32)) ** 2))
            li += 1
    return out


def timer(fn, *args, repeats=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)
