"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is 0 for pure
accuracy benchmarks).  Mapping to the paper:

  latency.py              Figure 1 (prefill latency/FLOPs vs length)
  oam_vs_sam.py           Table 1  (SAM vs OAM sparse loss)
  ablation.py             Table 5  (Uniform / +TPD / +OAM, matched budget)
  sensitivity.py          Figure 5 (mu, beta sweeps)
  position_sensitivity.py Figure 3 (loss vs sparsified position segment)
  cost_model.py           Eq. 2/4  (analytic vs measured computed pairs)
  roofline.py             EXPERIMENTS.md roofline collation (from dry-run)
  ragged_exec.py          padded vs ragged/deduped executor A/B (DESIGN.md;
                          also writes BENCH_ragged.json standalone)
  serving.py              continuous-batching engine A/Bs: stem-on vs
                          stem-off (BENCH_serving.json), chunked vs
                          monolithic prefill under a mixed workload
                          (``--chunked``, BENCH_chunked.json), and the
                          async-vs-sync engine loop (``--async``,
                          BENCH_async.json, bit-identity gated)
  policy_parity.py        named SparsityPolicy stack (stem / uniform-sam /
                          streaming) through the shared executor (writes
                          BENCH_policy.json standalone)
  prefix_cache.py         prefix-caching A/B: shared system prompt across
                          tenants, pages/TTFT with sharing on vs off
                          (writes BENCH_prefix.json standalone)
  sharding_scale.py       mesh-sharded serving: dp slot-group weak scaling
                          + mesh-vs-single-device differentials (needs 8
                          devices — skips gracefully without; writes
                          BENCH_sharded.json standalone)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (ablation, cost_model, latency, oam_vs_sam,
                            policy_parity, position_sensitivity, prefix_cache,
                            ragged_exec, roofline, sensitivity, serving,
                            sharding_scale)

    modules = [
        ("cost_model", cost_model),
        ("latency", latency),
        ("ragged_exec", ragged_exec),
        ("serving", serving),
        ("policy_parity", policy_parity),
        ("prefix_cache", prefix_cache),
        ("sharding_scale", sharding_scale),
        ("oam_vs_sam", oam_vs_sam),
        ("ablation", ablation),
        ("sensitivity", sensitivity),
        ("position_sensitivity", position_sensitivity),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us:.2f},{derived}")
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name}/ERROR,0,failed")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
