"""Paper Table 5: Uniform (SAM) -> +TPD -> +OAM at a matched total budget.

Uniform uses k_uni = k_start (1+mu)/2 (the paper's budget-matching rule),
so all three rows spend the same computed-pair budget; the orderings
Uniform >= +TPD >= +OAM (lower MSE is better) reproduce the table's
mechanism.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import schedule as sched
from repro.core.config import uniform_equivalent_budget


def _matched_uniform_k(base, n):
    """Integer k_uni whose realized (causally clamped) pair count best
    matches TPD's — the paper's k_uni ~ 0.85 k_start rule is exact only in
    the continuum; at block granularity we match measured budgets."""
    nb = n // base.block_size
    tpd = int(sched.schedule_for(base, n).sum())
    best, best_err = 1, 1e18
    for k in range(1, nb + 1):
        uni = int(np.minimum(np.full(nb, k), np.arange(1, nb + 1)).sum())
        if abs(uni - tpd) < best_err:
            best, best_err = k, abs(uni - tpd)
    return best, tpd


def run() -> list[tuple]:
    cfg, params = common.trained_model()
    batch = common.eval_batch()
    base = common.bench_stem()
    k_start = base.k_start_blocks(common.BENCH_SEQ)
    k_uni, tpd_pairs = _matched_uniform_k(base, common.BENCH_SEQ)

    variants = {
        # Uniform budget + routing-only metric (the paper's baseline row),
        # budget-matched on realized pairs (k_uni ~= 0.85 k_start rule).
        "uniform_sam": common.bench_stem(metric="sam", mu=1.0, min_budget_blocks=0,
                                         k_start_frac=k_uni / (common.BENCH_SEQ // base.block_size)),
        # + Token Position-Decay (budget-matched by construction).
        "tpd_sam": common.bench_stem(metric="sam"),
        # + Output-Aware Metric = full Stem.
        "tpd_oam": common.bench_stem(metric="oam"),
    }
    rows = []
    scores = {}
    for name, sc in variants.items():
        r = common.head_logit_mse(cfg, params, batch, sc)
        scores[name] = r["head_logits_mse"]
        rows.append((f"table5/{name}", 0.0,
                     f"head_logits={r['head_logits_mse']:.4e}"))
    import numpy as _np
    uni_pairs = int(_np.minimum(_np.full(common.BENCH_SEQ // base.block_size, k_uni),
                                _np.arange(1, common.BENCH_SEQ // base.block_size + 1)).sum())
    rows.append(("table5/budgets", 0.0,
                 f"k_start={k_start};k_uni={k_uni};tpd_pairs={tpd_pairs};"
                 f"uniform_pairs={uni_pairs}"))
    # Honest read-out: on this 6-layer model TPD is budget-neutral-to-
    # slightly-behind on all-position MSE (the paper's own Fig. 5 reports
    # mu=0.7 ~ uniform accuracy at lower cost; the Table-5 gains come from
    # 32-61-layer models where the recursive-anchor effect compounds —
    # position_sensitivity.py quantifies that mechanism directly).
    rows.append(("table5/ordering", 0.0,
                 f"uniform={scores['uniform_sam']:.3e};tpd={scores['tpd_sam']:.3e};"
                 f"stem={scores['tpd_oam']:.3e};"
                 f"tpd_delta={(scores['tpd_sam']/scores['uniform_sam']-1)*100:+.1f}%;"
                 f"oam_delta={(scores['tpd_oam']/scores['tpd_sam']-1)*100:+.1f}%"))
    return rows
