"""Mesh-sharded serving scaling benchmark (sharding/serving.py).

Two claims, measured on one host with 8 simulated devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``):

  * **Weak scaling** — dp slot groups behind one engine: a decode-heavy
    workload with requests proportional to dp must push near-linear
    aggregate throughput (acceptance: dp=4 >= 3x the dp=1 tok/s on the
    device-parallel metric below), because every group advances through
    the SAME two compiled traces in one jitted call per step.  Per-step
    host syncs (logits fetches) must not grow with the mesh — the
    scheduler stays replicated host-side and the step stays one dispatch.

    Simulated-device caveat, measured not assumed: forced host devices
    EXECUTE SERIALLY on the host's cores (one XLA CPU client), so raw
    wall-clock per step grows ~linearly with dp even though the dp shards
    exchange zero bytes (each slot group's program is independent — the
    bitwise differential against per-group single-device engines is the
    proof).  The report carries both numbers: ``tok_s_wall`` (raw, with
    the serialization baked in) and ``tok_s_device_parallel`` (per-step
    wall with the linearly-fitted per-simulated-device marginal removed —
    the critical path an actual dp-device deployment executes).  The
    acceptance ratio uses the device-parallel metric; it still fails if
    the slot-group scheduler needs extra steps per token, sheds requests,
    retraces, or adds host syncs — the failure modes this subsystem owns.

  * **Differential** — mesh shapes (2,1), (1,2), (2,2), for the XLA gather
    executor AND the fused Pallas kernels, must reproduce the single-device
    engine streams token-for-token, and (at budget_frac=1.0) the
    monolithic fixed-batch contiguous-cache decode — the engine-level and
    math-level references the serving suite pins per-path.

Standalone: ``PYTHONPATH=src python benchmarks/sharding_scale.py [--quick]
[--out BENCH_sharded.json]``.  Feeds CI's perf-trajectory artifacts; via
``benchmarks/run.py`` it degrades to skipped rows when fewer than 8
devices are visible (the harness runs without the XLA flag).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

# Standalone CLI runs always get the 8 simulated host devices; the flag
# only works before jax initializes, so it must precede the import chain
# below (benchmarks.serving pulls repro -> jax).  Library imports (e.g.
# benchmarks/run.py) leave the environment alone and degrade in run().
if __name__ == "__main__" and "jax" not in sys.modules and \
        "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

try:
    from benchmarks.serving import QUICK_ARCH, FULL_ARCH, _stem_cfg
except ModuleNotFoundError:      # standalone: benchmarks/ itself on sys.path
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.serving import QUICK_ARCH, FULL_ARCH, _stem_cfg

STEM_BUDGET = 0.25          # scaling arms: the paper-regime sparse budget
DP_POINTS = (1, 2, 4)
MIN_SCALING = 3.0           # acceptance: dp=4 >= 3x dp=1 tok/s


def _decode_heavy_trace(rng, *, n_requests, page_size, decode_tokens, vocab,
                        uid0=0):
    """Short prompts, long decodes, all arriving up front: the workload
    where throughput is decode-bound and dp groups genuinely run
    concurrently rather than queueing."""
    from repro.runtime.engine import Request
    return [Request(uid=uid0 + i,
                    prompt=rng.randint(0, vocab, size=(
                        int(rng.randint(page_size // 2, 2 * page_size)),
                    )).astype(np.int32),
                    max_new_tokens=decode_tokens)
            for i in range(n_requests)]


def _ecfg(stem_cfg, *, max_slots, max_prompt, decode_tokens, budget_frac,
          **kw):
    from repro.runtime.engine import EngineConfig
    return EngineConfig.for_trace(
        max_slots=max_slots, max_prompt=max_prompt,
        max_new_tokens=decode_tokens, page_size=stem_cfg.block_size,
        budget_frac=budget_frac, **kw)


def run_scaling_arm(bundle, params, stem_cfg, *, dp, slots_per_group,
                    decode_tokens, seed=0, mesh=True) -> dict:
    """One weak-scaling cell: requests proportional to dp, throughput and
    host-sync accounting from a timed steady-state pass."""
    from repro.runtime.engine import StemEngine

    bs = stem_cfg.block_size
    n_req = 2 * slots_per_group * dp
    ecfg = _ecfg(stem_cfg, max_slots=slots_per_group,
                 max_prompt=2 * bs, decode_tokens=decode_tokens,
                 budget_frac=STEM_BUDGET, mesh=(dp, 1) if mesh else None)
    engine = StemEngine(bundle, params, stem_cfg, ecfg)
    mk = lambda uid0: _decode_heavy_trace(
        np.random.RandomState(seed), n_requests=n_req, page_size=bs,
        decode_tokens=decode_tokens, vocab=bundle.cfg.vocab_size, uid0=uid0)

    engine.run(mk(0))                      # warmup: compiles both traces
    engine.reset_metrics()
    syncs0 = engine.stats["host_syncs"]
    calls0 = engine.stats["step_calls"]

    trace = mk(n_req)
    for r in trace:
        r.arrival_step += engine.step_count
    t0 = time.perf_counter()
    finished = engine.run(trace)
    wall = time.perf_counter() - t0
    total_tokens = sum(len(f.tokens) for f in finished)
    steps = engine.stats["step_calls"] - calls0
    return {
        "dp": dp,
        "mesh": mesh,
        "requests": len(finished),
        "total_tokens": total_tokens,
        "wall_s": wall,
        "throughput_tok_s": total_tokens / max(wall, 1e-9),
        "step_calls": steps,
        "host_syncs": engine.stats["host_syncs"] - syncs0,
        "host_syncs_per_step":
            (engine.stats["host_syncs"] - syncs0) / max(steps, 1),
        "traces": engine.stats["traces"],
        "tokens": {f.uid: f.tokens for f in finished},
    }


def _fixed_batch_tokens(bundle, params, pol, prompt, mnt):
    """Monolithic contiguous-cache reference at budget_frac=1.0 — the
    engine-vs-fixed-batch differential arm (no paging, no engine)."""
    import jax
    import jax.numpy as jnp
    from repro.launch import steps as steps_lib

    plen = len(prompt)
    bs = pol.block_size
    max_len = -(-(plen + mnt) // bs) * bs
    lp = -(-plen // bs) * bs
    toks = np.zeros((1, lp), np.int32)
    toks[0, :plen] = prompt
    prefill = jax.jit(lambda p, b, last: bundle.prefill(
        p, b, max_len=max_len, stem_cfg=pol, last_pos=last))
    serve = jax.jit(steps_lib.make_serve_step(bundle, stem_cfg=pol,
                                              budget_frac=1.0))
    logits, caches = prefill(params, {"tokens": jnp.asarray(toks)},
                             jnp.asarray([plen - 1]))
    tok = jnp.argmax(logits, axis=-1)[:, None]
    out = [int(tok[0, 0])]
    cache_lens = jnp.asarray([plen])
    for i in range(mnt - 1):
        logits, caches = serve(params, tok, caches,
                               cache_lens if i == 0 else None)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        out.append(int(tok[0, 0]))
    return out


def run_differential(bundle, params, stem_cfg, *, quick, seed=1) -> dict:
    """Mesh shapes x executors vs the single-device engine AND the
    fixed-batch decode, at budget_frac=1.0 where selection is
    content-independent — bit-equality or bust."""
    from repro.core import policy as policy_lib
    from repro.runtime.engine import StemEngine

    bs = stem_cfg.block_size
    n_req = 4
    decode_tokens = 4 if quick else 8
    mk = lambda: _decode_heavy_trace(
        np.random.RandomState(seed), n_requests=n_req, page_size=bs,
        decode_tokens=decode_tokens, vocab=bundle.cfg.vocab_size)
    ecfg = lambda **kw: _ecfg(stem_cfg, max_slots=2, max_prompt=2 * bs,
                              decode_tokens=decode_tokens, budget_frac=1.0,
                              **kw)

    ref_eng = StemEngine(bundle, params, stem_cfg, ecfg())
    ref = {f.uid: f.tokens for f in ref_eng.run(mk())}

    pol = policy_lib.as_policy(stem_cfg)
    fixed = {r.uid: _fixed_batch_tokens(bundle, params, pol, r.prompt,
                                        r.max_new_tokens)
             for r in mk()}
    assert ref == fixed, "single-device engine != fixed-batch decode"

    arms = [((2, 1), "xla"), ((1, 2), "xla"), ((2, 2), "xla"),
            ((2, 2), "pallas")]
    if not quick:
        arms += [((2, 1), "pallas"), ((1, 2), "pallas")]
    cells = []
    for mesh, executor in arms:
        eng = StemEngine(bundle, params, stem_cfg,
                         ecfg(mesh=mesh, executor=executor))
        got = {f.uid: f.tokens for f in eng.run(mk())}
        ok = got == ref
        cells.append({"mesh": list(mesh), "executor": executor,
                      "matches_single_device": ok,
                      "matches_fixed_batch": got == fixed,
                      "traces": eng.stats["traces"]})
        print(f"  differential mesh={mesh} executor={executor}: "
              f"{'OK' if ok else 'DIVERGED'}", flush=True)
        assert ok, f"mesh {mesh} ({executor}) diverged from single device"
        assert eng.stats["traces"] == 2
    return {"requests": n_req, "decode_tokens": decode_tokens,
            "engine_matches_fixed_batch": True, "cells": cells}


def run_bench(quick: bool) -> dict:
    import jax
    from repro.models import registry

    if len(jax.devices()) < 8:
        raise RuntimeError(
            "sharding_scale needs 8 devices; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")

    cfg = QUICK_ARCH if quick else FULL_ARCH
    stem_cfg = _stem_cfg(quick)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    slots_per_group = 4
    decode_tokens = 16 if quick else 32

    # Host-sync baseline: the identical dp=1 workload with no mesh at all.
    base = run_scaling_arm(bundle, params, stem_cfg, dp=1,
                           slots_per_group=slots_per_group,
                           decode_tokens=decode_tokens, mesh=False)
    print(f"  no-mesh baseline: {base['throughput_tok_s']:8.1f} tok/s, "
          f"{base['host_syncs_per_step']:.2f} syncs/step", flush=True)

    cells = []
    for dp in DP_POINTS:
        cell = run_scaling_arm(bundle, params, stem_cfg, dp=dp,
                               slots_per_group=slots_per_group,
                               decode_tokens=decode_tokens)
        print(f"  dp={dp}: {cell['requests']:>2} reqs, "
              f"{cell['throughput_tok_s']:8.1f} tok/s, "
              f"{cell['step_calls']} steps, "
              f"{cell['host_syncs_per_step']:.2f} syncs/step", flush=True)
        cells.append(cell)

    # dp=1 under the mesh must be the no-mesh streams bit-for-bit.
    assert cells[0].pop("tokens") == base.pop("tokens"), \
        "mesh (1,1) changed token streams"
    for c in cells[1:]:
        c.pop("tokens")

    # Structural scaling facts the slot-group scheduler owns: every dp
    # point serves its (proportional) workload in the SAME number of
    # engine steps with the same per-step host syncs and the same two
    # traces — dp multiplies tokens per step, not steps.
    assert all(c["traces"] == 2 for c in cells)
    step_spread = (max(c["step_calls"] for c in cells)
                   - min(c["step_calls"] for c in cells))
    assert step_spread <= 2, \
        f"slot-group scheduler step counts diverged across dp: {cells}"
    for c in cells:
        assert c["total_tokens"] == c["dp"] * cells[0]["total_tokens"] / \
            cells[0]["dp"], "weak-scaling workload not served in full"
    sync_regression = max(c["host_syncs_per_step"] for c in cells) \
        - base["host_syncs_per_step"]

    # Separate the simulated-device serialization from the per-step cost:
    # per-step wall is affine in dp (the dp shards are independent, the
    # simulator executes them back-to-back), so the linear fit's slope IS
    # the per-simulated-device marginal.  Removing it leaves the critical
    # path a real dp-device mesh executes per step.
    xs = np.asarray([c["dp"] for c in cells], np.float64)
    ys = np.asarray([c["wall_s"] / c["step_calls"] for c in cells])
    slope, intercept = np.polyfit(xs, ys, 1)
    fit_residual = float(np.max(np.abs(np.polyval([slope, intercept], xs)
                                       - ys)) / max(ys.mean(), 1e-12))
    for c in cells:
        per_step = c["wall_s"] / c["step_calls"]
        parallel = per_step - (c["dp"] - 1) * slope
        c["wall_per_step_ms"] = per_step * 1e3
        c["tok_s_device_parallel"] = (
            c["total_tokens"] / (c["step_calls"] * max(parallel, 1e-9)))
    scaling = (cells[-1]["tok_s_device_parallel"]
               / max(cells[0]["tok_s_device_parallel"], 1e-9))
    wall_scaling = (cells[-1]["throughput_tok_s"]
                    / max(cells[0]["throughput_tok_s"], 1e-9))
    print(f"  device-parallel dp4/dp1 = {scaling:.2f}x (raw wall "
          f"{wall_scaling:.2f}x; serialization "
          f"{slope * 1e3:.2f} ms/device/step, fit residual "
          f"{fit_residual:.3f})", flush=True)

    diff = run_differential(bundle, params, stem_cfg, quick=quick)

    report = {
        "benchmark": "sharding_scale",
        "mode": "quick" if quick else "full",
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "host_cores": len(os.sched_getaffinity(0)),
        "arch": cfg.name,
        "block_size": stem_cfg.block_size,
        "budget_frac": STEM_BUDGET,
        "slots_per_group": slots_per_group,
        "decode_tokens": decode_tokens,
        "no_mesh_baseline": base,
        "cells": cells,
        "dp4_vs_dp1_speedup": scaling,
        "dp4_vs_dp1_wall_speedup": wall_scaling,
        "simulated_serialization_ms_per_device_step": slope * 1e3,
        "serialization_fit_residual": fit_residual,
        "host_syncs_per_step_regression": sync_regression,
        "differential": diff,
    }
    assert scaling >= MIN_SCALING, (
        f"weak scaling dp=4 only {scaling:.2f}x dp=1 (need >= "
        f"{MIN_SCALING}x)")
    assert sync_regression <= 0, (
        f"mesh added {sync_regression:.2f} host syncs per step")
    return report


def run(quick: bool = True):
    """benchmarks/run.py entry point: one CSV row per dp point.  Without 8
    visible devices (harness runs un-flagged) degrade to a skip row rather
    than fail the whole suite."""
    import jax
    if len(jax.devices()) < 8:
        return [("sharding_scale/skipped", 0.0,
                 f"needs 8 devices, have {len(jax.devices())}")]
    report = run_bench(quick)
    rows = [("sharding_scale/no_mesh", 0.0,
             f"tok_s={report['no_mesh_baseline']['throughput_tok_s']:.1f};"
             f"syncs_step={report['no_mesh_baseline']['host_syncs_per_step']:.2f}")]
    for c in report["cells"]:
        rows.append((
            f"sharding_scale/dp{c['dp']}", 0.0,
            f"tok_s_parallel={c['tok_s_device_parallel']:.1f};"
            f"tok_s_wall={c['throughput_tok_s']:.1f};reqs={c['requests']};"
            f"syncs_step={c['host_syncs_per_step']:.2f}",
        ))
    rows.append((
        "sharding_scale/summary", 0.0,
        f"dp4_speedup={report['dp4_vs_dp1_speedup']:.2f};"
        f"dp4_wall_speedup={report['dp4_vs_dp1_wall_speedup']:.2f};"
        f"sync_regression={report['host_syncs_per_step_regression']:.2f};"
        f"differentials_ok={all(c['matches_single_device'] for c in report['differential']['cells'])}",
    ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2-layer model, shorter decodes")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    report = run_bench(args.quick)
    out = args.out or "BENCH_sharded.json"
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps({k: v for k, v in report.items()
                      if k not in ("no_mesh_baseline", "cells")}, indent=2))


if __name__ == "__main__":
    main()
