"""Policy-stack smoke benchmark: named policies through the shared executor.

Runs three registered ``SparsityPolicy`` compositions — ``stem`` (OAM x
TPD x top-k), ``uniform-sam`` (routing x uniform x top-k) and
``streaming`` (content-free x sink-local x top-k) — through the *same*
``sparse_attention`` entry point and XLA gather executor at seq=8192
(``--quick``: 1024), and reports per-policy prefill wall-clock, realized
density, and reconstruction error against the dense oracle.  The point is
the API claim, measured: swapping the policy swaps the selection rule
only; the executor, stats, and error accounting are shared.

Writes ``BENCH_policy.json`` so CI keeps a policy-coverage trajectory
across PRs (next to ``BENCH_ragged.json`` / ``BENCH_serving.json``).

Standalone: ``PYTHONPATH=src python benchmarks/policy_parity.py [--quick]``.
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_policy, sparse_attention
from repro.core.sparse_attention import dense_attention_auto

POLICY_NAMES = ("stem", "uniform-sam", "streaming")


def bench_policy(name: str, block_size: int):
    """Registered policy rescaled from paper geometry to the bench shape
    (comparable budgets: k_start 25% of blocks, small stability floors)."""
    return get_policy(name).with_updates(
        block_size=block_size, stride=16, sink_blocks=1, local_blocks=1,
        min_budget_blocks=2, k_start_frac=0.25, mu=0.5,
        ignore_missing=True)


def timer(fn, *args, repeats=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run_bench(quick: bool) -> dict:
    seq = 1024 if quick else 8192
    block = 64 if quick else 128
    b, hq, hk, d = 1, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, seq, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, hk, seq, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, hk, seq, d), jnp.bfloat16)

    dense = np.asarray(
        dense_attention_auto(q, k, v, causal=True), np.float32)

    cells = []
    for name in POLICY_NAMES:
        pol = bench_policy(name, block)
        fn = functools.partial(                 # sparse_attention is jitted
            sparse_attention, policy=pol, executor="xla", return_stats=True)
        out, stats = fn(q, k, v)
        dt = timer(lambda: fn(q, k, v))
        err = float(np.abs(np.asarray(out, np.float32) - dense).max())
        cell = {
            "policy": name,
            "us_per_call": dt * 1e6,
            "density": float(stats.density),
            "avg_budget_blocks": float(stats.avg_budget_blocks),
            "k_max": int(stats.k_max),
            "max_abs_err_vs_dense": err,
        }
        print(f"{name:>12}: {dt*1e3:8.1f} ms/call, density "
              f"{cell['density']:.3f}, max|err| {err:.3e}", flush=True)
        cells.append(cell)
    return {
        "benchmark": "policy_parity",
        "mode": "quick" if quick else "full",
        "backend": jax.default_backend(),
        "seq": seq,
        "block_size": block,
        "shape": {"batch": b, "q_heads": hq, "kv_heads": hk, "head_dim": d},
        "cells": cells,
    }


def run(quick: bool = True):
    """benchmarks/run.py entry point: one CSV row per policy."""
    report = run_bench(quick)
    return [(
        f"policy_parity/{c['policy']}",
        c["us_per_call"],
        f"density={c['density']:.3f};err={c['max_abs_err_vs_dense']:.2e}",
    ) for c in report["cells"]]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: seq=1024, block=64")
    ap.add_argument("--out", default="BENCH_policy.json")
    args = ap.parse_args()

    report = run_bench(args.quick)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
