"""Paper Figure 5: mu and beta sensitivity sweeps.

Expected shapes: accuracy (here: -MSE) improves with mu toward ~0.7 then
saturates; beta has a unimodal optimum near 0.2 with beta=0 (pure SAM)
strictly worse.
"""
from __future__ import annotations

from benchmarks import common


def run() -> list[tuple]:
    cfg, params = common.trained_model()
    batch = common.eval_batch()
    rows = []
    for mu in (0.5, 0.6, 0.7, 0.8, 0.9, 1.0):
        r = common.head_logit_mse(cfg, params, batch, common.bench_stem(mu=mu))
        rows.append((f"fig5/mu_{mu}", 0.0, f"head_logits={r['head_logits_mse']:.4e}"))
    for beta in (0.0, 0.1, 0.2, 0.3, 0.5):
        r = common.head_logit_mse(cfg, params, batch, common.bench_stem(beta=beta))
        rows.append((f"fig5/beta_{beta}", 0.0, f"head_logits={r['head_logits_mse']:.4e}"))
    return rows
