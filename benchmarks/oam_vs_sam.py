"""Paper Table 1: SAM vs OAM sparse loss at a fixed budget.

Measures per-layer residual MSE and head-logit MSE on the trained bench
model — the same quantities (L5/L15/... + Head Logits) the paper reports,
expecting OAM <= SAM.
"""
from __future__ import annotations

from benchmarks import common


def run() -> list[tuple]:
    cfg, params = common.trained_model()
    batch = common.eval_batch()
    rows = []
    results = {}
    for metric in ("sam", "oam"):
        stem_cfg = common.bench_stem(metric=metric)
        r = common.head_logit_mse(cfg, params, batch, stem_cfg)
        results[metric] = r
        per_layer = ";".join(f"L{i}={r[f'L{i}']:.3e}" for i in range(cfg.num_layers))
        rows.append((f"table1/{metric}", 0.0,
                     f"head_logits={r['head_logits_mse']:.4e};{per_layer}"))
    ratio = results["oam"]["head_logits_mse"] / max(results["sam"]["head_logits_mse"], 1e-30)
    rows.append(("table1/oam_over_sam", 0.0,
                 f"ratio={ratio:.4f};oam_wins_or_ties={ratio <= 1.01}"))
    rows.extend(_structured_mechanism())
    return rows


def _structured_mechanism() -> list[tuple]:
    """Controlled demonstration of the OAM mechanism in its designed-for
    regime (Eq. 5): blocks with *comparable routing scores* but different
    value magnitudes.  SAM cannot distinguish them (random tie-breaks);
    OAM keeps the blocks whose omission actually moves the output.
    Note the converse also holds (and the ablation's beta sweep shows it):
    when routing is informative and ||V|| anti-correlates with it, a large
    beta hurts — that's the paper's own 'excessive magnitude weight
    introduces noise' caveat."""
    import jax
    import jax.numpy as jnp

    from repro.core import StemConfig, dense_attention, stem_attention

    B, H, N, D = 2, 4, 2048, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    q = jax.random.normal(ks[0], (B, H, N, D))
    k = jax.random.normal(ks[1], (B, H, N, D)) * 0.2
    v = jax.random.normal(ks[2], (B, H, N, D)) * 0.2
    # 20 candidate tokens with EQUAL high routing (aligned with q); half
    # carry large values, half near-zero values.  SAM cannot rank within the
    # tie and drops consequential blocks at random; OAM keeps the big-||V||
    # half, whose omission is what actually moves the output.
    cand = jnp.arange(40, N, 100)[:20]
    big, small = cand[0::2], cand[1::2]
    k = k.at[:, :, cand].set(q.mean(axis=2, keepdims=True)[:, :, 0][:, :, None] * 1.5
                             + 0.05 * jax.random.normal(ks[3], (B, H, 20, D)))
    v = v.at[:, :, big].set(jax.random.normal(ks[4], (B, H, len(big), D)) * 4.0)
    v = v.at[:, :, small].set(0.01)
    dense = dense_attention(q, k, v)

    # Eq. 5 objective — the paper's own selection criterion: the
    # non-renormalized truncation error || sum_{j not in S} P_ij V_j ||.
    # (Appendix A.1 derives OAM from exactly this surrogate, explicitly
    # "without renormalizing probabilities".)
    from repro.core.selection import block_mask_to_token_mask
    from repro.core.sparse_attention import select_for

    scale = D ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    causal = jnp.tril(jnp.ones((N, N), bool))
    p = jax.nn.softmax(jnp.where(causal, s, -1e30), axis=-1)

    out = []
    trunc, renorm = {}, {}
    for metric in ("sam", "oam"):
        sc = common.bench_stem(metric=metric, k_start_frac=0.15, mu=1.0,
                               min_budget_blocks=1)
        sel, _ = select_for(q, k, v, sc)
        tok = block_mask_to_token_mask(sel.block_mask, sc.block_size,
                                       sc.block_size, N, N)
        dropped = jnp.einsum("bhqk,bhkd->bhqd", jnp.where(tok, 0.0, p), v)
        trunc[metric] = float(jnp.mean(jnp.linalg.norm(dropped, axis=-1)))
        o = stem_attention(q, k, v, sc)
        renorm[metric] = float(jnp.mean((o - dense) ** 2))
        out.append((f"table1/structured_{metric}", 0.0,
                    f"eq5_truncation={trunc[metric]:.4e};renormalized_mse={renorm[metric]:.4e}"))
    out.append(("table1/structured_gap", 0.0,
                f"eq5_oam/sam={trunc['oam']/trunc['sam']:.3f};"
                f"oam_wins_eq5={trunc['oam'] < trunc['sam']};"
                f"renorm_oam/sam={renorm['oam']/renorm['sam']:.3f}"))
    # Finding worth recording: under the *renormalized* softmax that real
    # sparse executors use, magnitude-led selection can over-weight the kept
    # high-energy blocks when dropped probability mass is large — Eq. 5's
    # surrogate ignores renormalization.  On trained models (where routing
    # concentrates and ||V|| correlates with importance) OAM still wins the
    # end-to-end comparison above.
    return out
