"""Serving A/B benchmarks over the continuous-batching engine.

Two studies, both on the paged Stem KV cache (``runtime/engine.py``):

  1. **stem-on vs stem-off** (``BENCH_serving.json``) — mixed-length,
     staggered-arrival trace at batch (max_slots) {4, 16}; end-to-end
     tokens/sec plus the serving-latency triple measured *separately*:
     TTFT (admission -> first token), TPOT (mean per-output-token time
     after the first), and inter-token p50/p95 (gaps as experienced by a
     request — these surface head-of-line stalls, unlike the old
     batched-step wall time).  The comparison isolates what OAM page
     selection buys at serving time.

  2. **chunked vs monolithic prefill** (``--chunked``,
     ``BENCH_chunked.json``) — a mixed workload where long prompts arrive
     *mid-decode*: short requests stream tokens while long prompts land.
     The monolithic arm prefills each long prompt in one admission pass
     (stalling every in-flight decode and retracing per prompt length);
     the chunked arm advances ``chunk_size`` tokens per unified step under
     the engine's token budget.  Reported per arm: decode-victim
     inter-token p95 (the HOL-blocking signature), long-prompt TTFT, and
     trace counts.  The chunked arm should show strictly lower p95 with
     TTFT within 2x.

  3. **FCFS vs SLO scheduler under overload** (``--slo``,
     ``BENCH_slo.json``) — arrival exceeds capacity (step token budget
     below the decode-saturated demand) while a few high-priority
     interactive requests with tight SLOs land mid-flight.  The FCFS arm
     defers their decode tokens behind the whole backlog; the SLO arm
     grants priority + SLO-headroom first and preempts low-priority
     residents (host page offload) at admission.  Headline: HP p99 decode
     latency, strictly better under the SLO scheduler.  ``--chaos`` runs
     the SLO arm under fault injection (alloc denial, step failure,
     restore failure) — the resilience configuration CI exercises.

  4. **sync vs async engine loop** (``--async``, ``BENCH_async.json``) —
     the same engine under ``async_depth`` 0 vs 1: the sync arm fetches
     full logits and blocks the host every step; the async arm samples
     on device, transfers only ``(slots,) int32`` ids, and dispatches
     step N+1 while step N's ids are in flight.  Streams are asserted
     bit-identical in-bench; reported per arm: decode tok/s, blocking
     host syncs per token (O(steps) -> O(finished requests)), and the
     host dispatch / sync-wait time split.

Standalone: ``PYTHONPATH=src python benchmarks/serving.py [--quick]
[--chunked] [--slo [--chaos]] [--async]``.  All reports feed CI's
perf-trajectory artifacts.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.config import StemConfig

QUICK_ARCH = ArchConfig(
    name="serve-bench-quick", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    qk_norm=True, dtype="float32",
)
FULL_ARCH = ArchConfig(
    name="serve-bench", family="dense", num_layers=6, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=512,
    qk_norm=True, dtype="float32",
)

STEM_BUDGET = 0.25          # the stem-on arm's budget_frac


def _stem_cfg(quick: bool) -> StemConfig:
    return StemConfig(block_size=16 if quick else 32, sink_blocks=1,
                      local_blocks=1, min_budget_blocks=2,
                      stride=4 if quick else 8)


def run_arm(bundle, params, stem_cfg: StemConfig, *, max_slots: int,
            budget_frac: float, min_prompt: int, max_prompt: int,
            decode_tokens: int, seed: int = 0) -> dict:
    """One (batch size, budget) cell: fresh engine, fresh trace, timed run."""
    from repro.launch.serve import _latency_stats, build_trace
    from repro.runtime.engine import EngineConfig, StemEngine

    ecfg = EngineConfig.for_trace(
        max_slots=max_slots, max_prompt=max_prompt,
        max_new_tokens=decode_tokens, page_size=stem_cfg.block_size,
        budget_frac=budget_frac)
    engine = StemEngine(bundle, params, stem_cfg, ecfg)
    mk_trace = lambda: build_trace(
        np.random.RandomState(seed), 2 * max_slots, min_prompt, max_prompt,
        decode_tokens, bundle.cfg.vocab_size, arrival_every=1)

    # Warmup pass with an identical trace: compiles the unified step, so
    # the timed pass below measures steady-state serving, not XLA
    # compilation.
    engine.run(mk_trace())
    engine.reset_metrics()

    trace = mk_trace()
    for r in trace:                 # preserve the staggered arrival pattern
        r.arrival_step += engine.step_count
    t0 = time.perf_counter()
    finished = engine.run(trace)
    wall = time.perf_counter() - t0
    total_tokens = sum(len(f.tokens) for f in finished)
    return {
        "max_slots": max_slots,
        "budget_frac": budget_frac,
        "requests": len(finished),
        "total_tokens": total_tokens,
        "wall_s": wall,
        "throughput_tok_s": total_tokens / max(wall, 1e-9),
        "max_concurrency": engine.stats["max_concurrency"],
        "slots_reused": engine.stats["slots_reused"],
        "traces": engine.stats["traces"],
        **_latency_stats(finished),
    }


def run_bench(quick: bool) -> dict:
    import jax
    from repro.models import registry

    cfg = QUICK_ARCH if quick else FULL_ARCH
    stem_cfg = _stem_cfg(quick)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    min_prompt, max_prompt = (24, 96) if quick else (64, 384)
    decode_tokens = 8 if quick else 32

    cells = []
    for max_slots in (4, 16):
        for budget_frac in (1.0, STEM_BUDGET):      # stem-off, stem-on
            cell = run_arm(bundle, params, stem_cfg, max_slots=max_slots,
                           budget_frac=budget_frac, min_prompt=min_prompt,
                           max_prompt=max_prompt, decode_tokens=decode_tokens)
            arm = "dense" if budget_frac == 1.0 else "stem"
            print(f"slots={max_slots:>2} {arm:>5}: "
                  f"{cell['throughput_tok_s']:8.1f} tok/s, inter-token "
                  f"p50 {cell['p50_ms']:.2f} / p95 {cell['p95_ms']:.2f} ms, "
                  f"TTFT {cell['ttft_ms_mean']:.1f} ms, "
                  f"TPOT {cell['tpot_ms_mean']:.2f} ms", flush=True)
            cells.append(cell)
    return {
        "benchmark": "serving",
        "mode": "quick" if quick else "full",
        "backend": jax.default_backend(),
        "arch": cfg.name,
        "block_size": stem_cfg.block_size,
        "stem_budget_frac": STEM_BUDGET,
        "decode_tokens": decode_tokens,
        "prompt_range": [min_prompt, max_prompt],
        "cells": cells,
    }


# ---------------------------------------------------------------------------
# Chunked vs monolithic prefill under a mixed workload (BENCH_chunked.json)
# ---------------------------------------------------------------------------

def build_mixed_workload(rng, *, n_short: int, short_prompt: tuple,
                         short_decode: int, n_long: int, long_prompt: int,
                         long_decode: int, long_arrival0: int,
                         long_every: int, vocab: int):
    """Short requests decoding steadily from step 0; long prompts landing
    mid-decode — the head-of-line-blocking scenario chunked prefill fixes."""
    from repro.runtime.engine import Request

    reqs = []
    for i in range(n_short):
        plen = int(rng.randint(short_prompt[0], short_prompt[1] + 1))
        reqs.append(Request(
            uid=i, prompt=rng.randint(0, vocab, size=(plen,)).astype(np.int32),
            max_new_tokens=short_decode, arrival_step=0))
    for j in range(n_long):
        reqs.append(Request(
            uid=n_short + j,
            prompt=rng.randint(0, vocab, size=(long_prompt,)).astype(np.int32),
            max_new_tokens=long_decode,
            arrival_step=long_arrival0 + j * long_every))
    return reqs


def run_chunked_arm(bundle, params, stem_cfg, *, monolithic: bool,
                    chunk_size: int, max_slots: int, workload_kw: dict,
                    seed: int = 0) -> dict:
    from repro.runtime.engine import EngineConfig, StemEngine

    long_prompt = workload_kw["long_prompt"]
    decode_max = max(workload_kw["short_decode"], workload_kw["long_decode"])
    ecfg = EngineConfig.for_trace(
        max_slots=max_slots, max_prompt=long_prompt,
        max_new_tokens=decode_max, page_size=stem_cfg.block_size,
        budget_frac=STEM_BUDGET, chunk_size=chunk_size,
        monolithic_prefill=monolithic)
    engine = StemEngine(bundle, params, stem_cfg, ecfg)
    vocab = bundle.cfg.vocab_size
    mk = lambda: build_mixed_workload(np.random.RandomState(seed),
                                      vocab=vocab, **workload_kw)

    engine.run(mk())            # warmup: compile every trace this arm needs
    engine.reset_metrics()
    trace = mk()
    for r in trace:
        r.arrival_step += engine.step_count
    t0 = time.perf_counter()
    finished = engine.run(trace)
    wall = time.perf_counter() - t0

    n_short = workload_kw["n_short"]
    short = [f for f in finished if f.uid < n_short]
    long_ = [f for f in finished if f.uid >= n_short]
    victim_lats = np.asarray([t for f in short for t in f.token_latencies_s])
    total_tokens = sum(len(f.tokens) for f in finished)
    return {
        "arm": "monolithic" if monolithic else "chunked",
        "chunk_size": None if monolithic else engine.chunk_size,
        "requests": len(finished),
        "total_tokens": total_tokens,
        "wall_s": wall,
        "throughput_tok_s": total_tokens / max(wall, 1e-9),
        "decode_p50_ms": float(np.percentile(victim_lats, 50) * 1e3),
        "decode_p95_ms": float(np.percentile(victim_lats, 95) * 1e3),
        "decode_max_ms": float(victim_lats.max() * 1e3),
        "long_ttft_ms_mean": float(np.mean([f.ttft_s for f in long_]) * 1e3),
        "long_ttft_ms_p95": float(np.percentile(
            [f.ttft_s for f in long_], 95) * 1e3),
        "tpot_ms_mean": float(np.nanmean([f.tpot_s for f in finished]) * 1e3),
        "traces": engine.stats["traces"],
        "prefill_traces": engine.stats["prefill_traces"],
        "chunks": engine.stats["chunks"],
    }


def run_chunked_bench(quick: bool) -> dict:
    import jax
    from repro.models import registry

    cfg = QUICK_ARCH if quick else FULL_ARCH
    stem_cfg = _stem_cfg(quick)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    bs = stem_cfg.block_size
    max_slots = 4
    workloads = {
        # Sized so the head-of-line stalls register in the p95: each long
        # arrival lands amid short decode streams whose total gap count
        # keeps the stall steps above the 95th percentile.
        "mixed": dict(
            chunk_size=12 * bs,
            workload_kw=dict(
                n_short=3,
                short_prompt=(bs, 3 * bs),
                short_decode=16 if quick else 24,
                n_long=4,
                long_prompt=24 * bs,
                long_decode=4,
                long_arrival0=3,
                long_every=5,
            )),
        # Long-context cell (seq >= 8k in full mode): prompts long enough
        # that a monolithic prefill stalls the decode lane for many steps,
        # while the chunk lane decodes every unified step — the regime the
        # fused paged kernels target.  Quick mode shrinks the shape (same
        # code path) to stay a CI smoke.
        "longctx": dict(
            chunk_size=(8 * bs) if quick else 64 * bs,
            workload_kw=dict(
                n_short=3,
                short_prompt=(bs, 3 * bs),
                short_decode=24 if quick else 96,
                n_long=1 if quick else 2,
                long_prompt=(32 * bs) if quick else 8192,
                long_decode=4,
                long_arrival0=3,
                long_every=8,
            )),
    }

    cells = []
    ratios = {}
    for wl_name, wl in workloads.items():
        arm_cells = []
        for monolithic in (False, True):
            cell = run_chunked_arm(bundle, params, stem_cfg,
                                   monolithic=monolithic,
                                   chunk_size=wl["chunk_size"],
                                   max_slots=max_slots,
                                   workload_kw=wl["workload_kw"])
            cell["workload"] = wl_name
            print(f"{wl_name:>8}/{cell['arm']:>10}: decode p50 "
                  f"{cell['decode_p50_ms']:.2f} / p95 "
                  f"{cell['decode_p95_ms']:.2f} / max "
                  f"{cell['decode_max_ms']:.2f} ms; long TTFT "
                  f"{cell['long_ttft_ms_mean']:.1f} ms; "
                  f"{cell['throughput_tok_s']:.1f} tok/s; traces "
                  f"{cell['traces']}+{cell['prefill_traces']} prefill",
                  flush=True)
            arm_cells.append(cell)
        chunked, mono = arm_cells
        ratios[wl_name] = {
            "p95_speedup_vs_monolithic":
                mono["decode_p95_ms"] / max(chunked["decode_p95_ms"], 1e-9),
            "ttft_ratio_vs_monolithic":
                chunked["long_ttft_ms_mean"]
                / max(mono["long_ttft_ms_mean"], 1e-9),
            "throughput_ratio_vs_monolithic":
                chunked["throughput_tok_s"]
                / max(mono["throughput_tok_s"], 1e-9),
        }
        cells.extend(arm_cells)
    return {
        "benchmark": "serving_chunked",
        "mode": "quick" if quick else "full",
        "backend": jax.default_backend(),
        "arch": cfg.name,
        "block_size": bs,
        "budget_frac": STEM_BUDGET,
        "workloads": {
            name: {"chunk_size": wl["chunk_size"],
                   **{k: (list(v) if isinstance(v, tuple) else v)
                      for k, v in wl["workload_kw"].items()}}
            for name, wl in workloads.items()},
        "cells": cells,
        "ratios": ratios,
        # kept for trajectory continuity with pre-longctx reports
        "p95_speedup_vs_monolithic":
            ratios["mixed"]["p95_speedup_vs_monolithic"],
        "ttft_ratio_vs_monolithic":
            ratios["mixed"]["ttft_ratio_vs_monolithic"],
    }


# ---------------------------------------------------------------------------
# Overload study: SLO scheduler + preemption vs FCFS (BENCH_slo.json)
# ---------------------------------------------------------------------------

def build_overload_workload(rng, *, n_lp: int, n_hp: int, lp_prompt: tuple,
                            hp_prompt: tuple, lp_decode: int, hp_decode: int,
                            hp_arrival0: int, hp_every: int,
                            hp_tpot_slo_s: float, hp_ttft_slo_s: float,
                            vocab: int):
    """Arrival > capacity: a steady stream of low-priority requests saturates
    the slots and the step token budget; a few high-priority interactive
    requests with tight SLOs land mid-flight.  Under FCFS the late HP
    arrivals queue behind everything; the SLO scheduler preempts for them
    at admission and grants their decode tokens first."""
    from repro.runtime.engine import Request

    reqs = []
    for i in range(n_lp):
        plen = int(rng.randint(lp_prompt[0], lp_prompt[1] + 1))
        reqs.append(Request(
            uid=i, prompt=rng.randint(0, vocab, size=(plen,)).astype(np.int32),
            max_new_tokens=lp_decode, arrival_step=i, priority=0))
    for j in range(n_hp):
        plen = int(rng.randint(hp_prompt[0], hp_prompt[1] + 1))
        reqs.append(Request(
            uid=n_lp + j,
            prompt=rng.randint(0, vocab, size=(plen,)).astype(np.int32),
            max_new_tokens=hp_decode,
            arrival_step=hp_arrival0 + j * hp_every, priority=1,
            tpot_slo_s=hp_tpot_slo_s, ttft_slo_s=hp_ttft_slo_s))
    return reqs


def run_slo_arm(bundle, params, stem_cfg, *, scheduler: str, max_slots: int,
                step_token_budget: int, workload_kw: dict,
                chaos: bool = False, seed: int = 0) -> dict:
    from repro.runtime.engine import EngineConfig, StemEngine

    max_prompt = max(workload_kw["lp_prompt"][1], workload_kw["hp_prompt"][1])
    decode_max = max(workload_kw["lp_decode"], workload_kw["hp_decode"])
    ecfg = EngineConfig.for_trace(
        max_slots=max_slots, max_prompt=max_prompt,
        max_new_tokens=decode_max, page_size=stem_cfg.block_size,
        budget_frac=STEM_BUDGET, step_token_budget=step_token_budget,
        scheduler=scheduler)
    injector = None
    if chaos:
        from repro.runtime.chaos import ChaosConfig, ChaosInjector
        injector = ChaosInjector(ChaosConfig(
            deny_alloc_steps=(3,), fail_steps=(5,), fail_restore_steps=(11,)))
    engine = StemEngine(bundle, params, stem_cfg, ecfg, chaos=injector)
    vocab = bundle.cfg.vocab_size
    mk = lambda: build_overload_workload(np.random.RandomState(seed),
                                         vocab=vocab, **workload_kw)

    # Warmup on a twin engine with the identical workload (same scheduler,
    # so the SLO twin also compiles the preempt extract/restore jits), then
    # share every compiled step — the timed run below measures scheduling,
    # not XLA compilation, and chaos steps stay in engine-step coordinates.
    warm = StemEngine(bundle, params, stem_cfg, ecfg)
    warm.run(mk())
    engine._unified = warm._unified
    engine._reset = warm._reset
    engine._extract = warm._extract
    engine._restore_pages = warm._restore_pages
    engine.stats["traces"] = warm.stats["traces"]

    trace = mk()
    t0 = time.perf_counter()
    finished = engine.run(trace)
    wall = time.perf_counter() - t0

    n_lp = workload_kw["n_lp"]
    ok = [f for f in finished if f.error is None]
    hp = [f for f in ok if f.uid >= n_lp]
    lp = [f for f in ok if f.uid < n_lp]
    hp_lats = np.asarray([t for f in hp for t in f.token_latencies_s])
    lp_lats = np.asarray([t for f in lp for t in f.token_latencies_s])
    total_tokens = sum(len(f.tokens) for f in finished)
    s = engine.stats
    return {
        "arm": scheduler + ("+chaos" if chaos else ""),
        "scheduler": scheduler,
        "chaos": chaos,
        "requests": len(finished),
        "failed": sum(f.error is not None for f in finished),
        "total_tokens": total_tokens,
        "wall_s": wall,
        "throughput_tok_s": total_tokens / max(wall, 1e-9),
        "hp_decode_p50_ms": float(np.percentile(hp_lats, 50) * 1e3),
        "hp_decode_p99_ms": float(np.percentile(hp_lats, 99) * 1e3),
        "hp_ttft_ms_mean": float(np.mean([f.ttft_s for f in hp]) * 1e3),
        "hp_ttft_ms_max": float(np.max([f.ttft_s for f in hp]) * 1e3),
        "lp_decode_p99_ms": (float(np.percentile(lp_lats, 99) * 1e3)
                             if lp_lats.size else 0.0),
        "preemptions": s["preemptions"],
        "restores": s["restores"],
        "decode_deferrals": s["decode_deferrals"],
        "chunk_caps": s["chunk_caps"],
        "starvation_grants": s["starvation_grants"],
        "step_failures": s["step_failures"],
        "restore_failures": s["restore_failures"],
        "alloc_denials": s["alloc_denials"],
        "aborts": s["aborts"],
        "offload_peak_bytes": engine.metrics["offload_peak_bytes"],
        "traces": s["traces"],
    }


def run_slo_bench(quick: bool, chaos: bool = False) -> dict:
    """Overload A/B: FCFS baseline vs the SLO scheduler (+ optional chaos
    configuration on the SLO arm — CI's resilience gate).  The headline
    number is high-priority p99 decode latency: the SLO arm must beat FCFS
    strictly, since FCFS defers late arrivals' decode tokens behind the
    whole saturated budget while the SLO arm grants them first and preempts
    low-priority residents at admission."""
    import jax
    from repro.models import registry

    cfg = QUICK_ARCH if quick else FULL_ARCH
    stem_cfg = _stem_cfg(quick)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    bs = stem_cfg.block_size
    max_slots = 4
    # Budget below the decode-saturated demand (4 active decodes) so the
    # scheduler must choose whom to defer every step — the overload regime.
    step_token_budget = 3
    workload_kw = dict(
        n_lp=10 if quick else 12,
        n_hp=3,
        lp_prompt=(bs, 2 * bs),
        hp_prompt=(bs, 2 * bs),
        lp_decode=16 if quick else 24,
        hp_decode=12 if quick else 16,
        hp_arrival0=8,
        hp_every=6,
        hp_tpot_slo_s=0.05,
        hp_ttft_slo_s=0.5,
    )

    cells = []
    for scheduler, arm_chaos in (("fcfs", False), ("slo", chaos)):
        cell = run_slo_arm(bundle, params, stem_cfg, scheduler=scheduler,
                           max_slots=max_slots,
                           step_token_budget=step_token_budget,
                           workload_kw=workload_kw, chaos=arm_chaos)
        print(f"{cell['arm']:>10}: HP decode p50 {cell['hp_decode_p50_ms']:.2f}"
              f" / p99 {cell['hp_decode_p99_ms']:.2f} ms, HP TTFT "
              f"{cell['hp_ttft_ms_mean']:.1f} ms (max "
              f"{cell['hp_ttft_ms_max']:.1f}); LP p99 "
              f"{cell['lp_decode_p99_ms']:.2f} ms; preempt "
              f"{cell['preemptions']}, deferrals {cell['decode_deferrals']}, "
              f"{cell['throughput_tok_s']:.1f} tok/s", flush=True)
        cells.append(cell)
    fcfs, slo = cells
    return {
        "benchmark": "serving_slo",
        "mode": "quick" if quick else "full",
        "chaos": chaos,
        "backend": jax.default_backend(),
        "arch": cfg.name,
        "block_size": bs,
        "budget_frac": STEM_BUDGET,
        "max_slots": max_slots,
        "step_token_budget": step_token_budget,
        "workload": {k: (list(v) if isinstance(v, tuple) else v)
                     for k, v in workload_kw.items()},
        "cells": cells,
        "hp_p99_speedup_vs_fcfs":
            fcfs["hp_decode_p99_ms"] / max(slo["hp_decode_p99_ms"], 1e-9),
        "hp_ttft_speedup_vs_fcfs":
            fcfs["hp_ttft_ms_mean"] / max(slo["hp_ttft_ms_mean"], 1e-9),
    }


# ---------------------------------------------------------------------------
# Async vs sync engine loop (BENCH_async.json)
# ---------------------------------------------------------------------------

def run_async_arm(bundle, params, stem_cfg, *, async_depth: int,
                  max_slots: int, min_prompt: int, max_prompt: int,
                  decode_tokens: int, seed: int = 0, reps: int = 3):
    """One loop arm (sync oracle / async pipeline) over the same staggered
    trace.  Runs the timed trace ``reps`` times and keeps the fastest —
    single-core hosts jitter enough run-to-run to swamp the loop delta
    otherwise.  Returns the metrics cell plus the full token streams so
    the caller can assert the two arms are bit-identical — the A/B is
    invalid if the async pipeline changed a single token."""
    from repro.launch.serve import _latency_stats, build_trace
    from repro.runtime.engine import EngineConfig, StemEngine

    ecfg = EngineConfig.for_trace(
        max_slots=max_slots, max_prompt=max_prompt,
        max_new_tokens=decode_tokens, page_size=stem_cfg.block_size,
        budget_frac=STEM_BUDGET, async_depth=async_depth)
    engine = StemEngine(bundle, params, stem_cfg, ecfg)
    mk_trace = lambda: build_trace(
        np.random.RandomState(seed), 2 * max_slots, min_prompt, max_prompt,
        decode_tokens, bundle.cfg.vocab_size, arrival_every=1)

    engine.run(mk_trace())          # warmup: compile both unified traces
    wall, finished, s = None, None, None
    for _ in range(reps):
        engine.reset_metrics()
        trace = mk_trace()
        for r in trace:
            r.arrival_step += engine.step_count
        t0 = time.perf_counter()
        fin = engine.run(trace)
        w = time.perf_counter() - t0
        if wall is None or w < wall:
            wall, finished, s = w, fin, dict(engine.stats)
    total_tokens = sum(len(f.tokens) for f in finished)
    decode_tok = s["tokens_generated"]
    # The transfer the pipeline eliminates: the sync loop fetches full
    # (slots, vocab) float32 logits every step; the async loop fetches
    # (slots,) int32 ids — vocab-independent.
    T = engine.total_slots
    fetch_bytes = (T * 4 if async_depth
                   else T * bundle.cfg.vocab_size * 4)
    cell = {
        "arm": "async" if async_depth else "sync",
        "async_depth": async_depth,
        "fetch_bytes_per_step": fetch_bytes,
        "requests": len(finished),
        "total_tokens": total_tokens,
        "wall_s": wall,
        "throughput_tok_s": total_tokens / max(wall, 1e-9),
        "decode_tok_s": decode_tok / max(wall, 1e-9),
        "host_syncs": s["host_syncs"],
        "host_syncs_per_token": s["host_syncs"] / max(decode_tok, 1),
        "id_fetches": s["id_fetches"],
        "lookahead_discards": s["lookahead_discards"],
        "dispatch_s": s["dispatch_s"],
        "sync_wait_s": s["sync_wait_s"],
        "traces": s["traces"],
        **_latency_stats(finished),
    }
    return cell, {f.uid: list(f.tokens) for f in finished}


def run_async_bench(quick: bool) -> dict:
    """Engine-loop A/B: the synchronous oracle (host argmax over fetched
    logits, one blocking sync per step) vs the async pipeline (on-device
    sampling, token-id-only transfers, one-step-lookahead dispatch).  Two
    workloads: *decode-heavy* (short prompts, long decode — every step
    pays the host sync, the regime the pipeline targets) and *mixed*
    (the standard staggered trace).  Both arms must produce bit-identical
    streams; the headline is the decode-heavy decode-throughput ratio and
    the host-sync collapse from O(steps) to O(finished requests).

    Reading the speedup honestly: the wall-clock win comes from
    overlapping host work with device compute and from not moving /
    host-sampling a (slots, vocab) logits tensor per step.  On a
    multi-core host driving an accelerator both effects are real
    (target: >= 1.2x decode tok/s).  On a single-core CPU host neither
    exists — host and 'device' time-slice one core and the logits fetch
    is a zero-copy view — so wall-clock lands at parity-to-modest
    (~1.0-1.1x) and the structural metrics (host syncs per token, fetch
    bytes per step) carry the comparison; ``speedup_target_met`` records
    which regime produced the committed report."""
    import jax
    from repro.models import registry

    cfg = QUICK_ARCH if quick else FULL_ARCH
    stem_cfg = _stem_cfg(quick)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    bs = stem_cfg.block_size
    workloads = {
        "decode_heavy": dict(max_slots=4, min_prompt=bs, max_prompt=2 * bs,
                             decode_tokens=32 if quick else 160),
        "mixed": dict(max_slots=4, min_prompt=24 if quick else 64,
                      max_prompt=96 if quick else 384,
                      decode_tokens=8 if quick else 32),
    }

    cells = []
    speedups = {}
    for wname, kw in workloads.items():
        arms = {}
        for depth in (0, 1):
            cell, tokens = run_async_arm(bundle, params, stem_cfg,
                                         async_depth=depth, **kw)
            cell["workload"] = wname
            arms[cell["arm"]] = (cell, tokens)
            cells.append(cell)
            print(f"{wname:>12}/{cell['arm']:>5}: "
                  f"{cell['decode_tok_s']:8.1f} decode tok/s, "
                  f"host syncs {cell['host_syncs']:>4} "
                  f"({cell['host_syncs_per_token']:.3f}/tok), "
                  f"dispatch {cell['dispatch_s']:.2f}s "
                  f"wait {cell['sync_wait_s']:.2f}s", flush=True)
        assert arms["sync"][1] == arms["async"][1], (
            f"{wname}: async streams diverged from the sync oracle")
        speedups[wname] = (arms["async"][0]["decode_tok_s"]
                           / max(arms["sync"][0]["decode_tok_s"], 1e-9))
        print(f"{wname:>12}: bit-identical, async speedup "
              f"{speedups[wname]:.2f}x", flush=True)
    import os
    return {
        "benchmark": "serving_async",
        "mode": "quick" if quick else "full",
        "backend": jax.default_backend(),
        "host_cores": os.cpu_count(),
        "arch": cfg.name,
        "block_size": bs,
        "budget_frac": STEM_BUDGET,
        "workloads": {k: dict(v) for k, v in workloads.items()},
        "bit_identical": True,
        "cells": cells,
        "async_decode_speedup": speedups,
        "speedup_target": 1.2,
        "speedup_target_met": speedups["decode_heavy"] >= 1.2,
    }


def run(quick: bool = True):
    """benchmarks/run.py entry point: CSV rows per cell (both studies)."""
    rows = []
    report = run_bench(quick)
    for c in report["cells"]:
        arm = "dense" if c["budget_frac"] == 1.0 else "stem"
        rows.append((
            f"serving/slots{c['max_slots']}/{arm}",
            c["p50_ms"] * 1e3,
            f"tok_s={c['throughput_tok_s']:.1f};p95_ms={c['p95_ms']:.2f};"
            f"ttft_ms={c['ttft_ms_mean']:.1f};tpot_ms={c['tpot_ms_mean']:.2f}",
        ))
    chunked = run_chunked_bench(quick)
    for c in chunked["cells"]:
        rows.append((
            f"serving/chunked/{c.get('workload', 'mixed')}/{c['arm']}",
            c["decode_p50_ms"] * 1e3,
            f"p95_ms={c['decode_p95_ms']:.2f};"
            f"ttft_ms={c['long_ttft_ms_mean']:.1f};"
            f"traces={c['traces']}+{c['prefill_traces']}",
        ))
    slo = run_slo_bench(quick)
    for c in slo["cells"]:
        rows.append((
            f"serving/slo/{c['arm']}",
            c["hp_decode_p99_ms"] * 1e3,
            f"hp_ttft_ms={c['hp_ttft_ms_mean']:.1f};"
            f"preempt={c['preemptions']};deferrals={c['decode_deferrals']}",
        ))
    async_rep = run_async_bench(quick)
    for c in async_rep["cells"]:
        rows.append((
            f"serving/async/{c['workload']}/{c['arm']}",
            c["tpot_ms_mean"] * 1e3,
            f"decode_tok_s={c['decode_tok_s']:.1f};"
            f"host_syncs={c['host_syncs']};"
            f"syncs_per_tok={c['host_syncs_per_token']:.3f}",
        ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2-layer model, short prompts")
    ap.add_argument("--chunked", action="store_true",
                    help="run the chunked-vs-monolithic mixed workload "
                         "instead of the stem-on/off study")
    ap.add_argument("--slo", action="store_true",
                    help="run the overload study: FCFS vs the SLO scheduler "
                         "with preemption (BENCH_slo.json)")
    ap.add_argument("--chaos", action="store_true",
                    help="with --slo: run the SLO arm under fault injection "
                         "(alloc denial, step failure, restore failure)")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="run the engine-loop A/B: sync oracle vs the async "
                         "pipeline (on-device sampling, id-only transfers, "
                         "one-step lookahead) (BENCH_async.json)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.async_:
        report = run_async_bench(args.quick)
        out = args.out or "BENCH_async.json"
    elif args.slo:
        report = run_slo_bench(args.quick, chaos=args.chaos)
        out = args.out or "BENCH_slo.json"
    elif args.chunked:
        report = run_chunked_bench(args.quick)
        out = args.out or "BENCH_chunked.json"
    else:
        report = run_bench(args.quick)
        out = args.out or "BENCH_serving.json"
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
