"""Serving A/B: continuous-batching engine throughput, stem-on vs stem-off.

Drives the engine (``runtime/engine.py``) with a mixed-length,
staggered-arrival trace at batch (max_slots) {4, 16} and measures
end-to-end tokens/sec plus p50/p95 per-token decode latency for the
Stem-sparse arm (``budget_frac < 1``) against the dense-equivalent arm
(``budget_frac = 1.0``) on the *same* paged cache and trace — the
comparison isolates what OAM page selection buys at serving time.

Writes ``BENCH_serving.json`` so CI keeps a serving-perf trajectory across
PRs (next to ``BENCH_ragged.json``).

Standalone: ``PYTHONPATH=src python benchmarks/serving.py [--quick]``.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.config import StemConfig

QUICK_ARCH = ArchConfig(
    name="serve-bench-quick", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    qk_norm=True, dtype="float32",
)
FULL_ARCH = ArchConfig(
    name="serve-bench", family="dense", num_layers=6, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=512,
    qk_norm=True, dtype="float32",
)

STEM_BUDGET = 0.25          # the stem-on arm's budget_frac


def _stem_cfg(quick: bool) -> StemConfig:
    return StemConfig(block_size=16 if quick else 32, sink_blocks=1,
                      local_blocks=1, min_budget_blocks=2,
                      stride=4 if quick else 8)


def run_arm(bundle, params, stem_cfg: StemConfig, *, max_slots: int,
            budget_frac: float, min_prompt: int, max_prompt: int,
            decode_tokens: int, seed: int = 0) -> dict:
    """One (batch size, budget) cell: fresh engine, fresh trace, timed run."""
    from repro.launch.serve import _latency_stats, build_trace
    from repro.runtime.engine import EngineConfig, StemEngine

    ecfg = EngineConfig.for_trace(
        max_slots=max_slots, max_prompt=max_prompt,
        max_new_tokens=decode_tokens, page_size=stem_cfg.block_size,
        budget_frac=budget_frac)
    engine = StemEngine(bundle, params, stem_cfg, ecfg)
    mk_trace = lambda: build_trace(
        np.random.RandomState(seed), 2 * max_slots, min_prompt, max_prompt,
        decode_tokens, bundle.cfg.vocab_size, arrival_every=1)

    # Warmup pass with an identical trace: compiles the decode step and
    # every prefill prompt-length bucket, so the timed pass below measures
    # steady-state serving, not XLA compilation.
    engine.run(mk_trace())
    engine.reset_metrics()

    trace = mk_trace()
    for r in trace:                 # preserve the staggered arrival pattern
        r.arrival_step += engine.step_count
    t0 = time.perf_counter()
    finished = engine.run(trace)
    wall = time.perf_counter() - t0
    total_tokens = sum(len(f.tokens) for f in finished)
    return {
        "max_slots": max_slots,
        "budget_frac": budget_frac,
        "requests": len(finished),
        "total_tokens": total_tokens,
        "wall_s": wall,
        "throughput_tok_s": total_tokens / max(wall, 1e-9),
        "ttft_ms_mean": float(np.mean([f.ttft_s for f in finished]) * 1e3),
        "max_concurrency": engine.stats["max_concurrency"],
        "slots_reused": engine.stats["slots_reused"],
        **_latency_stats(finished),
    }


def run_bench(quick: bool) -> dict:
    import jax
    from repro.models import registry

    cfg = QUICK_ARCH if quick else FULL_ARCH
    stem_cfg = _stem_cfg(quick)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    min_prompt, max_prompt = (24, 96) if quick else (64, 384)
    decode_tokens = 8 if quick else 32

    cells = []
    for max_slots in (4, 16):
        for budget_frac in (1.0, STEM_BUDGET):      # stem-off, stem-on
            cell = run_arm(bundle, params, stem_cfg, max_slots=max_slots,
                           budget_frac=budget_frac, min_prompt=min_prompt,
                           max_prompt=max_prompt, decode_tokens=decode_tokens)
            arm = "dense" if budget_frac == 1.0 else "stem"
            print(f"slots={max_slots:>2} {arm:>5}: "
                  f"{cell['throughput_tok_s']:8.1f} tok/s, per-token "
                  f"p50 {cell['p50_ms']:.2f} / p95 {cell['p95_ms']:.2f} ms, "
                  f"TTFT {cell['ttft_ms_mean']:.1f} ms", flush=True)
            cells.append(cell)
    return {
        "benchmark": "serving",
        "mode": "quick" if quick else "full",
        "backend": jax.default_backend(),
        "arch": cfg.name,
        "block_size": stem_cfg.block_size,
        "stem_budget_frac": STEM_BUDGET,
        "decode_tokens": decode_tokens,
        "prompt_range": [min_prompt, max_prompt],
        "cells": cells,
    }


def run(quick: bool = True):
    """benchmarks/run.py entry point: CSV rows per (slots, arm) cell."""
    report = run_bench(quick)
    rows = []
    for c in report["cells"]:
        arm = "dense" if c["budget_frac"] == 1.0 else "stem"
        rows.append((
            f"serving/slots{c['max_slots']}/{arm}",
            c["p50_ms"] * 1e3,
            f"tok_s={c['throughput_tok_s']:.1f};p95_ms={c['p95_ms']:.2f};"
            f"ttft_ms={c['ttft_ms_mean']:.1f}",
        ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2-layer model, short prompts")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    report = run_bench(args.quick)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
