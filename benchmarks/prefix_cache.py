"""Prefix-cache A/B: many tenants, one shared system prompt.

The canonical serving workload for prefix caching (``runtime/paged.py``
hash-keyed page index + copy-on-write, driven by ``runtime/engine.py``
admission): N tenants whose prompts share one multi-page system prompt and
differ only in a short per-tenant suffix.  With the cache OFF every tenant
prefills and stores the full prompt; with it ON each tenant after the
first maps the matched system-prompt pages read-only (one allocator ref
each) and prefills only its suffix.

Reported per arm: pages allocated during the timed run (the memory
headline — must drop >= 2x with sharing), mean/max TTFT (admission ->
first token; sharing skips the matched prefill chunks, so the queue drains
faster), prefix hit/share/CoW counters, and the greedy token streams —
which must be BIT-IDENTICAL between arms: prefix caching is a pure memory
optimisation, the differential suite (``tests/test_prefix_cache.py``)
pins the same property per-path.

Standalone: ``PYTHONPATH=src python benchmarks/prefix_cache.py [--quick]
[--out BENCH_prefix.json]``.  Feeds CI's perf-trajectory artifacts.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

try:
    from benchmarks.serving import FULL_ARCH, QUICK_ARCH, _stem_cfg
except ModuleNotFoundError:      # standalone: benchmarks/ itself on sys.path
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.serving import FULL_ARCH, QUICK_ARCH, _stem_cfg

STEM_BUDGET = 0.25


def build_tenant_workload(rng, *, n_tenants: int, system_pages: int,
                          suffix_range: tuple, decode_tokens: int,
                          arrival_every: int, page_size: int, vocab: int):
    """N tenants = one shared system prompt + per-tenant suffixes.  Suffix
    lengths stay inside one page bracket so every tenant's prompt pads to
    the SAME length — TPD budget rows then match across tenants and every
    system-prompt page is a prefix hit."""
    from repro.runtime.engine import Request

    system = rng.randint(0, vocab,
                         size=(system_pages * page_size,)).astype(np.int32)
    reqs = []
    for i in range(n_tenants):
        suf = int(rng.randint(suffix_range[0], suffix_range[1] + 1))
        suffix = rng.randint(0, vocab, size=(suf,)).astype(np.int32)
        reqs.append(Request(
            uid=i, prompt=np.concatenate([system, suffix]),
            max_new_tokens=decode_tokens, arrival_step=i * arrival_every))
    return reqs


def run_arm(bundle, params, stem_cfg, *, prefix_cache: bool, max_slots: int,
            workload_kw: dict, seed: int = 0) -> dict:
    from repro.launch.serve import _latency_stats
    from repro.runtime.engine import EngineConfig, StemEngine

    bs = stem_cfg.block_size
    max_prompt = (workload_kw["system_pages"] * bs
                  + workload_kw["suffix_range"][1])
    ecfg = EngineConfig.for_trace(
        max_slots=max_slots, max_prompt=max_prompt,
        max_new_tokens=workload_kw["decode_tokens"], page_size=bs,
        budget_frac=STEM_BUDGET, prefix_cache=prefix_cache)
    engine = StemEngine(bundle, params, stem_cfg, ecfg)
    vocab = bundle.cfg.vocab_size
    mk = lambda: build_tenant_workload(np.random.RandomState(seed),
                                       page_size=bs, vocab=vocab,
                                       **workload_kw)

    # Warmup compiles the unified step (and, on the sharing arm, seeds the
    # prefix index — the timed pass below measures steady-state serving).
    engine.run(mk())
    engine.reset_metrics()
    alloced0 = engine.allocator.total_alloced
    hits0 = engine.stats["prefix_hits"]
    shared0 = engine.stats["prefix_pages_shared"]

    trace = mk()
    for r in trace:
        # Fresh uids for the timed pass (the engine rejects resubmitted
        # uids); same offset on both arms keeps the token dicts comparable.
        r.uid += workload_kw["n_tenants"]
        r.arrival_step += engine.step_count
    t0 = time.perf_counter()
    finished = engine.run(trace)
    wall = time.perf_counter() - t0
    total_tokens = sum(len(f.tokens) for f in finished)
    return {
        "arm": "prefix-cache" if prefix_cache else "no-sharing",
        "prefix_cache": prefix_cache,
        "requests": len(finished),
        "total_tokens": total_tokens,
        "wall_s": wall,
        "throughput_tok_s": total_tokens / max(wall, 1e-9),
        "pages_alloced": engine.allocator.total_alloced - alloced0,
        "prefix_hits": engine.stats["prefix_hits"] - hits0,
        "prefix_pages_shared": engine.stats["prefix_pages_shared"] - shared0,
        "prefix_cows": engine.stats["prefix_cows"],
        "cached_pages_at_drain": engine.allocator.cached_pages,
        "steps": engine.step_count,
        "traces": engine.stats["traces"],
        **_latency_stats(finished),
        "tokens": {f.uid: f.tokens for f in finished},
    }


def run_bench(quick: bool) -> dict:
    import jax
    from repro.models import registry

    cfg = QUICK_ARCH if quick else FULL_ARCH
    stem_cfg = _stem_cfg(quick)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    bs = stem_cfg.block_size
    workload_kw = dict(
        n_tenants=8,
        system_pages=4,                    # >= 4-page shared system prompt
        suffix_range=(3, bs - 1),          # same padded length for all
        decode_tokens=8 if quick else 16,
        arrival_every=2,
    )
    max_slots = 2                          # tenants mostly sequential: later
                                           # arrivals see registered pages

    cells = []
    for prefix_cache in (False, True):
        cell = run_arm(bundle, params, stem_cfg, prefix_cache=prefix_cache,
                       max_slots=max_slots, workload_kw=workload_kw)
        print(f"{cell['arm']:>12}: pages alloced {cell['pages_alloced']:>3}, "
              f"TTFT {cell['ttft_ms_mean']:.1f} ms, "
              f"{cell['throughput_tok_s']:8.1f} tok/s, hits "
              f"{cell['prefix_hits']}, pages shared "
              f"{cell['prefix_pages_shared']}, steps {cell['steps']}",
              flush=True)
        cells.append(cell)
    off, on = cells
    identical = off.pop("tokens") == on.pop("tokens")
    report = {
        "benchmark": "prefix_cache",
        "mode": "quick" if quick else "full",
        "backend": jax.default_backend(),
        "arch": cfg.name,
        "block_size": bs,
        "budget_frac": STEM_BUDGET,
        "max_slots": max_slots,
        "workload": {k: (list(v) if isinstance(v, tuple) else v)
                     for k, v in workload_kw.items()},
        "cells": cells,
        "streams_bit_identical": identical,
        "pages_ratio_vs_no_sharing":
            off["pages_alloced"] / max(on["pages_alloced"], 1),
        "ttft_speedup_vs_no_sharing":
            off["ttft_ms_mean"] / max(on["ttft_ms_mean"], 1e-9),
    }
    assert identical, "prefix caching changed a token stream"
    assert report["pages_ratio_vs_no_sharing"] >= 2.0, report
    return report


def run(quick: bool = True):
    """benchmarks/run.py entry point: one CSV row per arm."""
    report = run_bench(quick)
    rows = []
    for c in report["cells"]:
        rows.append((
            f"prefix_cache/{c['arm']}",
            c["ttft_ms_mean"] * 1e3,
            f"pages={c['pages_alloced']};tok_s={c['throughput_tok_s']:.1f};"
            f"hits={c['prefix_hits']};shared={c['prefix_pages_shared']}",
        ))
    rows.append((
        "prefix_cache/ratio", 0.0,
        f"pages_ratio={report['pages_ratio_vs_no_sharing']:.2f};"
        f"ttft_speedup={report['ttft_speedup_vs_no_sharing']:.2f};"
        f"bit_identical={report['streams_bit_identical']}",
    ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2-layer model, short suffixes")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    report = run_bench(args.quick)
    out = args.out or "BENCH_prefix.json"
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
