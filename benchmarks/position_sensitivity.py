"""Paper Figure 3 / Figure 2: causal information flow — where sparsification
is applied matters, and errors amplify recursively across layers.

For each position segment [a, b) we sparsify ONLY those query rows
(StemConfig.sparse_segment) and report:

  * direct     — logits MSE at the sparsified rows themselves,
  * downstream — logits MSE at rows strictly AFTER the segment (these rows'
                 attention was exact: all error arrives via the recursive
                 V-chain of Eq. 1),
  * ratio      — downstream per unit of direct damage (the paper's
                 recursive-anchor claim, depth-normalized),
  * amp        — per-layer downstream error growth L1 -> L_last (the
                 recursive amplification of Figure 2 / Table 1).

Expected: ratio and amp are largest for early segments.  Note (also in
EXPERIMENTS.md): on a 6-layer model the *absolute* loss ordering is
dominated by direct damage (late rows simply have more context to lose);
the paper's 36-layer models sit deep enough that amplification^depth
reverses it.  The mechanism — early errors propagate and amplify more — is
exactly what these columns measure.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common
from repro.models import transformer


def run() -> list[tuple]:
    cfg, params = common.trained_model()
    batch = common.eval_batch()
    dense_logits, dense_h = transformer.forward_hiddens(params, batch, cfg)
    n = dense_logits.shape[1]
    rows, ratios, amps = [], [], []
    for lo, hi in [(0.0, 0.25), (0.25, 0.5), (0.5, 0.75)]:
        sc = common.bench_stem(sparse_segment=(lo, hi), k_start_frac=0.125,
                               min_budget_blocks=1, sink_blocks=0, local_blocks=1)
        s_logits, s_h = transformer.forward_hiddens(params, batch, cfg, stem_cfg=sc)
        cut, start = int(hi * n), int(lo * n)
        direct = float(jnp.mean((dense_logits[:, start:cut] - s_logits[:, start:cut]) ** 2))
        down = float(jnp.mean((dense_logits[:, cut:] - s_logits[:, cut:]) ** 2))
        layer_err = [float(jnp.mean((dense_h[0][l][:, cut:] - s_h[0][l][:, cut:]) ** 2))
                     for l in range(cfg.num_layers)]
        amp = layer_err[-1] / max(layer_err[1], 1e-30)
        ratios.append(down / max(direct, 1e-30))
        amps.append(amp)
        rows.append((f"fig3/segment_{lo:.2f}_{hi:.2f}", 0.0,
                     f"direct={direct:.4e};downstream={down:.4e};"
                     f"ratio={ratios[-1]:.4f};amplification_L1_to_L{cfg.num_layers-1}={amp:.1f}x"))
    rows.append(("fig3/recursive_anchor_claim", 0.0,
                 f"early_ratio={ratios[0]:.4f};late_ratio={ratios[-1]:.4f};"
                 f"early_propagates_more={ratios[0] > ratios[-1]};"
                 f"early_amp={amps[0]:.1f}x;late_amp={amps[-1]:.1f}x;"
                 f"early_amplifies_more={amps[0] > amps[-1]}"))
    return rows
