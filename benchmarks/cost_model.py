"""Paper Eq. (2) / Eq. (4): analytic cost model vs exact computed pairs.

Also reports the decay-savings fraction and the budget-matched uniform
equivalent used by Table 5.
"""
from __future__ import annotations

from repro.core import schedule
from repro.core.config import uniform_equivalent_budget


def run() -> list[tuple]:
    rows = []
    for n, frac in ((8192, 0.2), (32768, 0.1), (131072, 0.1)):
        k_start = int(frac * n)
        for mu in (0.5, 0.7, 1.0):
            measured = schedule.measured_cost_tokens(n, k_start, mu)
            analytic = schedule.cost_decay(n, k_start, mu)
            uniform = schedule.cost_uniform(n, k_start)
            rows.append((
                f"eq4/n{n}_mu{mu}", 0.0,
                f"measured={measured:.4g};eq4={analytic:.4g};"
                f"rel_err={abs(measured-analytic)/analytic:.4f};"
                f"savings_vs_uniform={1 - measured/uniform:.3f}"))
        rows.append((f"eq4/n{n}_kuni", 0.0,
                     f"k_uni(mu=0.7)={uniform_equivalent_budget(k_start, 0.7)};"
                     f"k_start={k_start}"))
    return rows
