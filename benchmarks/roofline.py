"""Roofline collation: reads results/dryrun/*.json -> the EXPERIMENTS.md
tables (per arch x shape x mesh: three terms, bottleneck, MODEL_FLOPS
ratio, memory fit)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common

HBM_PER_CHIP = 16e9   # v5e


def load_records(pattern="*.json"):
    recs = []
    for path in sorted(glob.glob(os.path.join(common.RESULTS, "dryrun", pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def to_markdown(recs, multi_pod: bool) -> str:
    rows = [r for r in recs if r.get("multi_pod") == multi_pod]
    if not rows:
        return "(no records)"
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | bottleneck "
           "| MODEL/HLO flops | peak GB/chip | fits v5e |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        rf = r["roofline"]
        peak = r.get("memory", {}).get("peak_bytes", 0) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | "
            f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | "
            f"{rf['bottleneck'].replace('_s','')} | "
            f"{r.get('model_flops_ratio', 0):.2f} | {peak:.1f} | "
            f"{'yes' if peak and peak <= HBM_PER_CHIP/1e9 else 'NO'} |\n")
    return "".join(out)


def run() -> list[tuple]:
    recs = load_records()
    rows = []
    for r in recs:
        rf = r["roofline"]
        mesh = "multipod" if r["multi_pod"] else "pod"
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / dom if dom else 0.0
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}/{mesh}",
            dom * 1e6,                       # dominant term as us_per_call
            f"bottleneck={rf['bottleneck']};compute_fraction={frac:.3f};"
            f"flops/dev={r['flops_per_device']:.3e};"
            f"coll={r['collectives']['total_bytes']:.3e}"))
    if not rows:
        rows.append(("roofline/missing", 0.0,
                     "run repro.launch.dryrun first"))
    return rows
